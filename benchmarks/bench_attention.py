"""Block-sparse attention benchmark: the fused one-kernel path vs the
composed SDDMM -> softmax -> SpMM triple vs the dense-masked oracle.

For each attention-mask family, times the three implementations and
reports the v7 ``op=attn`` fingerprint, the autotune pick, and the
DETERMINISTIC peak-workspace estimate: the composed path materializes the
scores AND probs tensors (``2 * nnzb * h * w * 4`` bytes per head
instance), while the fused kernel keeps only per-block-row running state
(max + denominator lanes and the context accumulator) — O(L * d).  Emits
``BENCH_attention.json`` for the CI regression-diff step:

  python benchmarks/bench_attention.py --smoke --out BENCH_attention.json \
      --diff benchmarks/BENCH_attention.baseline.json

Gate policy (README ## Benchmarks): the DETERMINISTIC fields gate hard —
case set, mask nnzb / max_bpr, the v7 ``op=attn`` fingerprint key, pick
membership in the attn variant family, the workspace-bytes estimates, and
the two correctness bits (``bitwise_equal``: fused == composed bit-for-bit
in f32; ``matches_oracle``: both within 1e-4 of the dense-masked
reference).  Wall-clock numbers are REPORT-ONLY: interpret-mode timings on
shared runners are not falsifiable.  Refresh with
``--out benchmarks/BENCH_attention.baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis import workspace
from repro.kernels import autotune
from repro.models import attention as A
from repro.obs import metrics as obs_metrics

_BLOCK = (16, 16)
_HEAD_DIM = 64


def _cases(smoke: bool):
    """(name, mask spec, seq_len) — the three mask families at benchmark
    scale (the same patterns the SDDMM benchmark streams)."""
    seq = 256 if smoke else 1024
    yield "attn_banded", A.banded(seq // 4), seq
    yield "attn_local_global", A.local_global(seq // 8, seq // 16), seq
    yield "attn_causal", A.blockwise_causal(), seq


def _dense_masked(q, k, v, mask, scale):
    L = q.shape[1]
    allowed = jnp.asarray(A.mask_allowed(mask, np.arange(L), np.arange(L)))

    def one(qi, ki, vi):                       # [L, d] per (batch, head)
        s = (qi @ ki.T) * scale
        p = jax.nn.softmax(jnp.where(allowed, s, A.NEG_INF), axis=-1)
        return p @ vi
    return jax.vmap(jax.vmap(one, in_axes=1, out_axes=1))(q, k, v)


def _time_fn(fn, *operands, iters=3):
    return obs_metrics.timeit(fn, *operands, warmup=1, iters=iters,
                              reduce="median")


def run(smoke: bool = True) -> dict:
    autotune.set_autotuner(autotune.Autotuner())
    rows = []
    for name, mask, seq in _cases(smoke):
        meta = A.attention_mask_meta(mask, seq, _BLOCK)
        fp = autotune.fingerprint(meta, _HEAD_DIM, op="attn")
        pick = autotune.get_autotuner().pick(meta, _HEAD_DIM, op="attn")
        spec_auto = A.AttnSparsitySpec(mask=mask, block=_BLOCK,
                                       backend="auto", interpret=True)
        rng = np.random.default_rng(0)
        q, k, v = (jnp.asarray(rng.standard_normal((1, seq, 2, _HEAD_DIM)),
                               jnp.float32) for _ in range(3))
        scale = _HEAD_DIM ** -0.5

        def attn(backend):
            spec = A.AttnSparsitySpec(mask=mask, block=_BLOCK,
                                      backend=backend, interpret=True)
            return jax.jit(lambda q, k, v: A.block_sparse_attention(
                q, k, v, spec))

        out_f = attn("fused")(q, k, v)
        out_c = attn("pallas")(q, k, v)
        out_d = _dense_masked(q, k, v, mask, scale)
        err = max(float(jnp.max(jnp.abs(out_f - out_d))),
                  float(jnp.max(jnp.abs(out_c - out_d))))
        fused_s = _time_fn(attn("fused"), q, k, v)
        composed_s = _time_fn(attn("pallas"), q, k, v)
        dense_s = _time_fn(jax.jit(lambda q, k, v: _dense_masked(
            q, k, v, mask, scale)), q, k, v)

        # deterministic peak-workspace estimates (bytes per head instance)
        # from the shared repro.analysis.workspace estimator — the same
        # numbers the launch verifier and dryrun reports use
        composed_ws = workspace.attn_composed_workspace_bytes(meta)
        fused_ws = workspace.attn_fused_state_bytes(_BLOCK, _HEAD_DIM)
        row = {
            "name": name,
            "seq_len": seq,
            "fingerprint": fp.key(),
            "nnzb": meta.nnzb,
            "max_bpr": meta.max_bpr,
            "attn_pick": pick.variant,
            "attn_impl": A.resolve_attn_impl(spec_auto, seq, _HEAD_DIM),
            "composed_workspace_bytes": composed_ws,
            "fused_state_bytes": fused_ws,
            "workspace_ratio": round(composed_ws / fused_ws, 2),
            "bitwise_equal": bool(jnp.all(out_f == out_c)),
            "matches_oracle": err < 1e-4,
            "fused_us": round(fused_s * 1e6, 2),
            "composed_us": round(composed_s * 1e6, 2),
            "dense_oracle_us": round(dense_s * 1e6, 2),
        }
        rows.append(row)
        print(f"{name:>18}: impl={row['attn_impl']} "
              f"fused {row['fused_us']}us / composed {row['composed_us']}us "
              f"/ dense {row['dense_oracle_us']}us, "
              f"workspace {row['workspace_ratio']}x, "
              f"bitwise={row['bitwise_equal']}", file=sys.stderr)
    return {"bench": "attention", "mode": "smoke" if smoke else "full",
            "cases": rows}


def diff(result: dict, baseline: dict) -> int:
    """Regression diff.  Hard gates are the deterministic fields plus the
    two correctness bits; timings are report-only (README policy)."""
    got = {c["name"]: c for c in result["cases"]}
    want = {c["name"]: c for c in baseline["cases"]}
    attn_family = set(autotune.variant_names("attn"))
    failures = []
    for name in sorted(set(want) - set(got)):
        failures.append(f"case disappeared vs baseline: {name}")
    for name, c in got.items():
        if not c["fingerprint"].startswith("v7|op=attn|"):
            failures.append(f"{name}: fingerprint not in the v7 op=attn "
                            f"key space: {c['fingerprint']}")
        if c["attn_pick"] not in attn_family:
            failures.append(f"{name}: pick {c['attn_pick']!r} is not an "
                            f"attn-family variant {attn_family}")
        if not c["bitwise_equal"]:
            failures.append(f"{name}: fused forward is NOT bit-for-bit "
                            f"equal to the composed path")
        if not c["matches_oracle"]:
            failures.append(f"{name}: drifted off the dense-masked oracle")
        base = want.get(name)
        if base is None:
            print(f"note: new case not in baseline: {name}", file=sys.stderr)
            continue
        for field in ("nnzb", "max_bpr", "fingerprint",
                      "composed_workspace_bytes", "fused_state_bytes"):
            if base[field] != c[field]:
                failures.append(f"{name}: deterministic field {field!r} "
                                f"changed {base[field]} -> {c[field]}")
        if base["attn_pick"] != c["attn_pick"]:
            print(f"note: {name} pick changed {base['attn_pick']} -> "
                  f"{c['attn_pick']} (analytic model; informational)",
                  file=sys.stderr)
    if failures:
        print("ATTENTION REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"attention diff OK: {len(got)} cases, deterministic fields "
          f"stable", file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--diff", default=None)
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}", file=sys.stderr)
    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
