"""Autotuned-dispatch benchmark: registry pick vs the hardcoded default.

For a suite of structure classes (band / power-law skew / uniform block
sparsity / near-dense), runs the ``repro.kernels.autotune`` micro-sweep and
reports the measured winner against the pre-registry hardcoded config
(nnz_stream, bn=512).  Because the sweep always measures the default too,
the cached pick is never slower than it (beyond the 2% tie-break band).

Emits machine-readable JSON (``BENCH_autotune.json``) consumed by the CI
regression-diff step:

  python benchmarks/bench_autotune.py --smoke --out BENCH_autotune.json \
      --diff benchmarks/BENCH_autotune.baseline.json

``--diff`` compares fresh results against a committed baseline: the case
set must match and every case must keep ``speedup_vs_default >= 0.9``
(absolute times are machine-specific and are NOT compared; refresh the
baseline with ``--out benchmarks/BENCH_autotune.baseline.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import topology
from repro.kernels import autotune, ops
from repro.obs import metrics as obs_metrics

# speedup below this vs the hardcoded default fails the regression gate;
# smoke mode (CI shared runners, interpret-mode timings) gets extra noise
# headroom — a genuinely wrong pick lands at 0.3-0.5x, far below either
MIN_SPEEDUP = 0.9
MIN_SPEEDUP_SMOKE = 0.75


def _time_config(arrays, meta, b, variant, bn, iters=3):
    """Wall-clock of one (variant, bn) config — a measurement pass
    INDEPENDENT of the tuner's selection sweep, so the speedup gate is
    falsifiable (a bad cached pick shows up here, it isn't >= default by
    construction)."""
    backend = autotune.get_variant(variant).backend
    fn = jax.jit(lambda bb: ops.spmm(arrays, meta, bb, backend=backend,
                                     bn=bn, interpret=True))
    # min: scheduler noise only ever adds time
    return obs_metrics.timeit(fn, b, warmup=1, iters=iters, reduce="min")


def _cases(smoke: bool):
    """name -> (BCSR, N).  Sizes are interpret-mode (CPU) friendly in smoke
    mode; the full suite mirrors the paper's structure classes at ~4-8x
    scaled-down sizes."""
    s = 1 if smoke else 4
    block = (16, 16)
    cases = []
    cases.append(("band", bcsr_lib.from_scipy(
        topology.band(256 * s, 8 * s), block), 128 * s))
    cases.append(("power_law_skew", bcsr_lib.from_scipy(
        topology.power_law(256 * s, 4.0, seed=3), block), 128 * s))
    cases.append(("uniform_p10", bcsr_lib.random_bcsr(
        0, (256 * s, 256 * s), block, 0.10), 128 * s))
    cases.append(("near_dense_p90", bcsr_lib.random_bcsr(
        1, (128 * s, 128 * s), block, 0.90), 128 * s))
    cases.append(("tall_skinny_n32", bcsr_lib.random_bcsr(
        2, (256 * s, 128 * s), block, 0.25), 32))
    return cases


def run(smoke: bool, cache_path=None) -> dict:
    tuner = autotune.Autotuner(cache_path=cache_path)
    iters = 5
    rows = []
    for name, a, n in _cases(smoke):
        a = a.ensure_nonempty_rows()
        fp = autotune.fingerprint_bcsr(a, n)
        choice, timings = tuner.tune(a, n, iters=iters)
        cached = tuner.get(fp)  # what backend="auto" dispatch will use
        tuned_label = f"{cached.variant}/bn{cached.bn}"
        # re-time default and the cached pick in a fresh pass (not the
        # sweep's own numbers) so a genuinely-slow pick fails the gate
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        b = jnp.asarray(np.random.default_rng(0).standard_normal(
            (meta.shape[1], n)).astype(np.float32))
        default_s = _time_config(arrays, meta, b, autotune.DEFAULT_VARIANT,
                                 autotune.DEFAULT_BN, iters=iters)
        if (cached.variant, cached.bn) == (autotune.DEFAULT_VARIANT,
                                           autotune.DEFAULT_BN):
            tuned_s = default_s  # identical config — nothing to re-time
        else:
            tuned_s = _time_config(arrays, meta, b, cached.variant,
                                   cached.bn, iters=iters)
        speedup = (default_s / tuned_s) if (default_s and tuned_s) else 1.0
        row = {
            "name": name,
            "fingerprint": fp.key(),
            "choice": choice.to_dict(),
            "default_us": round(default_s * 1e6, 2) if default_s else None,
            "tuned_us": round(tuned_s * 1e6, 2) if tuned_s else None,
            "speedup_vs_default": round(speedup, 3),
            "timings_us": {k: round(v * 1e6, 2) for k, v in timings.items()},
        }
        rows.append(row)
        print(f"{name:>18}: {tuned_label:<16} "
              f"{row['tuned_us']}us vs default {row['default_us']}us "
              f"({row['speedup_vs_default']}x)", file=sys.stderr)
    return {
        "bench": "autotune",
        "mode": "smoke" if smoke else "full",
        "min_speedup_gate": MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP,
        "cases": rows,
    }


def diff(result: dict, baseline: dict) -> int:
    """Regression diff: structural parity with the baseline + the
    never-slower-than-default gate.  Returns a process exit code."""
    got = {c["name"]: c for c in result["cases"]}
    want = {c["name"]: c for c in baseline["cases"]}
    gate = result.get("min_speedup_gate", MIN_SPEEDUP)
    failures = []
    for name in sorted(set(want) - set(got)):
        failures.append(f"case disappeared vs baseline: {name}")
    for name in sorted(set(got) - set(want)):
        print(f"note: new case not in baseline: {name}", file=sys.stderr)
    for name, c in got.items():
        sp = c["speedup_vs_default"]
        if sp < gate:
            failures.append(
                f"{name}: tuned pick {c['choice']['variant']}/"
                f"bn{c['choice']['bn']} is slower than the hardcoded "
                f"default ({sp}x < {gate}x gate)")
        base = want.get(name)
        if base and base["choice"]["variant"] != c["choice"]["variant"]:
            print(f"note: {name} choice changed "
                  f"{base['choice']['variant']} -> {c['choice']['variant']} "
                  "(machine-dependent; informational)", file=sys.stderr)
    if failures:
        print("AUTOTUNE REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"autotune diff OK: {len(got)} cases, all >= "
          f"{gate}x of default", file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small matrices / few iters (CI job)")
    ap.add_argument("--out", default="BENCH_autotune.json",
                    help="where to write the results JSON")
    ap.add_argument("--cache", default=None,
                    help="autotune decision cache JSON (persisted picks)")
    ap.add_argument("--diff", default=None, metavar="BASELINE",
                    help="after running, diff results against this baseline")
    args = ap.parse_args()

    result = run(args.smoke, cache_path=args.cache)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)

    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
