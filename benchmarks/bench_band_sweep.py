"""Paper Figure 9 + Section VI-C: band-matrix sparsity sweep — at what
sparsity does blocked-sparse beat dense?

The paper reports SMaT > cuBLAS for sparsity >= 78% (N=8) and >= 96%
(N=128), and up to 2,445x over cuSPARSE.  We sweep the same construction
(bandwidth doubling until fully dense) and report the TPU-modeled effective
GFLOP/s of each arm plus the measured-CPU ratio, and locate the crossover.
"""
from __future__ import annotations


from benchmarks.common import (effective_gflops, emit, modeled_bcsr_time,
                               modeled_csr_time, modeled_dense_time)
from repro.core import bcsr as bcsr_lib
from repro.core import topology

SIZE = 4096
BLOCK = (16, 16)


def run():
    rows = []
    for n_cols in (8, 128):
        crossover = None
        bw = 16
        while bw <= SIZE:
            mat = topology.band(SIZE, min(bw, SIZE - 1), seed=0)
            sparsity = 1.0 - mat.nnz / (SIZE * SIZE)
            a = bcsr_lib.from_scipy(mat, BLOCK)
            t_smat = modeled_bcsr_time(a, n_cols)
            t_dense = modeled_dense_time((SIZE, SIZE), n_cols)
            t_csr = modeled_csr_time(mat.nnz, n_cols)
            g = lambda t: effective_gflops(mat.nnz, n_cols, t)
            if t_smat <= t_dense:
                crossover = sparsity   # lowest sparsity where SMaT still wins
            rows.append((
                f"fig9/N{n_cols}_bw{bw}", round(t_smat * 1e6, 2),
                f"sparsity={sparsity:.4f};"
                f"gflops smat={g(t_smat):.0f} dense={g(t_dense):.0f} "
                f"csr={g(t_csr):.1f};vs_csr={t_csr/t_smat:.0f}x"))
            bw *= 2
        cx = f"{crossover:.2f}" if crossover is not None else ">0.997"
        rows.append((f"fig9/N{n_cols}_crossover_sparsity", 0,
                     f"smat_beats_dense_at>={cx}"
                     f" (paper: 0.78 @N=8, 0.96 @N=128 on A100)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
