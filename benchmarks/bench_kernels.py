"""Kernel-level benchmark: Pallas BCSR kernels (interpret-validated) +
block-size roofline table for the TPU target.

Reports, per (block shape x N-tile): modeled T_e, arithmetic intensity,
whether the block is MXU-aligned, and the VMEM working set of the BlockSpec
tiling — the inputs to the §Perf kernel iteration.  Also cross-checks the
nnz-stream and row-loop kernels against the oracle on a skewed matrix
(the dc2 worst case) and reports the static-schedule waste factor the
row-loop pays there (SMaT's documented weakness, fixed by nnz-streaming).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bcsr as bcsr_lib
from repro.core import perf_model as pm
from repro.core import topology
from repro.kernels import bcsr_spmm as pk
from repro.kernels import ops, ref

VMEM_BYTES = 128 * 2 ** 20     # ~128 MiB usable VMEM on v5e-class core


def run():
    rows = []
    # ---- block-size roofline table (TPU target)
    for h, w in [(8, 128), (16, 128), (32, 128), (128, 128), (256, 128),
                 (128, 256)]:
        for bn in (128, 256, 512):
            t_c, t_m, t_e = pm.block_mma_time(h, w, bn)
            ai = (2 * h * w * bn) / ((h * w + w * bn) * 2)
            vmem = (h * w + w * bn + h * bn * 2) * 4 * 2  # dbl-buffered f32
            aligned = (h % 16 == 0) and (w % 128 == 0) and (bn % 128 == 0)
            rows.append((
                f"kernel/block_{h}x{w}_bn{bn}", round(t_e * 1e9, 1),
                f"T_e_ns={t_e*1e9:.0f};bound={'mem' if t_m>t_c else 'mxu'};"
                f"AI={ai:.0f};vmem_kb={vmem/1024:.0f};"
                f"mxu_aligned={aligned};fits_vmem={vmem < VMEM_BYTES}"))

    # ---- dc2 worst case: static row-loop waste vs nnz-stream
    csr = topology.power_law(2048, 6.0, seed=3)
    a = bcsr_lib.from_scipy(csr, (16, 16)).ensure_nonempty_rows()
    bpr = a.blocks_per_row()
    waste = float(bpr.max() * a.n_block_rows) / max(float(bpr.sum()), 1)
    rng = np.random.default_rng(0)
    b = rng.standard_normal((a.n_block_cols * 16, 16)).astype(np.float32)

    got_stream = pk.bcsr_spmm_nnz_stream(
        jnp.asarray(a.vals), jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
        jnp.asarray(b), a.n_block_rows, bn=16, interpret=True)
    fi, fc, rl_, mb = ops.make_row_loop_schedule(a)
    got_loop = pk.bcsr_spmm_row_loop(
        jnp.asarray(a.vals), fi, fc, rl_, jnp.asarray(b), a.n_block_rows,
        bn=16, interpret=True)
    want = ref.bcsr_spmm_ref(jnp.asarray(a.vals), jnp.asarray(a.row_ids),
                             jnp.asarray(a.col_ids), jnp.asarray(b),
                             a.n_block_rows)
    ok_s = bool(np.allclose(np.asarray(got_stream), np.asarray(want),
                            atol=1e-4))
    ok_l = bool(np.allclose(np.asarray(got_loop), np.asarray(want),
                            atol=1e-4))
    rows.append(("kernel/dc2_static_schedule_waste", 0,
                 f"row_loop_grid_steps/nnz_blocks={waste:.1f}x;"
                 f"stream_correct={ok_s};loop_correct={ok_l};"
                 f"(nnz-stream pays 1.0x by construction)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
