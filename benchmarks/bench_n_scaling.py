"""Paper Figure 10 + Section VI-D: wall-clock vs the dense dimension N on a
cop20k_A-class matrix.

Paper claims: DASP wins at N=1 (pure SpMV); SMaT wins from small N on and
scales mildly with N; cuSPARSE/DASP degrade.  At N=1000 on A100 SMaT is
1.7-8.6x faster than the alternatives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, modeled_batched_spmv_time,
                               modeled_bcsr_time, modeled_csr_time, timeit)
from repro.core import bcsr as bcsr_lib
from repro.core import permute, reorder, topology
from repro.kernels import ref

BLOCK = (16, 16)
NS = [1, 8, 32, 128, 512, 1000]


def run():
    rows = []
    csr = topology.suite_matrix("cop20k_A")
    perm = permute.jaccard_rows_fast(csr, block_w=BLOCK[1], tau=0.7,
                                     max_candidates=4096)
    a = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm),
                            BLOCK).ensure_nonempty_rows()
    rng = np.random.default_rng(0)
    bcsr_fn = jax.jit(lambda v, ri, ci, bb: ref.bcsr_spmm_ref(
        v, ri, ci, bb, a.n_block_rows))
    va, ra, ca = (jnp.asarray(a.vals), jnp.asarray(a.row_ids),
                  jnp.asarray(a.col_ids))
    for n in NS:
        b = jnp.asarray(rng.standard_normal((csr.shape[1], n)).astype(
            np.float32))
        t_cpu = timeit(bcsr_fn, va, ra, ca, b)
        mt_smat = modeled_bcsr_time(a, n)
        mt_csr = modeled_csr_time(csr.nnz, n)
        mt_spmv = modeled_batched_spmv_time(csr.nnz, n)
        rows.append((
            f"fig10/N{n}", round(t_cpu * 1e6, 1),
            f"tpu_model_ms smat={mt_smat*1e3:.3f} csr={mt_csr*1e3:.3f} "
            f"batched_spmv={mt_spmv*1e3:.3f};"
            f"smat_vs_csr={mt_csr/mt_smat:.2f}x;"
            f"spmv_wins_at_N1={'yes' if mt_spmv <= mt_smat and n == 1 else '-'}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
