"""Paper Figure 2 + Section III: validate T_tot = T_e * n_e + T_init.

Band matrices of varying bandwidth isolate n_e from load-balance effects
(paper's own setup, scaled 4x down for one CPU core).  Three implementation
tiers mirror the paper's C/B/T ablation:

  naive  — scalar CSR gather + segment_sum (no blocking, no MMA): the
           "no-TC, per-nonzero" tier;
  B      — BCSR block iteration via gather+einsum (skip empty blocks);
  B+T    — the Pallas nnz-streamed kernel semantics; on CPU we measure its
           XLA-equivalent block-matmul path and model the TPU MXU T_e.

Outputs the per-tier Eq.1 fit (T_e, T_init, R^2) — the paper's claim is the
LINEARITY in n_e and the tier gap in T_e, both of which reproduce here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import bcsr as bcsr_lib
from repro.core import perf_model as pm
from repro.core import topology
from repro.kernels import ref

N_COLS = 8
SIZE = 4096
BANDWIDTHS = [16, 32, 64, 128, 256, 512]
BLOCK = (16, 16)


def run():
    rows = []
    fit_data = {"naive": [], "bcsr": []}
    rng = np.random.default_rng(0)
    b_dense = jnp.asarray(rng.standard_normal((SIZE, N_COLS)).astype(
        np.float32))

    csr_fn = jax.jit(lambda d, r, c, b: ref.spmm_csr_ref(d, r, c, b, SIZE))
    bcsr_fn = jax.jit(
        lambda v, ri, ci, b: ref.bcsr_spmm_ref(v, ri, ci, b,
                                               SIZE // BLOCK[0]))

    for bw in BANDWIDTHS:
        mat = topology.band(SIZE, bw, seed=1)
        a = bcsr_lib.from_scipy(mat, BLOCK).ensure_nonempty_rows()
        coo = mat.tocoo()
        d = jnp.asarray(coo.data)
        r = jnp.asarray(coo.row.astype(np.int32))
        c = jnp.asarray(coo.col.astype(np.int32))
        t_naive = timeit(csr_fn, d, r, c, b_dense)
        t_bcsr = timeit(bcsr_fn, jnp.asarray(a.vals),
                        jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
                        b_dense)
        t_tpu_model = pm.spmm_model_time(a.nnzb, *BLOCK, N_COLS)
        fit_data["naive"].append((mat.nnz, t_naive))
        fit_data["bcsr"].append((a.nnzb, t_bcsr))
        rows.append((f"fig2/band_bw{bw}", round(t_bcsr * 1e6, 1),
                     f"nnzb={a.nnzb};naive_us={t_naive*1e6:.1f};"
                     f"tpu_model_us={t_tpu_model*1e6:.2f}"))

    for tier, data in fit_data.items():
        n_e = [x for x, _ in data]
        t = [y for _, y in data]
        f = pm.fit(n_e, t)
        rows.append((f"fig2/eq1_fit_{tier}", round(f.t_init * 1e6, 2),
                     f"T_e_us={f.t_e*1e6:.4f};R2={f.r2:.4f}"))
    # tier gap (the paper's 10-22x claim for TC API + opts, hardware-scaled)
    te_naive = pm.fit(*zip(*[( n, t) for n, t in fit_data["naive"]])).t_e
    te_bcsr = pm.fit(*zip(*[(n, t) for n, t in fit_data["bcsr"]])).t_e
    # per useful flop: naive does 2*N flops per nnz; bcsr 2*h*w*N per block
    per_flop_naive = te_naive / (2 * N_COLS)
    per_flop_bcsr = te_bcsr / (2 * BLOCK[0] * BLOCK[1] * N_COLS)
    rows.append(("fig2/tier_speedup_per_flop",
                 0,
                 f"naive_vs_block={per_flop_naive / per_flop_bcsr:.1f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
