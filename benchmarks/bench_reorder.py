"""Paper Figures 3 & 4 + Section VI-A: reordering's effect on BCSR block
count and per-row load balance, on the SuiteSparse-pattern suite.

Claims validated (paper numbers in brackets, scaled suite):
  * row reordering reduces blocks on most matrices [6/9], up to ~2.5x;
  * on band-structured inputs (conf5_4-8x8) Jaccard may INCREASE blocks;
  * mip1-class: modest block reduction but large blocks-per-row stddev
    reduction [8.4x] — the load-balance win;
  * column permutation adds little [Section VI-F].
"""
from __future__ import annotations


from benchmarks.common import emit
from repro.core import bcsr as bcsr_lib
from repro.core import reorder, topology

BLOCK = (16, 16)


def stats_for(csr):
    a = bcsr_lib.from_scipy(csr, BLOCK)
    bpr = a.blocks_per_row()
    return a.nnzb, float(bpr.std())


def run():
    rows = []
    reduced = 0
    total = 0
    for name in topology.SUITE:
        csr = topology.suite_matrix(name)
        nnzb0, std0 = stats_for(csr)
        perm = reorder.jaccard_rows(csr, block_w=BLOCK[1], tau=0.7,
                                    max_candidates=4096)
        csr_r = reorder.apply_perm(csr, perm)
        nnzb_r, std_r = stats_for(csr_r)
        rperm, cperm = None, None
        # row+col ablation on the smaller matrices only (host-side cost)
        if csr.shape[0] <= 8192:
            rp, cp = reorder.jaccard_rows_cols(csr, BLOCK, tau=0.7)
            csr_rc = reorder.apply_perm(csr, rp, cp)
            nnzb_rc, _ = stats_for(csr_rc)
        else:
            nnzb_rc = nnzb_r
        total += 1
        if nnzb_r < nnzb0:
            reduced += 1
        rows.append((f"fig3/{name}", 0,
                     f"nnzb0={nnzb0};nnzb_row={nnzb_r};nnzb_rowcol={nnzb_rc};"
                     f"reduction={nnzb0/max(nnzb_r,1):.2f}x;"
                     f"bpr_std {std0:.1f}->{std_r:.1f}"))
    rows.append(("fig3/summary_reduced_fraction", 0,
                 f"{reduced}/{total} matrices improved by row reordering"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
