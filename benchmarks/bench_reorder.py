"""Reorder-pipeline benchmark (paper Figs. 3-4 / Section VI-A) with a CI
regression gate, in the style of ``bench_autotune.py``.

For each structure case it reports:
  * nnzb reduction of the FAST clustering (``core.permute``, packed-bitmask
    greedy; native kernel when a C toolchain exists) vs the offline
    pure-Python reference (``core.reorder.jaccard_rows``);
  * clustering wall-clock of both and the speedup (the tentpole's >= 50x
    target is measured on the 4k-row clustered case);
  * permuted-vs-identity SpMM time through the transparent
    ``prepare_sparse(reorder=...)`` + ``spmm`` path.

Emits machine-readable JSON consumed by the CI diff step:

  python benchmarks/bench_reorder.py --smoke --out BENCH_reorder.json \
      --diff benchmarks/BENCH_reorder.baseline.json

``--diff`` checks (a) no baseline case disappeared, (b) on clustered cases
the fast reduction stays >= 95% of the reference's (computed fresh, so the
gate is falsifiable), and (c) the fast reduction stays >= 90% of the
committed baseline's.  Clustering SPEEDUP is wall-clock on a shared
runner — matching the autotune baseline's "report, never compare" policy
for absolute times, a 4k-row speedup below the expected floor prints a
WARNING but never fails CI (the nnzb-reduction gates above are the
deterministic, falsifiable ones).  Refresh the baseline with
``--out benchmarks/BENCH_reorder.baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import native, permute, reorder, topology
from repro.kernels import ops
from repro.obs import metrics as obs_metrics

BLOCK = (16, 16)
TAU = 0.7
MAX_CANDIDATES = 4096
# expected 4k-case clustering speedup (>= 50x with the native kernel).
# Wall-clock on shared CI runners is not falsifiable — below this floor
# the diff prints a WARNING, it never fails (nnzb gates stay hard).
MIN_SPEEDUP_4K = 8.0
MIN_REDUCTION_VS_REF = 0.95
MIN_REDUCTION_VS_BASE = 0.90


def _cases(smoke: bool):
    """name -> (csr, clustered?).  The 4k-row clustered case anchors the
    clustering-speedup criterion in BOTH modes."""
    cases = [
        ("mip1_like_4k", topology.blocked_random(
            n=4096, nnz_target=160_000, cluster=32, seed=0), True),
        ("pdb1HYS_like", topology.blocked_random(
            n=2304, nnz_target=34_000, cluster=32, seed=1), True),
        ("conf5_band", topology.band(1536, 24), False),
        ("dc2_power_law", topology.power_law(2048, 6.0, seed=2), False),
    ]
    if not smoke:
        cases += [
            ("mip1_scaled_8k", topology.blocked_random(
                n=8192, nnz_target=163_000, cluster=64, seed=3), True),
        ]
    return cases


def _time_spmm(a: bcsr_lib.BCSR, reorder_scheme: str, n: int,
               iters: int = 3) -> float:
    arrays, meta = ops.prepare_sparse(
        a, dtype=jnp.float32, reorder=reorder_scheme, tau=TAU,
        max_candidates=MAX_CANDIDATES)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(
        (meta.shape[1], n)).astype(np.float32))
    fn = jax.jit(lambda bb: ops.spmm(arrays, meta, bb, backend="xla"))
    return obs_metrics.timeit(fn, b, warmup=1, iters=iters, reduce="min")


def run(smoke: bool = True) -> dict:
    rows = []
    for name, csr, clustered in _cases(smoke):
        a = bcsr_lib.from_scipy(csr, BLOCK)
        base = a.nnzb
        # fast clustering (min of 3: the permutation is deterministic)
        p_fast = permute.jaccard_rows_fast(
            csr, block_w=BLOCK[1], tau=TAU, max_candidates=MAX_CANDIDATES)
        t_fast = obs_metrics.timeit(
            permute.jaccard_rows_fast, csr, warmup=0, iters=3,
            reduce="min", block_w=BLOCK[1], tau=TAU,
            max_candidates=MAX_CANDIDATES)
        nnzb_fast = bcsr_lib.from_scipy(
            reorder.apply_perm(csr, p_fast), BLOCK).nnzb
        # offline reference (one run: it is the slow side being replaced)
        t0 = time.perf_counter()
        p_ref = reorder.jaccard_rows(csr, block_w=BLOCK[1], tau=TAU,
                                     max_candidates=MAX_CANDIDATES)
        t_ref = time.perf_counter() - t0
        nnzb_ref = bcsr_lib.from_scipy(
            reorder.apply_perm(csr, p_ref), BLOCK).nnzb
        # row_loop static-schedule length (n_block_rows * max_bpr) of the
        # permuted vs identity structure — clustering shrinks max_bpr, so
        # the paper-faithful static kernel visits fewer (mostly-padding)
        # slots.  Report-only per the gate policy (deterministic, but the
        # nnzb gates already pin clustering quality).
        m_id = ops.prepare_sparse_meta(a)
        m_ro = ops.prepare_sparse_meta(a, reorder="jaccard", tau=TAU,
                                       max_candidates=MAX_CANDIDATES)
        # permuted-vs-identity SpMM through the transparent op path
        n = 64 if smoke else 128
        spmm_id = _time_spmm(a, "identity", n)
        spmm_ro = _time_spmm(a, "jaccard", n)
        row = {
            "name": name,
            "rows": int(csr.shape[0]),
            "clustered": clustered,
            "nnzb_base": int(base),
            "nnzb_fast": int(nnzb_fast),
            "nnzb_ref": int(nnzb_ref),
            "reduction_fast": round(base / max(nnzb_fast, 1), 3),
            "reduction_ref": round(base / max(nnzb_ref, 1), 3),
            "clustering_ms_fast": round(t_fast * 1e3, 3),
            "clustering_ms_ref": round(t_ref * 1e3, 3),
            "clustering_speedup": round(t_ref / max(t_fast, 1e-9), 1),
            "spmm_identity_us": round(spmm_id * 1e6, 1),
            "spmm_reordered_us": round(spmm_ro * 1e6, 1),
            "spmm_reordered_ratio": round(spmm_ro / max(spmm_id, 1e-12), 3),
            "sched_len_identity": int(m_id.row_loop_sched_len),
            "sched_len_reordered": int(m_ro.row_loop_sched_len),
            "sched_len_reduction": round(
                m_id.row_loop_sched_len / max(m_ro.row_loop_sched_len, 1),
                3),
        }
        rows.append(row)
        print(f"{name:>16}: nnzb {base}->{nnzb_fast} "
              f"({row['reduction_fast']}x vs ref {row['reduction_ref']}x), "
              f"clustering {row['clustering_ms_fast']}ms vs "
              f"{row['clustering_ms_ref']}ms "
              f"({row['clustering_speedup']}x), spmm ratio "
              f"{row['spmm_reordered_ratio']}, row_loop sched "
              f"{row['sched_len_identity']}->{row['sched_len_reordered']} "
              f"({row['sched_len_reduction']}x)", file=sys.stderr)
    return {
        "bench": "reorder",
        "mode": "smoke" if smoke else "full",
        "native_kernel": native.get_kernel() is not None,
        "block": list(BLOCK),
        "tau": TAU,
        "max_candidates": MAX_CANDIDATES,
        "cases": rows,
    }


def diff(result: dict, baseline: dict) -> int:
    """Regression diff; returns a process exit code."""
    got = {c["name"]: c for c in result["cases"]}
    want = {c["name"]: c for c in baseline["cases"]}
    failures = []
    for name in sorted(set(want) - set(got)):
        failures.append(f"case disappeared vs baseline: {name}")
    for name in sorted(set(got) - set(want)):
        print(f"note: new case not in baseline: {name}", file=sys.stderr)
    for name, c in got.items():
        if c["clustered"]:
            if c["reduction_fast"] < c["reduction_ref"] * MIN_REDUCTION_VS_REF:
                failures.append(
                    f"{name}: fast clustering reduction "
                    f"{c['reduction_fast']}x fell below the reference's "
                    f"{c['reduction_ref']}x")
            base = want.get(name)
            if base and c["reduction_fast"] < \
                    base["reduction_fast"] * MIN_REDUCTION_VS_BASE:
                failures.append(
                    f"{name}: reduction {c['reduction_fast']}x regressed "
                    f"vs committed baseline {base['reduction_fast']}x")
        if "4k" in name and c["clustering_speedup"] < MIN_SPEEDUP_4K:
            # wall-clock on shared runners: warn, never gate (absolute
            # times follow the autotune baseline's report-only policy)
            print(f"WARNING: {name}: clustering speedup "
                  f"{c['clustering_speedup']}x below the expected "
                  f"{MIN_SPEEDUP_4K}x (timing-only signal; not a failure)",
                  file=sys.stderr)
    if failures:
        print("REORDER REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"reorder diff OK: {len(got)} cases "
          f"(native_kernel={result.get('native_kernel')})", file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small case set / small N (CI job)")
    ap.add_argument("--out", default="BENCH_reorder.json",
                    help="where to write the results JSON")
    ap.add_argument("--diff", default=None, metavar="BASELINE",
                    help="after running, diff results against this baseline")
    args = ap.parse_args()

    result = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)

    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
