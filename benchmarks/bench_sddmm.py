"""SDDMM benchmark: the ``ops.sddmm`` variant family over attention-mask
structures.

For each case (a block-sparse attention mask pattern at a given sequence
length, plus one weight-gradient shape), runs the ``op="sddmm"`` autotune
micro-sweep and reports the measured winner against the hardcoded default
(``sddmm_stream``, bn=512).  Emits ``BENCH_sddmm.json`` for the CI
regression-diff step:

  python benchmarks/bench_sddmm.py --smoke --out BENCH_sddmm.json \
      --diff benchmarks/BENCH_sddmm.baseline.json

Gate policy (README ## Benchmarks): the DETERMINISTIC fields gate hard —
case set, mask nnzb / max_bpr (the mask builders are pure functions), the
v7 ``op=sddmm`` fingerprint key, and pick membership in the SDDMM variant
family.  Wall-clock numbers (speedup_vs_default, timings) are REPORT-ONLY:
interpret-mode timings on shared runners are not falsifiable.  Refresh
with ``--out benchmarks/BENCH_sddmm.baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.kernels import autotune, ops
from repro.models import attention as A
from repro.obs import metrics as obs_metrics


def _cases(smoke: bool):
    """(name, host BCSR, n) — n is the SDDMM contraction width (the head
    dim for attention scores, the token count for weight gradients)."""
    seq = 256 if smoke else 1024
    blk = (16, 16)
    yield ("attn_banded",
           A.attention_mask_bcsr(A.banded(seq // 4), seq, blk), 64)
    yield ("attn_local_global",
           A.attention_mask_bcsr(A.local_global(seq // 8, seq // 16),
                                 seq, blk), 64)
    yield ("attn_causal",
           A.attention_mask_bcsr(A.blockwise_causal(), seq, blk), 64)
    # the dW shape: sparse weight structure, token-count contraction
    w = bcsr_lib.random_bcsr_exact(3, (seq, seq), blk,
                                   nnzb=max(2 * (seq // 16), 32))
    yield ("weight_grad", w, 128 if smoke else 512)


def _time_config(arrays, meta, x, y, variant, bn, iters=3):
    """Independent re-timing of one (variant, bn) config — not the sweep's
    own numbers, so a genuinely slow cached pick is visible here."""
    backend = autotune.get_variant(variant).backend
    fn = jax.jit(lambda xx, yy: ops.sddmm(arrays, meta, xx, yy,
                                          backend=backend, bn=bn,
                                          interpret=True))
    return obs_metrics.timeit(fn, x, y, warmup=1, iters=iters,
                              reduce="median")


def run(smoke: bool = True, cache_path=None) -> dict:
    tuner = autotune.Autotuner(cache_path=cache_path)
    rows = []
    for name, a, n in _cases(smoke):
        a = a.ensure_nonempty_rows()
        fp = autotune.fingerprint_bcsr(a, n, op="sddmm")
        choice, timings = tuner.tune(a, n, op="sddmm", iters=3)
        cached = tuner.get(fp)
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((meta.shape[0], n)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((meta.shape[1], n)), jnp.float32)
        dv = autotune.default_variant("sddmm")
        default_s = _time_config(arrays, meta, x, y, dv,
                                 autotune.DEFAULT_BN)
        if (cached.variant, cached.bn) == (dv, autotune.DEFAULT_BN):
            tuned_s = default_s
        else:
            tuned_s = _time_config(arrays, meta, x, y, cached.variant,
                                   cached.bn)
        speedup = (default_s / tuned_s) if (default_s and tuned_s) else 1.0
        row = {
            "name": name,
            "fingerprint": fp.key(),
            "nnzb": meta.nnzb,
            "max_bpr": meta.max_bpr,
            "choice": cached.to_dict(),
            "default_us": round(default_s * 1e6, 2),
            "tuned_us": round(tuned_s * 1e6, 2),
            "speedup_vs_default": round(speedup, 3),
            "timings_us": {k: round(v * 1e6, 2) for k, v in timings.items()},
        }
        rows.append(row)
        print(f"{name:>18}: {cached.variant}/bn{cached.bn} "
              f"{row['tuned_us']}us vs default {row['default_us']}us "
              f"({row['speedup_vs_default']}x)", file=sys.stderr)
    return {"bench": "sddmm", "mode": "smoke" if smoke else "full",
            "cases": rows}


def diff(result: dict, baseline: dict) -> int:
    """Regression diff.  Hard gates are the deterministic fields; timings
    are report-only (README ## Benchmarks policy)."""
    got = {c["name"]: c for c in result["cases"]}
    want = {c["name"]: c for c in baseline["cases"]}
    sddmm_family = set(autotune.variant_names("sddmm"))
    failures = []
    for name in sorted(set(want) - set(got)):
        failures.append(f"case disappeared vs baseline: {name}")
    for name, c in got.items():
        if not c["fingerprint"].startswith("v7|op=sddmm|"):
            failures.append(f"{name}: fingerprint not in the v7 op=sddmm "
                            f"key space: {c['fingerprint']}")
        if c["choice"]["variant"] not in sddmm_family:
            failures.append(f"{name}: pick {c['choice']['variant']!r} is "
                            f"not an SDDMM-family variant {sddmm_family}")
        base = want.get(name)
        if base is None:
            print(f"note: new case not in baseline: {name}", file=sys.stderr)
            continue
        for field in ("nnzb", "max_bpr", "fingerprint"):
            if base[field] != c[field]:
                failures.append(f"{name}: deterministic field {field!r} "
                                f"changed {base[field]} -> {c[field]}")
        if base["choice"]["variant"] != c["choice"]["variant"]:
            print(f"note: {name} choice changed "
                  f"{base['choice']['variant']} -> {c['choice']['variant']} "
                  "(machine-dependent; informational)", file=sys.stderr)
    if failures:
        print("SDDMM REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"sddmm diff OK: {len(got)} cases, deterministic fields stable",
          file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--diff", default=None)
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}", file=sys.stderr)
    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
