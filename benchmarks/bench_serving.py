"""Serving benchmark: continuous batching + paged block-sparse KV under
a seeded Poisson arrival trace.

Drives ``ServeEngine.step()`` explicitly: requests arrive at seeded
Poisson inter-arrival steps (shared prompt prefixes exercise the prefix
cache), the scheduler admits them into slots as they free up, and every
decision is recorded.  Emits ``BENCH_serving.json`` for the CI
regression-diff step:

  python benchmarks/bench_serving.py --smoke --out BENCH_serving.json \
      --diff benchmarks/BENCH_serving.baseline.json

Gate policy (README ## Benchmarks): the DETERMINISTIC fields gate hard —
the full scheduler trace (admit/finish events with step, slot, reuse),
its ``serve.*`` obs-event view (deterministic fields + checksum — the
same decisions through ``repro.obs``; a baseline match IS the
two-identical-runs bitwise-stability gate), prefix-cache hit counts,
the greedy token-stream checksum, per-request latency in STEPS
(p50/p99), and the paged-KV accounting (page counts, pages touched per
step, resident bytes).  All of these are pure
functions of the seeded trace, so any drift is a real behavior change.
Wall-clock tokens/sec and millisecond latencies are REPORT-ONLY:
interpret-mode timings on shared runners are not falsifiable.  Refresh
with ``--out benchmarks/BENCH_serving.baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import transformer as T
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.serve.engine import Request, ServeEngine

_VOCAB = 97


def _cfg() -> ModelConfig:
    return ModelConfig(
        name="bench-serving", family="dense", layout="attn_mlp",
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=_VOCAB, dtype="float32",
        attn_sparsity=A.AttnSparsitySpec(mask=A.banded(32), block=(16, 16),
                                         backend="xla", interpret=True))


def _arrival_trace(n_requests: int, max_new: int, seed: int = 0):
    """[(arrival_step, Request)] — Poisson inter-arrivals; every third
    request shares the pool prompt's prefix (prefix-cache traffic)."""
    rng = np.random.default_rng(seed)
    steps = np.cumsum(rng.poisson(2, n_requests))
    shared = rng.integers(0, _VOCAB, size=8, dtype=np.int32)
    out = []
    for rid in range(n_requests):
        if rid % 3 == 0:
            tail = rng.integers(0, _VOCAB, size=2, dtype=np.int32)
            prompt = np.concatenate([shared[:6], tail]).astype(np.int32)
        else:
            prompt = rng.integers(0, _VOCAB, size=int(rng.integers(3, 9)),
                                  dtype=np.int32)
        out.append((int(steps[rid]),
                    Request(rid=rid, prompt=prompt, max_new_tokens=max_new)))
    return out


def run(smoke: bool = True) -> dict:
    n_requests, max_new = (8, 4) if smoke else (32, 16)
    cfg = _cfg()
    params = T.init_params(cfg, seed=0)
    engine = ServeEngine(cfg, params, n_slots=2, cache_len=64)
    trace = _arrival_trace(n_requests, max_new)

    pending = list(trace)
    arrived_at, finished_at, tokens = {}, {}, {}
    t0 = time.perf_counter()
    step = 0
    with obs_trace.capture() as cap:
        while pending or engine.scheduler.has_work():
            while pending and pending[0][0] <= step:
                _, req = pending.pop(0)
                arrived_at[req.rid] = step
                engine.enqueue(req)
            for rid, tok in engine.step():
                tokens.setdefault(rid, []).append(tok)
                if len(tokens[rid]) == max_new:
                    finished_at[rid] = step
            step += 1
    wall_s = time.perf_counter() - t0
    # the serve.* slice of the obs stream, deterministic fields only:
    # (kind, name, args) — seq/span ids shift with unrelated events (e.g.
    # first-trace autotune picks), so they stay out of the gate
    serve_events = obs_export.deterministic_events(
        cap.events, prefix="serve.", fields=("kind", "name", "args"))

    total_tokens = sum(len(t) for t in tokens.values())
    latency = np.asarray(sorted(finished_at[r] - arrived_at[r]
                                for r in finished_at))
    checksum = int(sum((i + 1) * int(t) for toks in tokens.values()
                       for i, t in enumerate(toks)) % 1_000_000_007)
    kv_rep = engine.paged_kv.report()
    result = {
        "bench": "serving",
        "mode": "smoke" if smoke else "full",
        # -------- deterministic (hard-gated) --------
        "n_requests": n_requests,
        "max_new_tokens": max_new,
        "total_tokens": total_tokens,
        "token_checksum": checksum,
        "engine_steps": step,
        "scheduler_trace": engine.scheduler.trace,
        "prefix_hits": engine.scheduler.prefix_hits,
        "prefix_tokens_reused": engine.scheduler.prefix_tokens_reused,
        "latency_steps_p50": float(np.percentile(latency, 50)),
        "latency_steps_p99": float(np.percentile(latency, 99)),
        # the scheduler's obs-event view of the same decisions (PR 10:
        # one emitter, two views) — a committed-baseline diff of these IS
        # the two-identical-runs bitwise-stability gate
        "obs_serve_events": serve_events,
        "obs_serve_checksum": obs_export.checksum(serve_events),
        "paged_kv": {
            "resident_page_counts": kv_rep["resident_page_counts"],
            "resident_bytes_total": kv_rep["resident_bytes_total"],
            "offload_bytes_total": kv_rep["offload_bytes_total"],
            "groups": [{k: g[k] for k in ("group", "paged", "n_pages",
                                          "pages_touched_per_step",
                                          "page_bytes")}
                       for g in kv_rep["groups"]],
        },
        # -------- wall-clock (report-only) --------
        "wall_s": round(wall_s, 3),
        "tokens_per_sec": round(total_tokens / wall_s, 1),
        "latency_ms_p50": round(float(np.percentile(latency, 50))
                                * wall_s / step * 1e3, 2),
        "latency_ms_p99": round(float(np.percentile(latency, 99))
                                * wall_s / step * 1e3, 2),
    }
    print(f"serving: {n_requests} requests, {total_tokens} tokens in "
          f"{step} steps ({result['tokens_per_sec']} tok/s report-only), "
          f"prefix hits {result['prefix_hits']} "
          f"({result['prefix_tokens_reused']} tokens), latency p50/p99 "
          f"{result['latency_steps_p50']}/{result['latency_steps_p99']} "
          "steps", file=sys.stderr)
    return result


# deterministic fields that must match the committed baseline exactly
_GATED = ("n_requests", "max_new_tokens", "total_tokens", "token_checksum",
          "engine_steps", "scheduler_trace", "prefix_hits",
          "prefix_tokens_reused", "latency_steps_p50", "latency_steps_p99",
          "paged_kv", "obs_serve_events", "obs_serve_checksum")


def diff(result: dict, baseline: dict) -> int:
    """Regression diff: every deterministic field gates hard; wall-clock
    numbers are report-only (README policy)."""
    failures = []
    if result.get("mode") != baseline.get("mode"):
        print(f"note: mode changed {baseline.get('mode')} -> "
              f"{result.get('mode')}; skipping field diff", file=sys.stderr)
        return 0
    for field in _GATED:
        if result.get(field) != baseline.get(field):
            failures.append(f"deterministic field {field!r} changed: "
                            f"{baseline.get(field)!r} -> "
                            f"{result.get(field)!r}")
    if failures:
        print("SERVING REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"serving diff OK: {len(_GATED)} deterministic fields stable "
          f"(trace of {len(result['scheduler_trace'])} events)",
          file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--diff", default=None)
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}", file=sys.stderr)
    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
