"""Sharded-SpMM scaling benchmark (``launch.dist_spmm``) with a CI
regression gate, in the style of ``bench_autotune.py`` / ``bench_reorder.py``.

For each structure case and shard count in {1, 2, 4, 8} it reports:
  * per-shard nonzero-block loads of the LPT partition
    (``core.permute.shard_bins``) and the imbalance (max/mean) vs a naive
    contiguous equal-row split — the balance the partition buys;
  * wall-clock of the sharded SpMM (in-process local mode — the math the
    shard_map runs per device) vs the unsharded reference.

Emits machine-readable JSON consumed by the CI diff step:

  python benchmarks/bench_shard_scaling.py --smoke \
      --out BENCH_shard_scaling.json \
      --diff benchmarks/BENCH_shard_scaling.baseline.json

Gate policy (matching the autotune baseline's "report, never compare"
stance on absolute times): nnzb-BALANCE gates are hard — they are
deterministic functions of the seeded structures — while timings are
reported only.  ``--diff`` checks (a) no baseline case disappeared,
(b) the LPT imbalance never exceeds the contiguous split's, and (c) the
imbalance stays within 10% of the committed baseline's.  Refresh with
``--out benchmarks/BENCH_shard_scaling.baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import topology
from repro.kernels import ops
from repro.launch import dist_spmm

SHARD_COUNTS = (1, 2, 4, 8)
MAX_IMBALANCE_VS_BASE = 1.10


def _cases(smoke: bool):
    s = 1 if smoke else 4
    block = (16, 16)
    cases = [
        ("power_law_skew", bcsr_lib.from_scipy(
            topology.power_law(512 * s, 5.0, seed=2), block)),
        ("clustered", bcsr_lib.from_scipy(
            topology.blocked_random(n=512 * s, nnz_target=9000 * s,
                                    cluster=16, seed=1), block)),
        ("uniform_p15", bcsr_lib.random_bcsr(
            0, (512 * s, 256 * s), block, 0.15)),
    ]
    return cases


def _time(fn, b, iters=3):
    jax.block_until_ready(fn(b))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(b))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def run(smoke: bool = True) -> dict:
    n = 64 if smoke else 256
    rows = []
    for name, a in _cases(smoke):
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        b = jnp.asarray(np.random.default_rng(0).standard_normal(
            (meta.shape[1], n)).astype(np.float32))
        ref_s = _time(jax.jit(
            lambda bb: ops.spmm(arrays, meta, bb, backend="xla")), b)
        for S in SHARD_COUNTS:
            st = dist_spmm.shard_balance_stats(a, S)
            sharr, smeta = dist_spmm.prepare_sharded(a, S, dtype=jnp.float32)
            sh_s = _time(jax.jit(
                lambda bb, _sh=sharr, _sm=smeta: dist_spmm.spmm_sharded(
                    _sh, _sm, bb, backend="xla")), b)
            row = {
                "name": f"{name}/s{S}",
                "case": name,
                "n_shards": S,
                "nnzb": st["nnzb"],
                "loads": st["loads"],
                "imbalance": st["imbalance"],
                "contig_imbalance": st["contig_imbalance"],
                "load_cv_pct": st["load_cv_pct"],
                # absolute times are machine-dependent: reported, never gated
                "spmm_ref_us": round(ref_s * 1e6, 1),
                "spmm_sharded_us": round(sh_s * 1e6, 1),
            }
            rows.append(row)
            print(f"{row['name']:>20}: loads {row['loads']} "
                  f"(imb {row['imbalance']}x vs contig "
                  f"{row['contig_imbalance']}x), sharded "
                  f"{row['spmm_sharded_us']}us vs ref {row['spmm_ref_us']}us",
                  file=sys.stderr)
    return {
        "bench": "shard_scaling",
        "mode": "smoke" if smoke else "full",
        "shard_counts": list(SHARD_COUNTS),
        "cases": rows,
    }


def diff(result: dict, baseline: dict) -> int:
    """Regression diff; returns a process exit code.  Balance gates are
    hard (deterministic); timings are informational."""
    got = {c["name"]: c for c in result["cases"]}
    want = {c["name"]: c for c in baseline["cases"]}
    failures = []
    for name in sorted(set(want) - set(got)):
        failures.append(f"case disappeared vs baseline: {name}")
    for name in sorted(set(got) - set(want)):
        print(f"note: new case not in baseline: {name}", file=sys.stderr)
    for name, c in got.items():
        if c["imbalance"] > c["contig_imbalance"] + 1e-9:
            failures.append(
                f"{name}: LPT imbalance {c['imbalance']}x exceeds the "
                f"naive contiguous split's {c['contig_imbalance']}x")
        base = want.get(name)
        if base and c["imbalance"] > \
                base["imbalance"] * MAX_IMBALANCE_VS_BASE:
            failures.append(
                f"{name}: imbalance {c['imbalance']}x regressed vs "
                f"committed baseline {base['imbalance']}x")
    if failures:
        print("SHARD-SCALING REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"shard_scaling diff OK: {len(got)} cases", file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small case set / small N (CI job)")
    ap.add_argument("--out", default="BENCH_shard_scaling.json",
                    help="where to write the results JSON")
    ap.add_argument("--diff", default=None, metavar="BASELINE",
                    help="after running, diff results against this baseline")
    args = ap.parse_args()

    result = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)

    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
