"""Sharded-SpMM scaling benchmark (``launch.dist_spmm``) with a CI
regression gate, in the style of ``bench_autotune.py`` / ``bench_reorder.py``.

For each structure case and shard count in {1, 2, 4, 8} it reports:
  * per-shard nonzero-block loads of the LPT partition
    (``core.permute.shard_bins``) and the imbalance (max/mean) vs a naive
    contiguous equal-row split — the balance the partition buys;
  * wall-clock of the sharded SpMM (in-process local mode — the math the
    shard_map runs per device) vs the unsharded reference.

An ``overlap`` section sweeps the communication-overlap pipeline: per
structure it resolves the autotuned shard count (``dist_spmm
.resolve_n_shards`` — the same v7-keyed decision ``shards="auto"``
makes), runs the chunked dispatch at n_chunks in {1, 2, 4}, and records
whether every chunked panel is BIT-identical to the unchunked one
(uint32 view compare), the chunk schedules, and report-only timings of
auto-S chunked vs fixed-S unchunked.

Emits machine-readable JSON consumed by the CI diff step:

  python benchmarks/bench_shard_scaling.py --smoke \
      --out BENCH_shard_scaling.json \
      --diff benchmarks/BENCH_shard_scaling.baseline.json

Gate policy (matching the autotune baseline's "report, never compare"
stance on absolute times): nnzb-BALANCE gates are hard — they are
deterministic functions of the seeded structures — while timings are
reported only.  ``--diff`` checks (a) no baseline case disappeared,
(b) the LPT imbalance never exceeds the contiguous split's, (c) the
imbalance stays within 10% of the committed baseline's, and (d) the
overlap invariants: every chunked run bit-identical, the autotuned
shard counts unchanged vs baseline AND structure-dependent (the skewed
structure must pick S>1, the uniform one S=1).  Refresh with
``--out benchmarks/BENCH_shard_scaling.baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import topology
from repro.kernels import ops
from repro.launch import dist_spmm
from repro.obs import metrics as obs_metrics

SHARD_COUNTS = (1, 2, 4, 8)
CHUNK_COUNTS = (1, 2, 4)
MAX_IMBALANCE_VS_BASE = 1.10


def _cases(smoke: bool):
    s = 1 if smoke else 4
    block = (16, 16)
    cases = [
        ("power_law_skew", bcsr_lib.from_scipy(
            topology.power_law(512 * s, 5.0, seed=2), block)),
        ("clustered", bcsr_lib.from_scipy(
            topology.blocked_random(n=512 * s, nnz_target=9000 * s,
                                    cluster=16, seed=1), block)),
        ("uniform_p15", bcsr_lib.random_bcsr(
            0, (512 * s, 256 * s), block, 0.15)),
    ]
    return cases


def _time(fn, b, iters=3):
    return obs_metrics.timeit(fn, b, warmup=1, iters=iters, reduce="min")


def _overlap_sweep(smoke: bool, n: int) -> list:
    """Per structure: autotuned shard count (the ``shards="auto"``
    decision), chunked-vs-unchunked bit-identity at each pipeline depth,
    schedules, and report-only timings (auto-S chunked, fixed-S=4)."""
    out = []
    for name, a in _cases(smoke):
        _, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        b = jnp.asarray(np.random.default_rng(0).standard_normal(
            (meta.shape[1], n)).astype(np.float32))
        choice = dist_spmm.resolve_n_shards(a, n=n, max_shards=8, n_chunks=2)
        S = max(choice.n_shards, 1)
        sharr, smeta = dist_spmm.prepare_sharded(a, S, dtype=jnp.float32)
        base = None
        chunk_rows = []
        for k in CHUNK_COUNTS:
            fn = jax.jit(lambda bb, _k=k: dist_spmm.spmm_sharded(
                sharr, smeta, bb, backend="xla", n_chunks=_k))
            got = np.asarray(jax.block_until_ready(fn(b)))
            if base is None:
                base = got
            chunk_rows.append({
                "n_chunks": k,
                "schedule": [list(c) for c in
                             dist_spmm.chunk_schedule(n, k)],
                # the overlap contract: chunked == unchunked to the bit
                "bitwise_equal": bool(np.array_equal(
                    base.view(np.uint32), got.view(np.uint32))),
                "us": round(_time(fn, b) * 1e6, 1),
            })
        f_arr, f_meta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
        fixed_s = _time(jax.jit(lambda bb: dist_spmm.spmm_sharded(
            f_arr, f_meta, bb, backend="xla")), b)
        row = {
            "name": name,
            "auto_shards": S,
            "auto_source": choice.source,
            "predicted_us": choice.predicted_us,
            "chunks": chunk_rows,
            "fixed_s4_us": round(fixed_s * 1e6, 1),
        }
        out.append(row)
        bits = "".join("=" if c["bitwise_equal"] else "X"
                       for c in chunk_rows)
        print(f"{name:>20}: auto S={S} ({choice.source}), chunk bits "
              f"[{bits}], auto-chunked "
              f"{[c['us'] for c in chunk_rows]}us, fixed-S4 "
              f"{row['fixed_s4_us']}us", file=sys.stderr)
    return out


def run(smoke: bool = True, overlap_only: bool = False) -> dict:
    n = 64 if smoke else 256
    if overlap_only:
        return {
            "bench": "shard_scaling",
            "mode": "smoke" if smoke else "full",
            "overlap": _overlap_sweep(smoke, n),
        }
    rows = []
    for name, a in _cases(smoke):
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        b = jnp.asarray(np.random.default_rng(0).standard_normal(
            (meta.shape[1], n)).astype(np.float32))
        ref_s = _time(jax.jit(
            lambda bb: ops.spmm(arrays, meta, bb, backend="xla")), b)
        for S in SHARD_COUNTS:
            st = dist_spmm.shard_balance_stats(a, S)
            sharr, smeta = dist_spmm.prepare_sharded(a, S, dtype=jnp.float32)
            sh_s = _time(jax.jit(
                lambda bb, _sh=sharr, _sm=smeta: dist_spmm.spmm_sharded(
                    _sh, _sm, bb, backend="xla")), b)
            row = {
                "name": f"{name}/s{S}",
                "case": name,
                "n_shards": S,
                "nnzb": st["nnzb"],
                "loads": st["loads"],
                "imbalance": st["imbalance"],
                "contig_imbalance": st["contig_imbalance"],
                "load_cv_pct": st["load_cv_pct"],
                # absolute times are machine-dependent: reported, never gated
                "spmm_ref_us": round(ref_s * 1e6, 1),
                "spmm_sharded_us": round(sh_s * 1e6, 1),
            }
            rows.append(row)
            print(f"{row['name']:>20}: loads {row['loads']} "
                  f"(imb {row['imbalance']}x vs contig "
                  f"{row['contig_imbalance']}x), sharded "
                  f"{row['spmm_sharded_us']}us vs ref {row['spmm_ref_us']}us",
                  file=sys.stderr)
    return {
        "bench": "shard_scaling",
        "mode": "smoke" if smoke else "full",
        "shard_counts": list(SHARD_COUNTS),
        "cases": rows,
        "overlap": _overlap_sweep(smoke, n),
    }


def diff(result: dict, baseline: dict) -> int:
    """Regression diff; returns a process exit code.  Balance gates are
    hard (deterministic); timings are informational."""
    got = {c["name"]: c for c in result.get("cases", ())}
    want = {c["name"]: c for c in baseline.get("cases", ())}
    failures = []
    for name in sorted(set(want) - set(got)):
        failures.append(f"case disappeared vs baseline: {name}")
    for name in sorted(set(got) - set(want)):
        print(f"note: new case not in baseline: {name}", file=sys.stderr)
    for name, c in got.items():
        if c["imbalance"] > c["contig_imbalance"] + 1e-9:
            failures.append(
                f"{name}: LPT imbalance {c['imbalance']}x exceeds the "
                f"naive contiguous split's {c['contig_imbalance']}x")
        base = want.get(name)
        if base and c["imbalance"] > \
                base["imbalance"] * MAX_IMBALANCE_VS_BASE:
            failures.append(
                f"{name}: imbalance {c['imbalance']}x regressed vs "
                f"committed baseline {base['imbalance']}x")

    # overlap invariants: bit-identity and the autotuned shard counts are
    # deterministic functions of (structure, dims) — hard gates, like the
    # balance fields (timings above stay report-only)
    ov_got = {c["name"]: c for c in result.get("overlap", ())}
    ov_want = {c["name"]: c for c in baseline.get("overlap", ())}
    for name in sorted(set(ov_want) - set(ov_got)):
        failures.append(f"overlap case disappeared vs baseline: {name}")
    for name, c in ov_got.items():
        for ch in c["chunks"]:
            if not ch["bitwise_equal"]:
                failures.append(
                    f"{name}: n_chunks={ch['n_chunks']} output is NOT "
                    "bit-identical to the unchunked panel")
        base = ov_want.get(name)
        if base and c["auto_shards"] != base["auto_shards"]:
            failures.append(
                f"{name}: autotuned shard count {c['auto_shards']} != "
                f"baseline {base['auto_shards']} — the shards=\"auto\" "
                "decision drifted")
        if base and [ch["schedule"] for ch in c["chunks"]] != \
                [ch["schedule"] for ch in base["chunks"]]:
            failures.append(f"{name}: chunk schedules drifted vs baseline")
    # structure dependence (acceptance invariant): the skewed structure
    # must shard, the uniform one must not
    if "power_law_skew" in ov_got and \
            ov_got["power_law_skew"]["auto_shards"] <= 1:
        failures.append("power_law_skew: expected autotuned S>1 for the "
                        "skewed structure, got S=1")
    if "uniform_p15" in ov_got and \
            ov_got["uniform_p15"]["auto_shards"] != 1:
        failures.append(
            f"uniform_p15: expected autotuned S=1 for the uniform "
            f"structure, got S={ov_got['uniform_p15']['auto_shards']}")
    if failures:
        print("SHARD-SCALING REGRESSION:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"shard_scaling diff OK: {len(got)} cases", file=sys.stderr)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small case set / small N (CI job)")
    ap.add_argument("--out", default="BENCH_shard_scaling.json",
                    help="where to write the results JSON")
    ap.add_argument("--diff", default=None, metavar="BASELINE",
                    help="after running, diff results against this baseline")
    ap.add_argument("--overlap", action="store_true",
                    help="run only the communication-overlap sweep "
                         "(auto-S + chunked bit-identity), skipping the "
                         "shard-count scaling section")
    args = ap.parse_args()

    result = run(args.smoke, overlap_only=args.overlap)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}", file=sys.stderr)

    if args.diff:
        with open(args.diff) as f:
            baseline = json.load(f)
        return diff(result, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
