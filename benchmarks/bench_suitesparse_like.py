"""Paper Figure 8 / Table I: SpMM throughput on the SuiteSparse-pattern
suite (N=8 tall-skinny, the paper's DASP-fair setting).

Arms (CPU-measured wall clock of the XLA implementations + TPU-modeled
effective GFLOP/s from Eq.1):
  smat   — BCSR after Jaccard row reorder (the full SMaT pipeline);
  csr    — scalar CSR (cuSPARSE stand-in);
  spmv8  — 8 batched SpMVs (DASP stand-in);
  dense  — padded dense GEMM (cuBLAS stand-in).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (effective_gflops, emit,
                               modeled_batched_spmv_time, modeled_bcsr_time,
                               modeled_csr_time, modeled_dense_time, timeit)
from repro.core import bcsr as bcsr_lib
from repro.core import permute, reorder, topology
from repro.kernels import ref

BLOCK = (16, 16)
N = 8


def run():
    rows = []
    speedups = []
    rng = np.random.default_rng(0)
    for name in topology.SUITE:
        csr = topology.suite_matrix(name)
        m = csr.shape[0]
        nnz = csr.nnz
        perm = permute.jaccard_rows_fast(csr, block_w=BLOCK[1],
                                         tau=0.7, max_candidates=4096)
        a = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm),
                                BLOCK).ensure_nonempty_rows()
        k_pad = a.n_block_cols * BLOCK[1]
        b_np = rng.standard_normal((k_pad, N)).astype(np.float32)
        b_np[csr.shape[1]:] = 0
        b = jnp.asarray(b_np)

        bcsr_fn = jax.jit(lambda v, ri, ci, bb: ref.bcsr_spmm_ref(
            v, ri, ci, bb, a.n_block_rows))
        coo = csr.tocoo()
        csr_fn = jax.jit(lambda d, r, c, bb: ref.spmm_csr_ref(
            d, r, c, bb, m))

        t_smat = timeit(bcsr_fn, jnp.asarray(a.vals),
                        jnp.asarray(a.row_ids), jnp.asarray(a.col_ids), b)
        t_csr = timeit(csr_fn, jnp.asarray(coo.data),
                       jnp.asarray(coo.row.astype(np.int32)),
                       jnp.asarray(coo.col.astype(np.int32)), b)

        # modeled TPU numbers (paper's reporting unit)
        mt_smat = modeled_bcsr_time(a, N)
        mt_csr = modeled_csr_time(nnz, N)
        mt_spmv = modeled_batched_spmv_time(nnz, N)
        mt_dense = modeled_dense_time(csr.shape, N)
        g = lambda t: effective_gflops(nnz, N, t)
        speedups.append(mt_csr / mt_smat)
        rows.append((
            f"fig8/{name}", round(t_smat * 1e6, 1),
            f"cpu_csr_us={t_csr*1e6:.1f};"
            f"tpu_gflops smat={g(mt_smat):.0f} csr={g(mt_csr):.0f} "
            f"spmv8={g(mt_spmv):.0f} dense={g(mt_dense):.0f};"
            f"speedup_vs_csr={mt_csr/mt_smat:.1f}x"))
    geo = float(np.exp(np.mean(np.log(speedups))))
    rows.append(("fig8/geomean_speedup_vs_csr", 0, f"{geo:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
