"""Shared benchmark utilities.

Two measurement regimes (the container is CPU-only, TPU v5e is the target):
  * measured  — CPU wall-clock of the jitted XLA implementations (relative
    comparisons between algorithmic arms are meaningful);
  * modeled   — paper Eq.1 with the TPU block-roofline T_e
    (``core.perf_model``), reported as effective GFLOP/s exactly like the
    paper's figures.
Matrix sizes are scaled ~4-8x down from the paper's (single CPU core).
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core import bcsr as bcsr_lib
from repro.core import perf_model as pm
from repro.obs import metrics as obs_metrics


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5,
           reduce: str = "median", name: Optional[str] = None) -> float:
    """Wall-clock seconds of fn(*args) (jax arrays blocked) — delegates
    to ``repro.obs.metrics.timeit``, THE timing loop shared by every
    benchmark (the per-file copies were consolidated onto it)."""
    return obs_metrics.timeit(fn, *args, warmup=warmup, iters=iters,
                              reduce=reduce, name=name)


def modeled_bcsr_time(a: bcsr_lib.BCSR, n: int) -> float:
    h, w = a.block
    return pm.spmm_model_time(a.nnzb, h, w, n)


def modeled_dense_time(shape, n: int) -> float:
    return pm.dense_gemm_time(shape[0], shape[1], n)


def modeled_csr_time(nnz: int, n: int) -> float:
    return pm.csr_spmm_time(nnz, n)


def modeled_batched_spmv_time(nnz: int, n: int) -> float:
    """DASP arm: SpMM as n independent SpMVs (the paper's comparison mode).
    Each SpMV pays the full matrix stream."""
    return n * pm.csr_spmm_time(nnz, 1, gather_overhead=2.0)


def effective_gflops(nnz: int, n: int, t: float) -> float:
    return pm.spmm_effective_gflops(nnz, n, t)


def emit(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
