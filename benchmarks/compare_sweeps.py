"""Baseline vs optimized sweep comparison (EXPERIMENTS.md §Optimized sweep).

  PYTHONPATH=src python -m benchmarks.compare_sweeps \
      results_singlepod.json results_singlepod_optimized.json
"""
import json
import sys


def main(argv):
    base = {(r["arch"], r["shape"]): r
            for r in json.load(open(argv[0])) if r.get("status") == "ok"}
    opt = {(r["arch"], r["shape"]): r
           for r in json.load(open(argv[1])) if r.get("status") == "ok"}
    print("| arch | shape | step before (ms) | step after (ms) | speedup | "
          "mem before/after (GiB) |")
    print("|---|---|---|---|---|---|")
    gains = []
    for k in sorted(base):
        if k not in opt:
            continue
        rb, ro_ = base[k]["roofline"], opt[k]["roofline"]
        tb = max(rb["t_compute"], rb["t_memory"], rb["t_collective"]) * 1e3
        ta = max(ro_["t_compute"], ro_["t_memory"], ro_["t_collective"]) * 1e3
        mb = base[k]["memory"]["peak_bytes_per_device"] / 2**30
        ma = opt[k]["memory"]["peak_bytes_per_device"] / 2**30
        gains.append(tb / ta)
        print(f"| {k[0]} | {k[1]} | {tb:.1f} | {ta:.1f} | {tb/ta:.2f}x | "
              f"{mb:.1f} / {ma:.1f} |")
    import math
    geo = math.exp(sum(math.log(g) for g in gains) / len(gains))
    print(f"\ngeomean step-time speedup across {len(gains)} cells: "
          f"{geo:.2f}x")


if __name__ == "__main__":
    main(sys.argv[1:])
