"""Generate the EXPERIMENTS.md roofline/dry-run tables from dryrun JSONs.

  PYTHONPATH=src python -m benchmarks.make_report \
      results_singlepod.json results_multipod.json > tables.md
"""
from __future__ import annotations

import json
import sys


def _gib(b):
    return b / 2 ** 30


def fmt_roofline_table(records):
    lines = [
        "| arch | shape | Tc (ms) | Tm (ms) | Tx (ms) | bottleneck | "
        "mem/dev (GiB) | MODEL_FLOPS/HLO | peak frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"SKIP (noted) | — | — | — |")
            continue
        if r.get("status") == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{ro['t_compute']*1e3:.1f} | {ro['t_memory']*1e3:.1f} | "
            f"{ro['t_collective']*1e3:.1f} | {ro['bottleneck']} | "
            f"{_gib(r['memory']['peak_bytes_per_device']):.1f} | "
            f"{ro['useful_ratio']:.2f} | {ro['peak_fraction']:.3f} |")
    return "\n".join(lines)


def fmt_dryrun_table(records):
    lines = [
        "| arch | shape | mesh | status | compile (s) | bytes/dev (GiB) | "
        "collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"{r.get('mesh','-')} | {r['status'].upper()} | — | "
                         f"— | {r.get('reason', r.get('error',''))[:60]} |")
            continue
        coll = r["roofline"]["collectives"].get("counts", {})
        cstr = " ".join(f"{k}:{v}" for k, v in sorted(coll.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r['compile_s']:.0f} | "
            f"{_gib(r['memory']['peak_bytes_per_device']):.1f} | {cstr} |")
    return "\n".join(lines)


def main(argv):
    for path in argv:
        with open(path) as f:
            records = json.load(f)
        n_ok = sum(r.get("status") == "ok" for r in records)
        n_skip = sum(r.get("status") == "skip" for r in records)
        n_err = sum(r.get("status") == "error" for r in records)
        print(f"\n## {path}: {n_ok} ok / {n_skip} skip / {n_err} error\n")
        print(fmt_dryrun_table(records))
        if "single" in path:
            print("\n### Roofline terms (single-pod 16x16)\n")
            print(fmt_roofline_table(records))


if __name__ == "__main__":
    main(sys.argv[1:])
