"""Benchmark runner — CI suite entrypoint + paper-figure modules.

CI suite mode (the single entrypoint the ``benchmark-smoke`` job runs):

  python benchmarks/run.py --smoke --diff-all

runs every gated benchmark (autotune, reorder, shard_scaling, sddmm,
attention, serving),
writes one ``BENCH_<name>.json`` each (a single combined artifact for CI),
diffs each against its committed ``benchmarks/BENCH_<name>.baseline.json``,
and exits nonzero if ANY diff fails.  Refresh a baseline with the
individual module's ``--out benchmarks/BENCH_<name>.baseline.json``.

Figure mode (``--figures [name,...]``, or legacy no flags = all): one
module per paper table/figure —

  bench_perf_model       — T_tot = T_e*n_e + T_init fit (Fig. 2 / SIII)
  bench_reorder          — reordering block-count effect (Figs. 3-4 / SVI-A)
  bench_suitesparse_like — SuiteSparse-pattern throughput (Fig. 8 / SVI-B)
  bench_band_sweep       — band sparsity sweep, dense crossover (Fig. 9)
  bench_n_scaling        — N scaling (Fig. 10 / SVI-D)
  bench_kernels          — Pallas kernel roofline table + dc2 study

Prints ``name,us_per_call,derived`` CSV.  These are slower, report-only
paper figures — CI runs the gated suite; ``tests/test_system.py`` keeps
the figure modules importable so they cannot silently rot.  Roofline
tables for the (arch x shape) cells come from ``repro.launch.dryrun``
(see its --out JSON + ``benchmarks/compare_sweeps.py`` for A/B tables).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

# gated CI benchmarks: (module name, baseline file)
SUITE = (
    ("bench_autotune", "BENCH_autotune.baseline.json"),
    ("bench_reorder", "BENCH_reorder.baseline.json"),
    ("bench_shard_scaling", "BENCH_shard_scaling.baseline.json"),
    ("bench_sddmm", "BENCH_sddmm.baseline.json"),
    ("bench_attention", "BENCH_attention.baseline.json"),
    ("bench_serving", "BENCH_serving.baseline.json"),
)

# report-only paper-figure modules (never gated; run via --figures)
FIGURES = ("bench_perf_model", "bench_reorder", "bench_suitesparse_like",
           "bench_band_sweep", "bench_n_scaling", "bench_kernels")


def run_suite(smoke: bool, diff_all: bool, out_dir: str = ".") -> int:
    import importlib

    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace

    rc = 0
    # the runner is an obs consumer: every suite module runs under a span
    # and the whole run exports a Perfetto trace next to the BENCH_*.json
    # artifacts (same glob, so CI uploads it for free)
    with obs_trace.capture() as cap:
        for mod_name, baseline_name in SUITE:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            short = mod_name.replace("bench_", "")
            print(f"# === {short} ===", file=sys.stderr)
            with obs_trace.span(f"bench.{short}", smoke=smoke):
                result = mod.run(smoke)
            out_path = os.path.join(out_dir, f"BENCH_{short}.json")
            with open(out_path, "w") as f:
                json.dump(result, f, indent=1, sort_keys=True)
            print(f"wrote {out_path}", file=sys.stderr)
            if diff_all:
                baseline_path = os.path.join(_HERE, baseline_name)
                with open(baseline_path) as f:
                    baseline = json.load(f)
                rc |= mod.diff(result, baseline)
    perfetto_path = os.path.join(out_dir, "BENCH_trace_perfetto.json")
    obs_export.write_perfetto(cap.events, perfetto_path)
    print(f"wrote {perfetto_path} ({len(cap.events)} events)",
          file=sys.stderr)
    print(obs_export.summary_tree(cap.events), file=sys.stderr)
    return rc


def run_figures(names=None) -> None:
    import importlib
    names = tuple(names or FIGURES)
    bad = [n for n in names if n not in FIGURES]
    if bad:  # validate up front — these modules run for minutes each
        raise SystemExit(f"unknown figure module(s) {bad}; "
                         f"pick from {FIGURES}")
    t0 = time.time()
    for name in names:
        print(f"# === {name} ===", file=sys.stderr)
        importlib.import_module(f"benchmarks.{name}").run()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="suite mode, small cases (the CI job)")
    ap.add_argument("--full", action="store_true",
                    help="suite mode, full-size cases")
    ap.add_argument("--diff-all", action="store_true",
                    help="diff every suite result against its committed "
                         "baseline; exit nonzero on any regression")
    ap.add_argument("--out-dir", default=".",
                    help="where suite mode writes BENCH_*.json")
    ap.add_argument("--figures", nargs="*", default=None,
                    help="run the (report-only) paper-figure modules; "
                         "optionally name a subset, e.g. "
                         "--figures bench_kernels")
    args = ap.parse_args()

    if args.figures is not None:
        run_figures(args.figures or None)
        return 0
    if args.smoke or args.full or args.diff_all:
        return run_suite(smoke=not args.full, diff_all=args.diff_all,
                         out_dir=args.out_dir)
    run_figures()
    return 0


if __name__ == "__main__":
    sys.exit(main())
