"""Benchmark runner — one module per paper table/figure.

  fig2   — perf model T_tot = T_e*n_e + T_init fit (paper Fig. 2 / SIII)
  fig3   — reordering block-count + load-balance effect (Figs. 3-4 / SVI-A)
  fig8   — SuiteSparse-pattern suite throughput (Fig. 8 / Table I / SVI-B)
  fig9   — band sparsity sweep, dense crossover (Fig. 9 / SVI-C)
  fig10  — N scaling (Fig. 10 / SVI-D)
  kernel — Pallas kernel roofline table + dc2 schedule study

Prints ``name,us_per_call,derived`` CSV.  Roofline tables for the 40
(arch x shape) cells come from ``repro.launch.dryrun`` (see EXPERIMENTS.md).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_band_sweep, bench_kernels,
                            bench_n_scaling, bench_perf_model,
                            bench_reorder, bench_suitesparse_like)
    t0 = time.time()
    for mod in (bench_perf_model, bench_reorder, bench_suitesparse_like,
                bench_band_sweep, bench_n_scaling, bench_kernels):
        name = mod.__name__.split(".")[-1]
        print(f"# === {name} ===", file=sys.stderr)
        mod.run()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
