"""Benchmark runner — CI suite entrypoint + paper-figure modules.

CI suite mode (the single entrypoint the ``benchmark-smoke`` job runs):

  python benchmarks/run.py --smoke --diff-all

runs every gated benchmark (autotune, reorder, shard_scaling), writes one
``BENCH_<name>.json`` each (a single combined artifact for CI), diffs each
against its committed ``benchmarks/BENCH_<name>.baseline.json``, and exits
nonzero if ANY diff fails.  Refresh a baseline with the individual
module's ``--out benchmarks/BENCH_<name>.baseline.json``.

Figure mode (legacy, no flags): one module per paper table/figure —

  fig2   — perf model T_tot = T_e*n_e + T_init fit (paper Fig. 2 / SIII)
  fig3   — reordering block-count + load-balance effect (Figs. 3-4 / SVI-A)
  fig8   — SuiteSparse-pattern suite throughput (Fig. 8 / Table I / SVI-B)
  fig9   — band sparsity sweep, dense crossover (Fig. 9 / SVI-C)
  fig10  — N scaling (Fig. 10 / SVI-D)
  kernel — Pallas kernel roofline table + dc2 schedule study

Prints ``name,us_per_call,derived`` CSV.  Roofline tables for the 40
(arch x shape) cells come from ``repro.launch.dryrun`` (see EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:  # runnable without a manual PYTHONPATH prefix
        sys.path.insert(0, _p)

# gated CI benchmarks: (module name, baseline file)
SUITE = (
    ("bench_autotune", "BENCH_autotune.baseline.json"),
    ("bench_reorder", "BENCH_reorder.baseline.json"),
    ("bench_shard_scaling", "BENCH_shard_scaling.baseline.json"),
)


def run_suite(smoke: bool, diff_all: bool, out_dir: str = ".") -> int:
    import importlib
    rc = 0
    for mod_name, baseline_name in SUITE:
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        short = mod_name.replace("bench_", "")
        print(f"# === {short} ===", file=sys.stderr)
        result = mod.run(smoke)
        out_path = os.path.join(out_dir, f"BENCH_{short}.json")
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {out_path}", file=sys.stderr)
        if diff_all:
            baseline_path = os.path.join(_HERE, baseline_name)
            with open(baseline_path) as f:
                baseline = json.load(f)
            rc |= mod.diff(result, baseline)
    return rc


def run_figures() -> None:
    from benchmarks import (bench_band_sweep, bench_kernels,
                            bench_n_scaling, bench_perf_model,
                            bench_reorder, bench_suitesparse_like)
    t0 = time.time()
    for mod in (bench_perf_model, bench_reorder, bench_suitesparse_like,
                bench_band_sweep, bench_n_scaling, bench_kernels):
        name = mod.__name__.split(".")[-1]
        print(f"# === {name} ===", file=sys.stderr)
        mod.run()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="suite mode, small cases (the CI job)")
    ap.add_argument("--full", action="store_true",
                    help="suite mode, full-size cases")
    ap.add_argument("--diff-all", action="store_true",
                    help="diff every suite result against its committed "
                         "baseline; exit nonzero on any regression")
    ap.add_argument("--out-dir", default=".",
                    help="where suite mode writes BENCH_*.json")
    args = ap.parse_args()

    if args.smoke or args.full or args.diff_all:
        return run_suite(smoke=not args.full, diff_all=args.diff_all,
                         out_dir=args.out_dir)
    run_figures()
    return 0


if __name__ == "__main__":
    sys.exit(main())
