"""Repo-root pytest conftest.

* Guarantees ``src`` is importable even when the ``pythonpath`` ini option
  is unavailable (defensive — pyproject.toml sets it too).
* Installs the deterministic ``hypothesis`` stub when the real package is
  missing (offline CI container), so property tests run instead of erroring
  at collection.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import hypothesis_stub
    hypothesis_stub.install(sys.modules)
