"""Quickstart: the SMaT SpMM library end-to-end.

CSR in -> Jaccard row reorder -> BCSR -> SpMM on the Pallas kernel
(interpret mode on CPU; the same call targets the TPU MXU), cross-checked
against dense.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import reorder, topology
from repro.kernels import ops

# 1. an unstructured sparse matrix in CSR (clustered structure, scattered)
csr = topology.blocked_random(n=1024, nnz_target=30_000, cluster=32, seed=0)
print(f"matrix: {csr.shape}, nnz={csr.nnz}, "
      f"sparsity={1 - csr.nnz / (csr.shape[0] * csr.shape[1]):.3%}")

# 2. block-densifying row permutation (the paper's preprocessing)
block = (16, 16)
before = bcsr_lib.from_scipy(csr, block)
perm = reorder.jaccard_rows(csr, block_w=block[1], tau=0.7)
after = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm), block)
print(f"BCSR blocks: {before.nnzb} -> {after.nnzb} "
      f"({before.nnzb / after.nnzb:.2f}x reduction), "
      f"padding {before.padding_ratio:.1%} -> {after.padding_ratio:.1%}")

# 3. SpMM through the kernel API (custom VJP: also differentiable)
arrays, meta = ops.prepare_sparse(after.ensure_nonempty_rows(),
                                  dtype=jnp.float32)
b = jnp.asarray(np.random.default_rng(1).standard_normal(
    (meta.n_block_cols * block[1], 64)).astype(np.float32))
y_pallas = ops.spmm(arrays, meta, b, backend="pallas", interpret=True)
y_dense = ops.spmm(arrays, meta, b, backend="dense")
err = float(jnp.max(jnp.abs(y_pallas - y_dense)))
print(f"pallas-vs-dense max err: {err:.2e}")
assert err < 1e-3

# 4. autotuned dispatch: the registry picks (variant, bn) from the matrix's
# structure fingerprint (cached; run Autotuner.tune for a measured sweep)
from repro.kernels import autotune
choice = autotune.get_autotuner().pick(meta, int(b.shape[1]))
print(f"autotune pick for {autotune.fingerprint(meta, int(b.shape[1])).key()}:"
      f" {choice.variant}/bn{choice.bn} ({choice.source})")
y_auto = ops.spmm(arrays, meta, b, backend="auto", interpret=True)
assert float(jnp.max(jnp.abs(y_auto - y_dense))) < 1e-3
print("OK")
