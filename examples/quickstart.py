"""Quickstart: the SMaT SpMM library end-to-end.

CSR in -> Jaccard row reorder (transparent: handled inside ops.prepare)
-> BCSR -> SpMM on the Pallas kernel (interpret mode on CPU; the same call
targets the TPU MXU), cross-checked against dense.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import topology
from repro.kernels import ops

# 1. an unstructured sparse matrix in CSR (clustered structure, scattered)
csr = topology.blocked_random(n=1024, nnz_target=30_000, cluster=32, seed=0)
print(f"matrix: {csr.shape}, nnz={csr.nnz}, "
      f"sparsity={1 - csr.nnz / (csr.shape[0] * csr.shape[1]):.3%}")

# 2. block-densifying row permutation (the paper's preprocessing) — one
# argument on ops.prepare (the unified entry point since PR 8;
# prepare_sparse / prepare_sparse_meta remain as aliases, and
# meta_only=True returns the static meta without device arrays).  The
# permutation is stored as pytree leaves (row_perm / inv_perm) and spmm
# returns ORIGINAL row order (C = P^T A' B), so nothing downstream has to
# know about it.  Schemes come from the repro.core.SCHEMES dispatch
# table: jaccard | rcm | shard_balance | identity.
block = (16, 16)
a = bcsr_lib.from_scipy(csr, block)
arrays, meta = ops.prepare(a, dtype=jnp.float32, reorder="jaccard")
arrays_id, meta_id = ops.prepare(a, dtype=jnp.float32)
print(f"BCSR blocks: {meta_id.nnzb} -> {meta.nnzb} "
      f"({meta_id.nnzb / meta.nnzb:.2f}x reduction from reorder="
      f"{meta.reorder!r})")

# 3. SpMM through the kernel API (custom VJP: also differentiable; the VJP
# carries the permutation through dB and dvals)
b = jnp.asarray(np.random.default_rng(1).standard_normal(
    (meta.shape[1], 64)).astype(np.float32))
y_pallas = ops.spmm(arrays, meta, b, backend="pallas", interpret=True)
y_dense = ops.spmm(arrays_id, meta_id, b, backend="dense")
err = float(jnp.max(jnp.abs(y_pallas - y_dense)))
print(f"reordered-pallas vs identity-dense max err: {err:.2e}")
assert err < 1e-3

# 4. autotuned dispatch: the registry picks (variant, bn) from the matrix's
# structure fingerprint — which includes the reorder scheme, so the permuted
# matrix (different bpr skew) never aliases the identity one's cached pick
from repro.kernels import autotune
fp = autotune.fingerprint(meta, int(b.shape[1]))
choice = autotune.get_autotuner().pick(meta, int(b.shape[1]))
print(f"autotune pick for {fp.key()}: "
      f"{choice.variant}/bn{choice.bn} ({choice.source})")
y_auto = ops.spmm(arrays, meta, b, backend="auto", interpret=True)
assert float(jnp.max(jnp.abs(y_auto - y_dense))) < 1e-3

# 5. sharded execution (launch.dist_spmm): partition the operand over
# block-rows with load-balanced LPT bins — each shard gets a static
# schedule and its own autotuned kernel pick, outputs gather back to
# ORIGINAL row order.  With >= 4 devices (e.g.
# XLA_FLAGS=--xla_force_host_platform_device_count=8) this runs as a real
# shard_map; on one device it falls back to the in-process equivalent.
import jax
from repro.launch import dist_spmm
n_shards = 4
sharr, smeta = dist_spmm.prepare(a, n_shards, dtype=jnp.float32)
mesh = (dist_spmm.make_spmm_mesh(n_shards)
        if jax.device_count() >= n_shards else None)
y_sharded = dist_spmm.spmm_sharded(sharr, smeta, b, backend="auto",
                                   interpret=True, mesh=mesh)
stats = dist_spmm.shard_balance_stats(a, n_shards)
print(f"sharded over {n_shards} {'devices' if mesh else 'slices (local)'}: "
      f"loads={stats['loads']} (imbalance {stats['imbalance']}x), "
      f"max err {float(jnp.max(jnp.abs(y_sharded - y_dense))):.2e}")
assert float(jnp.max(jnp.abs(y_sharded - y_dense))) < 1e-3

# 6. the MODEL path: the same partitioned execution as a layer spec
# (SparsitySpec(shards=...) -> init_sparse_linear -> apply_sparse_linear —
# what transformer FFN blocks, the serve engine, and launch.train trace).
# The layer's structure metadata is STATIC aux data, deterministic in
# (seed, dims, spec): sparse_linear_meta reproduces exactly the meta init
# returned, so every apply dispatches each shard on its REAL structure
# stats — heterogeneous per-shard picks, no params needed to plan them.
from repro.core.sparse_linear import (SparsitySpec, apply_sparse_linear,
                                      init_sparse_linear,
                                      sparse_linear_meta)
spec = SparsitySpec(density=0.2, block=(16, 16), backend="auto",
                    shards=n_shards, interpret=True)
params, lmeta = init_sparse_linear(0, 256, 512, spec, dtype=jnp.float32)
assert sparse_linear_meta(0, 256, 512, spec) == lmeta   # static re-derivation
x = jnp.asarray(np.random.default_rng(2).standard_normal(
    (2, 8, 256)).astype(np.float32))
with dist_spmm.use_spmm_mesh(mesh):                     # None -> local path
    y = apply_sparse_linear(params, lmeta, x, spec)
picks = ["{}/bn{}".format(*ops.resolve_backend("auto", spec.bn, m, 16))
         for m in lmeta.shard_metas]
print(f"model-path sharded layer: y {y.shape}, per-shard auto picks {picks}")

# 7. the SECOND workload: block-sparse ATTENTION.  The same kernel pair
# runs sparse interactions instead of sparse weights: scores = Q K^T
# sampled on a static BCSR mask (ops.sddmm — SpMM's dual, with its own
# custom VJP), masked block softmax, then probs @ V through ops.spmm.
# Masks are pure functions of (spec, seq_len, block), so the static-meta
# pipeline autotunes per mask structure (v6 op= fingerprints: sddmm,
# spmm, and attn picks can never alias for the same mask).  Since PR 6
# backend="auto" also arbitrates the WHOLE layer through the op=attn
# family: for this banded mask it resolves to the FUSED one-kernel path
# (single launch, scores/probs never materialized) — bit-for-bit equal
# to the composed triple in f32.
from repro.models import attention as A
rngq = np.random.default_rng(3)
q, k, v = (jnp.asarray(rngq.standard_normal((1, 128, 4, 16)), jnp.float32)
           for _ in range(3))
aspec = A.AttnSparsitySpec(mask=A.banded(48), block=(16, 16),
                           backend="auto", interpret=True)
out = A.block_sparse_attention(q, k, v, aspec)
mmeta = A.attention_mask_meta(aspec.mask, 128, aspec.block)
rep = A.attention_mask_report(aspec, 128)
out_composed = A.block_sparse_attention(
    q, k, v, A.AttnSparsitySpec(mask=aspec.mask, block=aspec.block,
                                backend="xla"))
assert bool(jnp.all(out == out_composed))     # fused == composed, bitwise
# oracle: dense attention under the same banded mask
pos = jnp.arange(128)
ok_mask = A.mask_allowed(aspec.mask, pos, pos)
s = jnp.einsum("blhd,bshd->bhls", q, k) * (16 ** -0.5)
p = jax.nn.softmax(jnp.where(ok_mask[None, None], s, A.NEG_INF), axis=-1)
want = jnp.einsum("bhls,bshd->blhd", p, v)
err = float(jnp.max(jnp.abs(out - want)))
print(f"block-sparse attention: mask nnzb={mmeta.nnzb} "
      f"({rep['block_density_vs_causal']:.0%} of dense-causal blocks), "
      f"impl={rep['attn_impl']} (pick {rep['attn_pick']}), "
      f"picks sddmm={rep['sddmm_pick']} spmm={rep['spmm_pick']}, "
      f"max err vs dense-masked {err:.2e}")
assert rep["attn_impl"] == "fused" and rep["attn_pick"] == "attn_fused"
assert err < 1e-4
print("OK")
