"""Serving example: batched decode with continuous slot batching on the
MusicGen-style codebook decoder (smoke scale).

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("musicgen-medium:smoke")
    params = T.init_params(cfg, seed=0)
    engine = ServeEngine(cfg, params, n_slots=2, cache_len=64)

    rng = np.random.default_rng(0)
    n_requests, new_tokens = 5, 8
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(6, cfg.n_codebooks), dtype=np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=new_tokens))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done.values())
    print(f"served {len(done)}/{n_requests} requests "
          f"({total} codebook-token steps) in {dt:.1f}s "
          f"with 2 decode slots")
    assert len(done) == n_requests
    print("OK")


if __name__ == "__main__":
    main()
