"""Serving example: continuous batching on the MusicGen-style codebook
decoder (smoke scale) through the PR 8 streaming API.

``engine.generate(requests)`` yields ``(rid, token)`` pairs as each slot
decodes — requests are admitted/evicted by the scheduler per step, so a
short request finishing frees its slot for the next queued one mid-run.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("musicgen-medium:smoke")
    params = T.init_params(cfg, seed=0)
    engine = ServeEngine(cfg, params, n_slots=2, cache_len=64)

    rng = np.random.default_rng(0)
    n_requests, new_tokens = 5, 8
    requests = [
        Request(rid=rid,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=(6, cfg.n_codebooks),
                                    dtype=np.int32),
                max_new_tokens=new_tokens)
        for rid in range(n_requests)
    ]

    t0 = time.time()
    streamed = {}
    for rid, token in engine.generate(requests):
        streamed.setdefault(rid, []).append(token)
    dt = time.time() - t0
    total = sum(len(toks) for toks in streamed.values())
    print(f"served {len(streamed)}/{n_requests} requests "
          f"({total} codebook-token steps) in {dt:.1f}s "
          f"with 2 decode slots")
    assert len(streamed) == n_requests
    assert all(len(t) == new_tokens for t in streamed.values())
    print("OK")


if __name__ == "__main__":
    main()
