"""End-to-end driver: train a ~100M-param LM whose FFN weights are
block-sparse (the paper's technique as a training feature) for a few hundred
steps, with checkpointing.

  PYTHONPATH=src python examples/train_sparse_lm.py --steps 150

~100M params: d_model=768, 12 layers, vocab 32000, FFN 3072 at 30%
block-density (block 32x32).  Loss should drop from ~10.4 to < 7 within
~100 steps on the synthetic n-gram stream.
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.sparse_linear import SparsitySpec
from repro.launch import mesh as mesh_lib
from repro.optim import adamw
from repro.train.loop import train

import logging
logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(message)s", datefmt="%H:%M:%S")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/smat_lm_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="smat-ffn-100m", family="dense", layout="attn_mlp",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=32000,
        ffn_sparsity=SparsitySpec(density=0.30, block=(32, 32),
                                  backend="xla"),
        dtype="float32",
    )
    print(f"params ~{cfg.param_count()/1e6:.0f}M "
          f"(sparse FFN at {cfg.ffn_sparsity.density:.0%} block-density)")
    shape = ShapeCell("train", "train", args.seq, args.batch)
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    warmup = min(20, max(args.steps // 5, 1))
    res = train(cfg, shape, mesh, total_steps=args.steps,
                opt_cfg=adamw.AdamWConfig(lr=6e-4, warmup_steps=warmup,
                                          total_steps=args.steps),
                ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"over {len(res.losses)} steps")
    assert res.losses[-1] < res.losses[0]
    print("OK")


if __name__ == "__main__":
    main()
