"""Public facade of the ``repro`` package.

The curated surface a user needs for the three workloads — sparse
kernels (``prepare``/``prepare_sparse`` -> ``spmm``/``sddmm``), model
integration (``SparsitySpec`` for sparse FFNs, ``AttnSparsitySpec`` for
block-sparse attention), serving (``ServeEngine``/``Request``), and
tuning (``Autotuner``) — importable as ``import repro`` instead of deep
module paths.  Everything else stays addressed by its submodule.

Exports resolve lazily (PEP 562 ``__getattr__``): importing ``repro``
stays free of jax/kernel import cost until a name is touched, and the
facade cannot create import cycles with the submodules it re-exports.
``analysis.lint_rules`` R6 gates that every ``__all__`` name resolves.

>>> import repro
>>> repro.SparsitySpec(density=0.25, block=(16, 16)).density
0.25
>>> callable(repro.prepare) and callable(repro.spmm)
True
"""
from __future__ import annotations

import importlib

__all__ = [
    "AttnSparsitySpec",
    "Autotuner",
    "Request",
    "ServeEngine",
    "SparsitySpec",
    "prepare",
    "prepare_sparse",
    "sddmm",
    "spmm",
]

_EXPORTS = {
    "AttnSparsitySpec": "repro.core.attention_mask",
    "Autotuner": "repro.kernels.autotune",
    "Request": "repro.serve.engine",
    "ServeEngine": "repro.serve.engine",
    "SparsitySpec": "repro.core.sparse_linear",
    "prepare": "repro.kernels.ops",
    "prepare_sparse": "repro.kernels.ops",
    "sddmm": "repro.kernels.ops",
    "spmm": "repro.kernels.ops",
}


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value        # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
