"""Static contract analyzer: ``python -m repro.analysis``.

Three passes prove the invariants the kernels and caches assume (see
docs/ARCHITECTURE.md "Static contracts"):

* ``verify_launch`` — schedule coverage / sentinel / bounds / VMEM
  checks per meta, plus the ``REPRO_VERIFY_LAUNCH=1`` pre-dispatch hook;
* ``lint_rules``   — AST rules over ``src/`` (traced-numpy reachability,
  lru_cache signatures, custom_vjp pairing, frozen static-aux
  dataclasses, fingerprint field coverage);
* ``fingerprint_audit`` — v6 key grammar: parse, injectivity,
  committed-artifact validation.

``workspace`` holds the shared VMEM/workspace byte estimators
(autotuner, attention benchmark, and verifier all delegate here).
"""
from repro.analysis.report import Finding, render
from repro.analysis import workspace

__all__ = ["Finding", "render", "workspace"]
