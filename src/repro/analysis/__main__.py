"""CLI for the static contract analyzer.

  python -m repro.analysis --all            # what CI gates on
  python -m repro.analysis --lint           # AST rules over src/ only
  python -m repro.analysis --verify-launch  # structure-zoo launch checks
  python -m repro.analysis --audit-fingerprints
  python -m repro.analysis --vmem-budget 4194304

Prints one ``path:line: [rule] message`` diagnostic per finding and
exits nonzero iff any pass found one.  No flags = ``--all``.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import workspace
from repro.analysis.report import render


def _repo_root() -> str:
    import repro
    # locate the installed package via __path__ (works for the facade
    # package since PR 8 just as it did for the old namespace package)
    pkg_dir = os.path.abspath(list(repro.__path__)[0])     # .../src/repro
    return os.path.dirname(os.path.dirname(pkg_dir))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract analyzer: launch verification, "
                    "repo-invariant lints, fingerprint audit")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass selected)")
    ap.add_argument("--lint", action="store_true",
                    help="AST repo-invariant rules over --src")
    ap.add_argument("--verify-launch", action="store_true",
                    help="schedule/grid/VMEM checks over the structure zoo")
    ap.add_argument("--audit-fingerprints", action="store_true",
                    help="v6 key grammar: injectivity + committed files")
    ap.add_argument("--vmem-budget", type=int,
                    default=workspace.DEFAULT_VMEM_BUDGET,
                    help="VMEM budget in bytes for the launch verifier "
                         f"(default {workspace.DEFAULT_VMEM_BUDGET})")
    ap.add_argument("--src", default=None,
                    help="source tree for --lint (default: the installed "
                         "repro package's parent src/)")
    args = ap.parse_args(argv)

    run_all = args.all or not (args.lint or args.verify_launch
                               or args.audit_fingerprints)
    findings = []
    if run_all or args.lint:
        from repro.analysis import lint_rules
        src = args.src if args.src else os.path.join(_repo_root(), "src")
        n0 = len(findings)
        findings += lint_rules.lint_tree(src)
        print(f"lint: {len(findings) - n0} finding(s) over {src}",
              file=sys.stderr)
    if run_all or args.verify_launch:
        from repro.analysis import verify_launch
        n0 = len(findings)
        findings += verify_launch.run_verify(vmem_budget=args.vmem_budget)
        print(f"verify-launch: {len(findings) - n0} finding(s) over the "
              "structure zoo", file=sys.stderr)
    if run_all or args.audit_fingerprints:
        from repro.analysis import fingerprint_audit
        n0 = len(findings)
        findings += fingerprint_audit.run_audit(_repo_root())
        print(f"fingerprint-audit: {len(findings) - n0} finding(s)",
              file=sys.stderr)

    if findings:
        print(render(findings))
        print(f"FAIL: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("OK: all static contracts hold", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
