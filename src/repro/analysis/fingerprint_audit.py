"""v7 fingerprint-grammar audit: parse, prove injectivity, validate files.

The autotune cache key is a flat string (``Fingerprint.key()``); nothing
at runtime ever parses it back, so a grammar bug — a field dropped from
the template, two fields that can collide textually, a stale cache from
an older grammar — would surface as silently-aliased picks, not an
error.  This pass closes that hole three ways:

* ``parse_key`` — a strict grammar for the v7 key; round-tripping
  ``parse_key(fp.key()) == fp`` proves the rendering is lossless.
  Keys from the retired v1-v6 grammars raise ``StaleKeyError`` with the
  refresh command instead of a generic parse failure.
* ``audit_injectivity`` — over ops x reorders x shard counts x a sampled
  structure space (plus every structure-zoo meta), distinct fingerprints
  must render to distinct keys and every key must round-trip.
* ``audit_files`` — every committed artifact that embeds keys (the
  ``BENCH_*.baseline.json`` fingerprints, any autotune cache JSON with
  the ``{"version": 1, "entries": {key: {variant, ...}}}`` shape) must
  parse under the current grammar, with each cached variant still
  registered; ``shard_entries`` keys (the shard-count axis —
  ``shards|max=<M>|<v7 key>``) must parse and carry a sane S.

>>> from repro.kernels import autotune
>>> fp = autotune._make_fingerprint(4, 4, (16, 16), 8, 25, 40, 512)
>>> parse_key(fp.key()) == fp
True
>>> parse_key("v5|op=spmm|nbr=4")  # doctest: +IGNORE_EXCEPTION_DETAIL
Traceback (most recent call last):
    ...
StaleKeyError: stale fingerprint grammar v5 (current: v7) in key ...
"""
from __future__ import annotations

import glob
import itertools
import json
import os
import re

from repro.analysis.report import Finding

_KEY_RE = re.compile(
    r"^v7\|op=(?P<op>[a-z_]+)\|nbr=(?P<nbr>\d+)\|nbc=(?P<nbc>\d+)"
    r"\|b=(?P<h>\d+)x(?P<w>\d+)\|nnzb=(?P<nnzb>\d+)\|pad=(?P<pad>\d+)"
    r"\|skew=(?P<skew>\d+)\|n=(?P<n>\d+)\|ro=(?P<ro>[A-Za-z0-9_]+)"
    r"\|ns=(?P<ns>\d+)\|mb=(?P<mb>\d+)\|nk=(?P<nk>\d+)$")

# shard-count cache entries: the mesh cap prefixed onto a full v7 key
_SHARD_KEY_RE = re.compile(r"^shards\|max=(?P<max>\d+)\|(?P<fp>v\d+\|.+)$")

_STALE_RE = re.compile(r"^v(\d+)\|")

_OPS = ("spmm", "sddmm", "attn")


class StaleKeyError(ValueError):
    """A key from a retired (v1-v6) fingerprint grammar."""


def parse_key(key: str):
    """Strict inverse of ``Fingerprint.key()`` — returns the Fingerprint
    or raises (``StaleKeyError`` for old grammar versions, ``ValueError``
    for anything else malformed)."""
    from repro.kernels import autotune
    m = _KEY_RE.match(key)
    if m is None:
        sv = _STALE_RE.match(key)
        if sv and int(sv.group(1)) < 7:
            raise StaleKeyError(
                f"stale fingerprint grammar v{sv.group(1)} (current: v7) "
                f"in key {key!r} — regenerate: delete the stale autotune "
                "cache (REPRO_AUTOTUNE_CACHE) or refresh the baseline "
                "with `python benchmarks/<bench>.py --smoke --out "
                "benchmarks/BENCH_<name>.baseline.json`")
        raise ValueError(f"key {key!r} does not match the v7 fingerprint "
                         "grammar")
    g = m.groupdict()
    return autotune.Fingerprint(
        n_block_rows=int(g["nbr"]), n_block_cols=int(g["nbc"]),
        block=(int(g["h"]), int(g["w"])), nnzb=int(g["nnzb"]),
        pad_bucket=int(g["pad"]), skew_bucket=int(g["skew"]),
        n_bucket=int(g["n"]), reorder=g["ro"], n_shards=int(g["ns"]),
        max_bpr=int(g["mb"]), op=g["op"], n_chunks=int(g["nk"]))


def parse_shard_key(key: str):
    """Strict inverse of ``autotune.shard_entry_key`` — returns
    ``(max_shards, Fingerprint)`` or raises like ``parse_key``."""
    m = _SHARD_KEY_RE.match(key)
    if m is None:
        raise ValueError(f"key {key!r} does not match the shard-entry "
                         "grammar shards|max=<M>|<fingerprint>")
    return int(m.group("max")), parse_key(m.group("fp"))


def sample_fingerprints():
    """Deterministic sample of the fingerprint space: every op family x
    reorder x shard count over a spread of structures, plus the realized
    metas of the launch verifier's structure zoo at two N widths."""
    from repro.kernels import autotune
    from repro.analysis import verify_launch
    fps = []
    for (op, reorder, ns, nk, block, nbr, nnzb, pad, skew, n) in \
            itertools.product(
                _OPS, ("identity", "jaccard"), (1, 4), (1, 4),
                ((16, 16), (32, 16)), (4, 16), (8, 64),
                (0, 35), (0, 120), (64, 512)):
        fps.append(autotune._make_fingerprint(
            nbr, nbr + 1, block, nnzb, pad, skew, n, reorder=reorder,
            n_shards=ns, max_bpr=max(1, nnzb // nbr), op=op, n_chunks=nk))
    for case in verify_launch.structure_zoo():
        metas = case.meta.shard_metas if hasattr(case.meta, "shard_metas") \
            else (case.meta,)
        for m in metas:
            for op in _OPS:
                for n in (64, 512):
                    fps.append(autotune.fingerprint(m, n, op=op))
    return fps


def audit_injectivity() -> list:
    """Prove no aliasing over the sampled space: distinct fingerprints
    -> distinct keys, and every key round-trips losslessly."""
    findings = []
    seen = {}
    for fp in sample_fingerprints():
        key = fp.key()
        try:
            back = parse_key(key)
        except ValueError as e:
            findings.append(Finding("fingerprint-audit", "key-grammar", 0,
                                    f"key {key!r} failed to parse: {e}"))
            continue
        if back != fp:
            findings.append(Finding(
                "fingerprint-audit", "key-grammar", 0,
                f"key {key!r} is lossy: parsed back to {back}, not {fp}"))
        prev = seen.setdefault(key, fp)
        if prev != fp:
            findings.append(Finding(
                "fingerprint-audit", "key-grammar", 0,
                f"ALIASING: distinct fingerprints {prev} and {fp} render "
                f"the same key {key!r}"))
    return findings


def _iter_fingerprint_strings(obj, ctx=""):
    """Yield (context, key-string) for every ``"fingerprint"`` value in a
    nested JSON object."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            if k == "fingerprint" and isinstance(v, str):
                yield ctx, v
            else:
                yield from _iter_fingerprint_strings(v, f"{ctx}/{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _iter_fingerprint_strings(v, f"{ctx}[{i}]")


def audit_files(root: str) -> list:
    """Validate committed artifacts under ``root``: benchmark baselines'
    embedded fingerprints, and any autotune-cache-format JSON."""
    from repro.kernels import autotune
    findings = []
    paths = sorted(glob.glob(os.path.join(root, "benchmarks",
                                          "BENCH_*.baseline.json")))
    cache = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if cache and os.path.exists(cache):
        paths.append(cache)
    for path in paths:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            findings.append(Finding("fingerprint-audit", path, 0,
                                    f"unreadable JSON: {e}"))
            continue
        for ctx, key in _iter_fingerprint_strings(data):
            try:
                parse_key(key)
            except ValueError as e:
                findings.append(Finding(
                    "fingerprint-audit", path, 0,
                    f"fingerprint at {ctx or '/'} invalid: {e}"))
        if isinstance(data, dict) and isinstance(data.get("entries"), dict):
            for key, entry in data["entries"].items():
                try:
                    parse_key(key)
                except ValueError as e:
                    findings.append(Finding("fingerprint-audit", path, 0,
                                            f"cache key invalid: {e}"))
                variant = (entry or {}).get("variant")
                if variant not in autotune._REGISTRY:
                    findings.append(Finding(
                        "fingerprint-audit", path, 0,
                        f"cached variant {variant!r} for {key!r} is not "
                        "in the current registry — stale cache"))
        if isinstance(data, dict) and \
                isinstance(data.get("shard_entries"), dict):
            for key, entry in data["shard_entries"].items():
                try:
                    parse_shard_key(key)
                except ValueError as e:
                    findings.append(Finding("fingerprint-audit", path, 0,
                                            f"shard-entry key invalid: {e}"))
                ns = (entry or {}).get("n_shards")
                if not isinstance(ns, int) or ns < 1:
                    findings.append(Finding(
                        "fingerprint-audit", path, 0,
                        f"shard entry {key!r} has invalid n_shards={ns!r}"))
    return findings


def run_audit(root: str) -> list:
    """The CLI pass: grammar injectivity + committed-artifact validation."""
    return audit_injectivity() + audit_files(root)
