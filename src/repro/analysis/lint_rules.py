"""AST-based repo-invariant lints over ``src/`` — the contracts that are
documented (docs/ARCHITECTURE.md "Static contracts") but were previously
enforced only by convention:

* **R1 traced-numpy** — no ``numpy`` call reachable (same-module call
  graph) from a traced body: a ``custom_vjp`` primal / registered
  fwd-bwd pair, or a Pallas kernel function.  Host numpy inside a traced
  body is at best a silent constant-fold, at worst a tracer leak.  Two
  sanctioned idioms are excluded: calls whose arguments reference
  ``float0`` (the zero-cotangent convention for integer residuals), and
  anything behind an ``lru_cache`` boundary (trace-safe host
  memoization — the cached value embeds as a constant).
* **R2 lru-cache-static** — ``lru_cache`` only on hashable-static
  signatures: no mutable-literal defaults, no parameter annotated with a
  known-unhashable type (list/dict/set/ndarray).
* **R3 custom-vjp-pairing** — every ``custom_vjp`` primal has a
  ``defvjp`` registration; fwd arity matches the primal; fwd returns a
  literal 2-tuple (out, residuals); bwd takes ``n_nondiff + 2`` args and
  returns one cotangent per differentiable primal arg (literal-tuple
  returns only; computed returns are skipped, not guessed).
* **R4 static-aux-frozen** — dataclasses that act as static aux /
  dispatch keys (names ending Meta/Spec/Config/Fingerprint/Choice/
  Variant/Cell) must be ``frozen=True`` with no unhashable field
  annotations, or they silently break jit caching and autotune keys.
* **R5 fingerprint-fields** — every dispatch-relevant ``SparseMeta``
  field appears in ``autotune.fingerprint``'s reads, and every
  ``Fingerprint`` field appears in ``key()``; a field missed by either
  is a cache-aliasing bug (two different structures, one autotune entry).
* **R6 package-facade** — every name in the package facade's literal
  ``__all__`` (``src/repro/__init__.py``) imports and resolves on the
  live package; a stale export would break every ``import repro``
  README snippet.
* **R7 obs-host-only** — no ``repro.obs`` call reachable (same-module
  call graph, same BFS as R1) from a traced body: observability is
  host-side, and an event emitted under jit either bakes its args in as
  compile-time constants or leaks tracers into the ring buffer.
  ``repro.obs.jaxmon`` is exempt (its wrappers are trace-time-safe by
  design — that is their whole job), as are the obs modules themselves.

``lint_source`` runs R1-R4 and R7 on one module; ``lint_tree`` runs
everything (R5 needs ops.py + autotune.py together; R6 runs when the
tree has a ``repro/__init__.py``) and is what the CLI gates CI on.

>>> fs = lint_source("import functools\\n"
...                  "@functools.lru_cache(maxsize=None)\\n"
...                  "def f(xs: list): return sum(xs)\\n", "x.py")
>>> [f.rule for f in fs]
['lru-cache-static']
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.report import Finding

RULES = ("traced-numpy", "lru-cache-static", "custom-vjp-pairing",
         "static-aux-frozen", "fingerprint-fields", "package-facade",
         "obs-host-only")

# dataclasses with these name suffixes are static aux: jit static args,
# scan carries' hashable halves, cache keys
_STATIC_AUX_RE = re.compile(
    r".*(Meta|Spec|Config|Fingerprint|Choice|Variant|Cell)$")

_UNHASHABLE_NAMES = {"list", "List", "dict", "Dict", "set", "Set",
                     "ndarray", "bytearray", "MutableMapping"}

# SparseMeta fields that are legitimately absent from the fingerprint:
# ``shape`` is determined by (n_block_rows, n_block_cols, block) up to
# ragging the N-bucket already captures; ``nnzb_t`` is derived transpose
# bookkeeping, not a dispatch dimension.
FINGERPRINT_FIELD_ALLOWLIST = frozenset({"shape", "nnzb_t"})


# ----------------------------------------------------------- AST utilities
def _dotted(node):
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _dec_name(dec):
    """Dotted name of a decorator, unwrapping a call: ``@x.y(...)`` -> x.y."""
    return _dotted(dec.func if isinstance(dec, ast.Call) else dec)


def _is_lru(func_def) -> bool:
    return any((_dec_name(d) or "").endswith("lru_cache")
               for d in func_def.decorator_list)


def _arity(func_def):
    """Positional arity, or None when *args makes it open-ended."""
    a = func_def.args
    if a.vararg is not None:
        return None
    return len(a.posonlyargs) + len(a.args)


def _all_args(func_def):
    a = func_def.args
    return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)


def _ann_unhashable(ann) -> bool:
    for node in ast.walk(ann):
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr
        if name in _UNHASHABLE_NAMES:
            return True
    return False


def _mentions_float0(call) -> bool:
    return any(isinstance(n, (ast.Name, ast.Attribute))
               and "float0" in (_dotted(n) or getattr(n, "attr", "") or "")
               for n in ast.walk(call))


class _Module:
    """One parsed module plus the indexes every rule needs."""

    def __init__(self, tree: ast.Module, path: str):
        self.tree = tree
        self.path = path
        self.funcs = {n.name: n for n in tree.body
                      if isinstance(n, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))}
        self.numpy_aliases = set()
        for n in ast.walk(tree):
            if isinstance(n, ast.Import):
                for alias in n.names:
                    if alias.name == "numpy":
                        self.numpy_aliases.add(alias.asname or "numpy")
        # name -> underlying function for ``g = functools.partial(f, ...)``
        self.partial_of = {}
        for n in tree.body:
            if (isinstance(n, ast.Assign) and len(n.targets) == 1
                    and isinstance(n.targets[0], ast.Name)
                    and isinstance(n.value, ast.Call)
                    and (_dotted(n.value.func) or "").endswith("partial")
                    and n.value.args
                    and isinstance(n.value.args[0], ast.Name)):
                self.partial_of[n.targets[0].id] = n.value.args[0].id

    # -- custom_vjp primals: {name: (nondiff_argnums, FunctionDef)}
    def custom_vjp_primals(self):
        out = {}
        for name, fd in self.funcs.items():
            for dec in fd.decorator_list:
                nondiff = None
                if (isinstance(dec, ast.Call)
                        and (_dotted(dec.func) or "").endswith("partial")
                        and dec.args
                        and (_dotted(dec.args[0]) or "").endswith(
                            "custom_vjp")):
                    nondiff = _literal_int_tuple(
                        _kw(dec, "nondiff_argnums")) or ()
                elif (_dec_name(dec) or "").endswith("custom_vjp"):
                    nondiff = (_literal_int_tuple(
                        _kw(dec, "nondiff_argnums")) or ()
                        if isinstance(dec, ast.Call) else ())
                if nondiff is not None:
                    out[name] = (nondiff, fd)
        return out

    # -- defvjp registrations: {primal: (fwd, bwd, lineno)}
    def defvjp_regs(self):
        out = {}
        for n in ast.walk(self.tree):
            if (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "defvjp"
                    and isinstance(n.func.value, ast.Name)
                    and len(n.args) >= 2):
                names = [a.id if isinstance(a, ast.Name) else None
                         for a in n.args[:2]]
                out[n.func.value.id] = (names[0], names[1], n.lineno)
        return out

    # -- pallas kernel bodies (first arg of pl.pallas_call)
    def pallas_kernels(self):
        out = set()
        for n in ast.walk(self.tree):
            if (isinstance(n, ast.Call)
                    and (_dotted(n.func) or "").endswith("pallas_call")
                    and n.args):
                k = n.args[0]
                if isinstance(k, ast.Call) and k.args and \
                        isinstance(k.args[0], ast.Name):
                    k = k.args[0]          # pallas_call(partial(kern, ...))
                if isinstance(k, ast.Name):
                    name = self.partial_of.get(k.id, k.id)
                    if name in self.funcs:
                        out.add(name)
        return out


def _kw(call: ast.Call, name: str):
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _literal_int_tuple(node):
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                vals.append(e.value)
            else:
                return None
        return tuple(vals)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return None


# ------------------------------------------------------------------- rules
def _rule_traced_numpy(mod: _Module) -> list:
    findings = []
    primals = mod.custom_vjp_primals()
    regs = mod.defvjp_regs()
    roots = set(primals) | mod.pallas_kernels()
    for primal, (fwd, bwd, _) in regs.items():
        roots |= {n for n in (fwd, bwd) if n}
    # BFS over the same-module call graph, lru_cache as the stop boundary
    seen, queue = set(), [r for r in roots if r in mod.funcs]
    while queue:
        fname = queue.pop()
        if fname in seen:
            continue
        seen.add(fname)
        fd = mod.funcs[fname]
        for node in ast.walk(fd):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee and callee.split(".")[0] in mod.numpy_aliases:
                if not _mentions_float0(node):
                    findings.append(Finding(
                        "traced-numpy", mod.path, node.lineno,
                        f"numpy call `{callee}` inside `{fname}`, which is "
                        "reachable from a traced body (custom_vjp / Pallas "
                        "kernel); use jnp, or move it behind an lru_cache "
                        "host-memoization boundary"))
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in mod.funcs:
                target = mod.funcs[node.func.id]
                if not _is_lru(target):
                    queue.append(node.func.id)
    return findings


def _obs_import_map(tree: ast.Module) -> dict:
    """Local name -> fully dotted ``repro.obs...`` origin, covering every
    binding form: ``import repro.obs.trace as t``, ``from repro import
    obs``, ``from repro.obs import trace as obs_trace``, and direct
    function imports (``from repro.obs.trace import span``).  A bare
    ``import repro.obs.trace`` binds ``repro`` and is caught by the
    raw-prefix check in the rule instead."""
    out = {}
    for n in ast.walk(tree):
        if isinstance(n, ast.Import):
            for alias in n.names:
                if alias.asname and (alias.name == "repro.obs"
                                     or alias.name.startswith("repro.obs.")):
                    out[alias.asname] = alias.name
        elif isinstance(n, ast.ImportFrom) and n.module and n.level == 0:
            for alias in n.names:
                full = f"{n.module}.{alias.name}"
                if full == "repro.obs" or full.startswith("repro.obs."):
                    out[alias.asname or alias.name] = full
    return out


def _rule_obs_host_only(mod: _Module) -> list:
    """R7: same reachability BFS as R1, flagging ``repro.obs`` calls.

    jaxmon is exempt (any resolved path with a ``jaxmon`` segment), and
    the obs package itself is skipped — its modules call each other."""
    if "repro/obs" in mod.path.replace(os.sep, "/"):
        return []
    obs_map = _obs_import_map(mod.tree)
    findings = []
    primals = mod.custom_vjp_primals()
    regs = mod.defvjp_regs()
    roots = set(primals) | mod.pallas_kernels()
    for primal, (fwd, bwd, _) in regs.items():
        roots |= {n for n in (fwd, bwd) if n}
    seen, queue = set(), [r for r in roots if r in mod.funcs]
    while queue:
        fname = queue.pop()
        if fname in seen:
            continue
        seen.add(fname)
        fd = mod.funcs[fname]
        for node in ast.walk(fd):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if not callee:
                continue
            head = callee.split(".")[0]
            if callee == "repro.obs" or callee.startswith("repro.obs."):
                resolved = callee
            elif head in obs_map:
                resolved = obs_map[head] + callee[len(head):]
            else:
                resolved = None
            if resolved is not None:
                if "jaxmon" not in resolved.split("."):
                    findings.append(Finding(
                        "obs-host-only", mod.path, node.lineno,
                        f"obs call `{callee}` inside `{fname}`, which is "
                        "reachable from a traced body (custom_vjp / Pallas "
                        "kernel); observability is host-side — emit the "
                        "event outside the traced region"))
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in mod.funcs:
                if not _is_lru(mod.funcs[node.func.id]):
                    queue.append(node.func.id)
    return findings


def _rule_lru_static(mod: _Module) -> list:
    findings = []
    for fname, fd in mod.funcs.items():
        if not _is_lru(fd):
            continue
        defaults = list(fd.args.defaults) + \
            [d for d in fd.args.kw_defaults if d is not None]
        for d in defaults:
            if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
                findings.append(Finding(
                    "lru-cache-static", mod.path, d.lineno,
                    f"`{fname}` is lru_cache'd but has a mutable literal "
                    "default — unhashable, and shared across calls"))
        for arg in _all_args(fd):
            if arg.annotation is not None and \
                    _ann_unhashable(arg.annotation):
                findings.append(Finding(
                    "lru-cache-static", mod.path, arg.annotation.lineno,
                    f"`{fname}` is lru_cache'd but parameter "
                    f"`{arg.arg}` is annotated with an unhashable type; "
                    "cache keys must be hashable statics"))
    return findings


def _rule_custom_vjp(mod: _Module) -> list:
    findings = []
    primals = mod.custom_vjp_primals()
    regs = mod.defvjp_regs()
    for name, (nondiff, fd) in primals.items():
        if name not in regs:
            findings.append(Finding(
                "custom-vjp-pairing", mod.path, fd.lineno,
                f"custom_vjp primal `{name}` has no `{name}.defvjp(fwd, "
                "bwd)` registration in this module"))
            continue
        fwd_name, bwd_name, reg_line = regs[name]
        n_params = _arity(fd)
        fwd = mod.funcs.get(fwd_name)
        bwd = mod.funcs.get(bwd_name)
        if fwd is not None and n_params is not None and \
                _arity(fwd) not in (None, n_params):
            findings.append(Finding(
                "custom-vjp-pairing", mod.path, fwd.lineno,
                f"fwd `{fwd_name}` takes {_arity(fwd)} args but primal "
                f"`{name}` takes {n_params} — fwd sees the primal "
                "signature exactly"))
        if fwd is not None:
            for ret in ast.walk(fwd):
                if isinstance(ret, ast.Return) and \
                        isinstance(ret.value, ast.Tuple) and \
                        len(ret.value.elts) != 2:
                    findings.append(Finding(
                        "custom-vjp-pairing", mod.path, ret.lineno,
                        f"fwd `{fwd_name}` must return a 2-tuple "
                        "(out, residuals), got a "
                        f"{len(ret.value.elts)}-tuple"))
        if bwd is not None:
            want_bwd = len(nondiff) + 2
            if _arity(bwd) not in (None, want_bwd):
                findings.append(Finding(
                    "custom-vjp-pairing", mod.path, bwd.lineno,
                    f"bwd `{bwd_name}` takes {_arity(bwd)} args, want "
                    f"{want_bwd} (nondiff args + residuals + cotangent)"))
            if n_params is not None:
                want_cots = n_params - len(nondiff)
                for ret in ast.walk(bwd):
                    if isinstance(ret, ast.Return) and \
                            isinstance(ret.value, ast.Tuple) and \
                            len(ret.value.elts) != want_cots:
                        findings.append(Finding(
                            "custom-vjp-pairing", mod.path, ret.lineno,
                            f"bwd `{bwd_name}` returns "
                            f"{len(ret.value.elts)} cotangents, want "
                            f"{want_cots} (one per differentiable primal "
                            "arg)"))
    return findings


def _rule_static_aux(mod: _Module) -> list:
    findings = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        dc_dec = None
        for dec in node.decorator_list:
            if (_dec_name(dec) or "").endswith("dataclass"):
                dc_dec = dec
        if dc_dec is None or not _STATIC_AUX_RE.match(node.name):
            continue
        frozen = (isinstance(dc_dec, ast.Call)
                  and any(k.arg == "frozen"
                          and isinstance(k.value, ast.Constant)
                          and k.value.value is True
                          for k in dc_dec.keywords))
        if not frozen:
            findings.append(Finding(
                "static-aux-frozen", mod.path, node.lineno,
                f"dataclass `{node.name}` names a static-aux role "
                "(*Meta/*Spec/*Config/...) but is not frozen=True — it "
                "must be hashable to serve as a jit static / cache key"))
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    _ann_unhashable(stmt.annotation):
                findings.append(Finding(
                    "static-aux-frozen", mod.path, stmt.lineno,
                    f"`{node.name}` field annotated with an unhashable "
                    "type; static-aux dataclasses must hash"))
    return findings


def check_fingerprint_fields(ops_src: str, autotune_src: str,
                             ops_path: str = "ops.py",
                             autotune_path: str = "autotune.py") -> list:
    """R5 (cross-file): every dispatch-relevant SparseMeta field is read
    by ``fingerprint``/``_make_fingerprint``, and every Fingerprint field
    is rendered by ``key()``."""
    findings = []
    ops_tree = ast.parse(ops_src)
    at_tree = ast.parse(autotune_src)

    def _class(tree, name):
        for n in ast.walk(tree):
            if isinstance(n, ast.ClassDef) and n.name == name:
                return n
        return None

    def _fields(cls):
        return [(s.target.id, s.lineno) for s in cls.body
                if isinstance(s, ast.AnnAssign)
                and isinstance(s.target, ast.Name)]

    def _attr_reads(fn, base):
        return {n.attr for n in ast.walk(fn)
                if isinstance(n, ast.Attribute)
                and isinstance(n.value, ast.Name) and n.value.id == base}

    sparse_meta = _class(ops_tree, "SparseMeta")
    fp_cls = _class(at_tree, "Fingerprint")
    if sparse_meta is None or fp_cls is None:
        return [Finding("fingerprint-fields", autotune_path, 0,
                        "could not locate SparseMeta/Fingerprint classes "
                        "to audit")]
    fp_fns = [n for n in ast.walk(at_tree)
              if isinstance(n, ast.FunctionDef)
              and n.name in ("fingerprint", "_make_fingerprint")]
    reads = set().union(*(_attr_reads(f, "meta") for f in fp_fns)) \
        if fp_fns else set()
    line = fp_fns[0].lineno if fp_fns else 0
    for fname, _ in _fields(sparse_meta):
        if fname not in FINGERPRINT_FIELD_ALLOWLIST and fname not in reads:
            findings.append(Finding(
                "fingerprint-fields", autotune_path, line,
                f"SparseMeta.{fname} is dispatch-relevant but never read "
                "by autotune.fingerprint — two metas differing only in it "
                "would alias one cache entry"))
    key_fn = next((n for n in fp_cls.body
                   if isinstance(n, ast.FunctionDef) and n.name == "key"),
                  None)
    if key_fn is None:
        findings.append(Finding("fingerprint-fields", autotune_path,
                                fp_cls.lineno,
                                "Fingerprint has no key() method"))
    else:
        key_reads = _attr_reads(key_fn, "self")
        for fname, fline in _fields(fp_cls):
            if fname not in key_reads:
                findings.append(Finding(
                    "fingerprint-fields", autotune_path, fline,
                    f"Fingerprint.{fname} is not rendered into key() — "
                    "distinct fingerprints would collide in the cache"))
    return findings


def check_package_facade(init_path: str, package: str = "repro") -> list:
    """R6: every name in the facade's ``__all__`` imports and resolves.

    The export list must be a LITERAL (``ast.literal_eval``-able) so the
    check cannot be fooled by a computed ``__all__``; resolution runs
    against the importable ``package`` on ``sys.path`` — for the CI gate
    that is the same tree being linted (``pythonpath = ["src"]``)."""
    with open(init_path) as f:
        tree = ast.parse(f.read())
    names = None
    line = 0
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                    line = node.lineno
                    try:
                        names = list(ast.literal_eval(node.value))
                    except ValueError:
                        return [Finding(
                            "package-facade", init_path, node.lineno,
                            "__all__ is not a literal list — the facade "
                            "check cannot verify computed exports")]
    if names is None:
        return [Finding("package-facade", init_path, 0,
                        "package facade has no __all__")]
    import importlib
    try:
        mod = importlib.import_module(package)
    except Exception as e:  # noqa: BLE001 — any import failure is the bug
        return [Finding("package-facade", init_path, line,
                        f"`import {package}` failed: {e!r}")]
    findings = []
    for name in names:
        try:
            getattr(mod, name)
        except Exception as e:  # noqa: BLE001
            findings.append(Finding(
                "package-facade", init_path, line,
                f"__all__ name {name!r} does not resolve on "
                f"`import {package}`: {e!r}"))
    return findings


# ------------------------------------------------------------- entrypoints
def lint_source(text: str, path: str = "<source>") -> list:
    """R1-R4 and R7 on one module's source text."""
    mod = _Module(ast.parse(text), path)
    return (_rule_traced_numpy(mod) + _rule_lru_static(mod)
            + _rule_custom_vjp(mod) + _rule_static_aux(mod)
            + _rule_obs_host_only(mod))


def lint_file(path: str) -> list:
    with open(path) as f:
        return lint_source(f.read(), path)


def lint_tree(src_root: str) -> list:
    """All rules over every ``.py`` under ``src_root`` (R5 runs when the
    tree contains kernels/ops.py + kernels/autotune.py; R6 when it has a
    repro/__init__.py facade)."""
    findings = []
    ops_path = autotune_path = None
    for dirpath, _, names in sorted(os.walk(src_root)):
        for name in sorted(names):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            findings += lint_file(path)
            if path.endswith(os.path.join("kernels", "ops.py")):
                ops_path = path
            if path.endswith(os.path.join("kernels", "autotune.py")):
                autotune_path = path
    if ops_path and autotune_path:
        with open(ops_path) as f:
            ops_src = f.read()
        with open(autotune_path) as f:
            at_src = f.read()
        findings += check_fingerprint_fields(ops_src, at_src,
                                             ops_path, autotune_path)
    init_path = os.path.join(src_root, "repro", "__init__.py")
    if os.path.exists(init_path):           # fixture trees have no facade
        findings += check_package_facade(init_path)
    return findings
