"""Finding: the one diagnostic currency every analyzer pass trades in.

Each pass (launch verifier, repo-invariant linter, fingerprint audit)
returns a flat ``list[Finding]``; the CLI prints them as classic
``path:line: [rule] message`` diagnostics and exits nonzero iff any
exist.  Keeping the type here — not in ``__init__`` — lets the pass
modules import it without touching package-init order.

>>> print(Finding(rule="traced-numpy", path="src/x.py", line=7,
...               message="numpy call reachable from a traced body"))
src/x.py:7: [traced-numpy] numpy call reachable from a traced body
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rule`` names the invariant, ``path``/``line``
    anchor it (line 0 = whole-file / non-source findings)."""
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def render(findings) -> str:
    """Stable, sorted rendering of a finding list (one per line).

    >>> render([Finding("r", "b.py", 2, "m"), Finding("r", "a.py", 1, "m")])
    'a.py:1: [r] m\\nb.py:2: [r] m'
    """
    return "\n".join(str(f) for f in
                     sorted(findings, key=lambda f: (f.path, f.line, f.rule)))
