"""Pre-launch static verification of schedules, grids, and VMEM budgets.

Everything that makes the TC-shaped kernels correct is decided BEFORE any
device array exists: the (block-row x slot) static schedule, its sentinel
padding convention, the grid derived from (meta, n, bn), and the VMEM
working set.  This pass re-derives each of those symbolically — pure
numpy on the host index structure — and checks the contracts the kernels
assume:

* **coverage** — every live nnzb slot appears in the schedule exactly
  once; sentinels (entry 0 for the spmm family, entry ``nnzb`` for
  sddmm/attn) appear ONLY on padding slots;
* **bounds** — every index the schedule can hand an index_map stays
  inside the derived grid / operand shapes;
* **shape** — block shapes divide the matrix dims or rag them by less
  than one block (``nbr == ceil(M/h)``, ``nbc == ceil(K/w)``);
* **VMEM** — the per-cell working set (``repro.analysis.workspace``, the
  same estimator the autotuner and the attention benchmark use) fits a
  configurable budget when double-buffered.

Entry points: ``verify_meta`` / ``verify_sharded_meta`` (invariants of a
meta alone), ``verify_schedule`` (a concrete schedule against its meta),
``assert_launch_ok`` (the opt-in ``REPRO_VERIFY_LAUNCH=1`` hook inside
``ops.resolve_backend``), ``verify_summary`` (the dict ``launch.dryrun``
embeds), and ``run_verify`` (the CLI pass over the structure zoo).

>>> import numpy as np
>>> from repro.core import bcsr as bcsr_lib
>>> from repro.kernels import ops
>>> a = bcsr_lib.random_bcsr_exact(0, (128, 128), (16, 16), 24)
>>> meta = ops.prepare_sparse_meta(a)
>>> verify_meta(meta)
[]
>>> fi, fc = sddmm_row_loop_schedule_host(a.row_ids, a.col_ids,
...                                       meta.n_block_rows, meta.max_bpr)
>>> verify_schedule("sddmm", fi, fc, a.row_ids, a.col_ids, meta)
[]
>>> bad = fi.copy(); bad[np.flatnonzero(fi != meta.nnzb)[0]] = meta.nnzb
>>> len(verify_schedule("sddmm", bad, fc, a.row_ids, a.col_ids, meta)) > 0
True
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.analysis import workspace
from repro.analysis.report import Finding

FAMILIES = ("spmm", "sddmm", "attn")


class LaunchError(ValueError):
    """A meta/schedule/budget contract is violated for the requested
    launch — raised by ``assert_launch_ok`` before any kernel dispatch."""


# ------------------------------------------------------- schedule mirrors
def spmm_row_loop_schedule_host(row_ids, col_ids, n_block_rows: int,
                                max_bpr: int):
    """Host-numpy twin of ``ops._row_loop_schedule`` (and of the host
    builder ``ops.make_row_loop_schedule``): per (block-row, slot) the
    entry index and block-col, padding slots pointing at entry 0 / col 0,
    plus the per-row live count ``row_len`` the kernel masks its loop
    with."""
    row_ids = np.asarray(row_ids, np.int64)
    col_ids = np.asarray(col_ids, np.int64)
    nnzb = row_ids.shape[0]
    row_len = np.bincount(row_ids, minlength=n_block_rows)
    rowptr = np.concatenate([[0], np.cumsum(row_len)])
    slot = np.arange(nnzb) - rowptr[row_ids]
    pos = row_ids * max_bpr + slot
    flat_idx = np.zeros(n_block_rows * max_bpr, np.int32)
    flat_col = np.zeros(n_block_rows * max_bpr, np.int32)
    flat_idx[pos] = np.arange(nnzb, dtype=np.int32)
    flat_col[pos] = col_ids
    return flat_idx, flat_col, row_len.astype(np.int32)


def sddmm_row_loop_schedule_host(row_ids, col_ids, n_block_rows: int,
                                 max_bpr: int):
    """Host-numpy twin of ``ops._sddmm_row_loop_schedule`` AND of the
    fused-attention schedule (``models.attention._fused_inputs`` builds
    the identical arrays): padding slots point at the sentinel entry
    ``nnzb`` instead of entry 0."""
    row_ids = np.asarray(row_ids, np.int64)
    col_ids = np.asarray(col_ids, np.int64)
    nnzb = row_ids.shape[0]
    row_len = np.bincount(row_ids, minlength=n_block_rows)
    rowptr = np.concatenate([[0], np.cumsum(row_len)])
    slot = np.arange(nnzb) - rowptr[row_ids]
    pos = row_ids * max_bpr + slot
    flat_idx = np.full(n_block_rows * max_bpr, nnzb, np.int32)
    flat_col = np.zeros(n_block_rows * max_bpr, np.int32)
    flat_idx[pos] = np.arange(nnzb, dtype=np.int32)
    flat_col[pos] = col_ids
    return flat_idx, flat_col


def build_schedule(family: str, row_ids, col_ids, meta):
    """(flat_idx, flat_col, row_len|None) for ``family`` from the sorted
    entry list — the schedule the kernels would actually launch with."""
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; want one of {FAMILIES}")
    if family == "spmm":
        return spmm_row_loop_schedule_host(
            row_ids, col_ids, meta.n_block_rows, meta.max_bpr)
    fi, fc = sddmm_row_loop_schedule_host(
        row_ids, col_ids, meta.n_block_rows, meta.max_bpr)
    return fi, fc, None


# ------------------------------------------------------- meta invariants
def verify_meta(meta) -> list:
    """Structural invariants of one ``SparseMeta`` — no arrays involved.

    Dims-only specs metas (``max_bpr == 0``) are legal: they carry shape
    budgets, not a realized structure, and the row_loop family refuses
    them separately.  Shard-local metas (``n_shards > 1``) may contain
    duplicate (row, col) slots (padding), so the distinct-entries bound
    ``nnzb <= nbr * nbc`` applies only to whole-matrix metas."""
    errs = []
    h, w = meta.block
    M, K = meta.shape
    nbr, nbc = meta.n_block_rows, meta.n_block_cols
    if h <= 0 or w <= 0:
        errs.append(f"block {meta.block} must be positive")
        return errs
    if M <= 0 or K <= 0:
        errs.append(f"shape {meta.shape} must be positive")
        return errs
    if nbr != -(-M // h):
        errs.append(f"n_block_rows={nbr} != ceil({M}/{h})={-(-M // h)} "
                    "(block must divide or rag M by < one block)")
    if nbc != -(-K // w):
        errs.append(f"n_block_cols={nbc} != ceil({K}/{w})={-(-K // w)}")
    if meta.nnzb < 0:
        errs.append(f"nnzb={meta.nnzb} < 0")
    if meta.n_shards == 1 and meta.nnzb > nbr * nbc:
        errs.append(f"nnzb={meta.nnzb} exceeds the {nbr}x{nbc} distinct "
                    "block capacity of a whole-matrix meta")
    if not (meta.nnzb <= meta.nnzb_t <= meta.nnzb + nbc):
        errs.append(f"nnzb_t={meta.nnzb_t} outside [nnzb, nnzb + nbc] = "
                    f"[{meta.nnzb}, {meta.nnzb + nbc}] (transpose structure "
                    "adds at most one sentinel per t-block-row)")
    if meta.max_bpr < 0:
        errs.append(f"max_bpr={meta.max_bpr} < 0")
    elif meta.n_shards == 1 and meta.max_bpr > nbc:
        # shard-local metas (n_shards > 1) may exceed nbc: padding slots
        # duplicate (row 0, col 0) and count toward the schedule bound
        errs.append(f"max_bpr={meta.max_bpr} outside [0, n_block_cols={nbc}]")
    if meta.max_bpr > 0:
        if meta.nnzb > nbr * meta.max_bpr:
            errs.append(
                f"schedule capacity violated: nnzb={meta.nnzb} > "
                f"n_block_rows*max_bpr={nbr * meta.max_bpr} — some entry "
                "has no (row, slot) to live in")
        if meta.max_bpr > meta.nnzb:
            errs.append(f"max_bpr={meta.max_bpr} > nnzb={meta.nnzb}")
        if meta.n_shards == 1 and meta.nnzb < nbr:
            errs.append(
                f"nnzb={meta.nnzb} < n_block_rows={nbr} with max_bpr > 0 — "
                "prepared metas pad every block-row nonempty")
    if not (0 <= meta.padding_ratio_pct <= 100):
        errs.append(f"padding_ratio_pct={meta.padding_ratio_pct} not a pct")
    if meta.bpr_cv_pct < 0:
        errs.append(f"bpr_cv_pct={meta.bpr_cv_pct} < 0")
    if meta.n_shards < 1:
        errs.append(f"n_shards={meta.n_shards} < 1")
    return errs


def verify_sharded_meta(smeta) -> list:
    """Invariants of a ``ShardedMeta``: global bookkeeping plus every
    per-shard ``SparseMeta`` (checked via ``verify_meta``)."""
    errs = []
    h, w = smeta.block
    M, K = smeta.shape
    nbr = -(-M // h)
    if smeta.n_shards < 1 or smeta.col_shards < 1:
        errs.append(f"n_shards={smeta.n_shards}, col_shards="
                    f"{smeta.col_shards} must be >= 1")
        return errs
    if len(smeta.shard_metas) != smeta.n_shards:
        errs.append(f"{len(smeta.shard_metas)} shard_metas != n_shards="
                    f"{smeta.n_shards}")
        return errs
    if smeta.rows_per_shard * smeta.n_shards < nbr:
        errs.append(f"rows_per_shard={smeta.rows_per_shard} x n_shards="
                    f"{smeta.n_shards} cannot hold {nbr} block-rows")
    if smeta.nnzb_t_per_shard != smeta.nnzb_per_shard + -(-K // w):
        errs.append(f"nnzb_t_per_shard={smeta.nnzb_t_per_shard} != "
                    f"nnzb_per_shard + n_block_cols (shape-deterministic "
                    "t-structure contract)")
    for s, m in enumerate(smeta.shard_metas):
        sub = verify_meta(m)
        errs += [f"shard {s}: {e}" for e in sub]
        if m.shape != (smeta.rows_per_shard * h, K):
            errs.append(f"shard {s}: shape {m.shape} != "
                        f"{(smeta.rows_per_shard * h, K)}")
        if m.nnzb != smeta.nnzb_per_shard:
            errs.append(f"shard {s}: nnzb={m.nnzb} != nnzb_per_shard="
                        f"{smeta.nnzb_per_shard}")
        if m.block != smeta.block:
            errs.append(f"shard {s}: block {m.block} != {smeta.block}")
        if m.n_shards != smeta.n_shards:
            errs.append(f"shard {s}: n_shards={m.n_shards} != "
                        f"{smeta.n_shards}")
    return errs


# ---------------------------------------------------- schedule verification
def verify_schedule(family: str, flat_idx, flat_col, row_ids, col_ids,
                    meta, row_len=None) -> list:
    """Check one realized (block-row x slot) schedule against its meta.

    ``family`` fixes the sentinel convention: ``"spmm"`` pads with entry 0
    and needs ``row_len`` (the kernel's loop mask); ``"sddmm"``/``"attn"``
    pad with the sentinel entry index ``nnzb``.  Returns a list of error
    strings — empty means the schedule covers every live slot exactly
    once, sentinels sit only on padding, every index is in bounds, and
    the (row, col) bookkeeping is self-consistent."""
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; want one of {FAMILIES}")
    errs = []
    flat_idx = np.asarray(flat_idx, np.int64)
    flat_col = np.asarray(flat_col, np.int64)
    row_ids = np.asarray(row_ids, np.int64)
    col_ids = np.asarray(col_ids, np.int64)
    nnzb, nbr, nbc = meta.nnzb, meta.n_block_rows, meta.n_block_cols
    max_bpr = meta.max_bpr
    if max_bpr <= 0:
        return [f"{family}: meta.max_bpr={max_bpr} — no static schedule "
                "exists for a dims-only meta"]
    want_len = nbr * max_bpr
    if flat_idx.shape[0] != want_len or flat_col.shape[0] != want_len:
        return [f"{family}: schedule length {flat_idx.shape[0]} != "
                f"n_block_rows*max_bpr={want_len}"]
    if row_ids.shape[0] != nnzb:
        return [f"{family}: entry list length {row_ids.shape[0]} != "
                f"meta.nnzb={nnzb}"]
    if row_ids.size and (row_ids.min() < 0 or row_ids.max() >= nbr):
        errs.append(f"{family}: entry row_ids outside [0, {nbr})")
    if col_ids.size and (col_ids.min() < 0 or col_ids.max() >= nbc):
        errs.append(f"{family}: entry col_ids outside [0, {nbc})")
    if np.any(np.diff(row_ids) < 0):
        errs.append(f"{family}: entry list not sorted row-major "
                    "(row_ids must be nondecreasing)")
    if errs:
        return errs

    counts = np.bincount(row_ids, minlength=nbr)
    if counts.max(initial=0) > max_bpr:
        return [f"{family}: a block-row holds {int(counts.max())} entries "
                f"> max_bpr={max_bpr} — schedule cannot represent it"]
    slots = np.arange(want_len) % max_bpr
    seg_row = np.arange(want_len) // max_bpr
    if family == "spmm":
        if row_len is None:
            return [f"{family}: row_len is required (the kernel's loop "
                    "bound) for the spmm family"]
        row_len = np.asarray(row_len, np.int64)
        if row_len.shape[0] != nbr:
            return [f"{family}: row_len length {row_len.shape[0]} != "
                    f"n_block_rows={nbr}"]
        if not np.array_equal(row_len, counts):
            bad = int(np.flatnonzero(row_len != counts)[0])
            errs.append(
                f"{family}: row_len[{bad}]={int(row_len[bad])} != true "
                f"entry count {int(counts[bad])} — the loop mask drops or "
                "double-visits slots")
        live = slots < row_len[seg_row]
        # in-bounds: every slot (live or padding) indexes a real entry
        if flat_idx.min() < 0 or flat_idx.max() >= max(nnzb, 1):
            errs.append(f"{family}: flat_idx outside [0, nnzb={nnzb}) — "
                        "spmm padding must reuse entry 0, not a sentinel")
        pad_bad = np.flatnonzero(~live & ((flat_idx != 0) | (flat_col != 0)))
        if pad_bad.size:
            errs.append(f"{family}: {pad_bad.size} padding slot(s) (first "
                        f"at {int(pad_bad[0])}) not pointing at entry 0 / "
                        "col 0")
    else:
        live = flat_idx != nnzb
        if flat_idx.min() < 0 or flat_idx.max() > nnzb:
            errs.append(f"{family}: flat_idx outside [0, nnzb={nnzb}] "
                        "(sentinel row is index nnzb)")
        live_counts = np.bincount(seg_row[live], minlength=nbr)
        if not np.array_equal(live_counts, counts):
            bad = int(np.flatnonzero(live_counts != counts)[0])
            errs.append(
                f"{family}: block-row {bad} schedules "
                f"{int(live_counts[bad])} live slot(s) but owns "
                f"{int(counts[bad])} entries — sentinel on a live block "
                "or a dropped slot")
        pad_bad = np.flatnonzero(~live & (flat_col != 0))
        if pad_bad.size:
            errs.append(f"{family}: {pad_bad.size} sentinel slot(s) with "
                        "nonzero flat_col (must DMA block-col 0)")
    if errs:
        return errs

    live_idx = flat_idx[live]
    if not np.array_equal(np.sort(live_idx), np.arange(nnzb)):
        missing = np.setdiff1d(np.arange(nnzb), live_idx)
        dupes = live_idx.size - np.unique(live_idx).size
        errs.append(
            f"{family}: live slots are not a permutation of the {nnzb} "
            f"entries ({missing.size} dropped, {dupes} duplicated) — "
            "coverage contract violated")
        return errs
    if not np.array_equal(row_ids[live_idx], seg_row[live]):
        errs.append(f"{family}: a live slot's entry belongs to a different "
                    "block-row than its schedule segment")
    if not np.array_equal(flat_col[live], col_ids[live_idx]):
        errs.append(f"{family}: flat_col disagrees with the entry list's "
                    "col_ids — the kernel would DMA the wrong B/K panel")
    if flat_col.min() < 0 or flat_col.max() >= nbc:
        errs.append(f"{family}: flat_col outside [0, n_block_cols={nbc})")
    return errs


# ------------------------------------------------------ grid + VMEM checks
def derive_grid(meta, family: str, n: int, bn: int = 512):
    """The Pallas grid the row_loop/fused kernels launch with — the bound
    every schedule index must stay inside."""
    from repro.kernels import ops
    bn_eff = ops._clamp_bn(bn, n)
    n_tiles = -(-n // bn_eff)
    nbr, max_bpr = meta.n_block_rows, meta.max_bpr
    if family == "spmm":
        return (nbr, n_tiles, max_bpr)
    if family == "sddmm":
        return (nbr, max_bpr, n_tiles)
    if family == "attn":
        return (1, nbr, 3, max_bpr)
    raise ValueError(f"unknown family {family!r}")


def estimate_vmem_bytes(meta, family: str, n: int, bn: int = 512) -> int:
    """Double-buffered working-set estimate for one grid cell, from the
    shared ``repro.analysis.workspace`` formulas (``n`` is N for the
    spmm/sddmm families, head_dim for attn)."""
    from repro.kernels import ops
    if family == "attn":
        h, w = meta.block
        return (workspace.attn_fused_state_bytes(meta.block, n)
                + workspace.spmm_cell_bytes(meta.block, ops._clamp_bn(bn, n)))
    return workspace.spmm_cell_bytes(meta.block, ops._clamp_bn(bn, n)) * 2


def _family_for(backend: str, op: str) -> Optional[str]:
    """Which static-schedule family (if any) a resolved backend launches.
    ``None`` = no row_loop-style schedule (nnz_stream / xla / dense)."""
    if op == "attn":
        return "attn" if backend in ("fused", "row_loop") else None
    if backend == "row_loop":
        return op if op in ("spmm", "sddmm") else "spmm"
    return None


def verify_launch(meta, backend: str, *, n: int, bn: int = 512,
                  op: str = "spmm",
                  vmem_budget: int = workspace.DEFAULT_VMEM_BUDGET) -> list:
    """All static checks for one resolved (meta, backend, n, bn, op)
    launch: meta invariants, schedule feasibility for the backend's
    family, and the VMEM budget.  Returns error strings (empty = ok)."""
    errs = list(verify_meta(meta))
    family = _family_for(backend, op)
    if family is not None and meta.max_bpr <= 0:
        errs.append(f"backend {backend!r} (family {family}) needs "
                    "meta.max_bpr > 0; this is a dims-only meta")
    if family is not None and meta.max_bpr > 0:
        sched_len = meta.n_block_rows * meta.max_bpr
        if sched_len < meta.nnzb:
            errs.append(f"schedule length {sched_len} cannot cover "
                        f"nnzb={meta.nnzb}")
        grid = derive_grid(meta, family, n, bn)
        if any(g <= 0 for g in grid):
            errs.append(f"degenerate grid {grid} for family {family}")
    if backend in ("pallas", "row_loop", "fused"):
        need = estimate_vmem_bytes(meta, family if family else "spmm", n, bn)
        if need > vmem_budget:
            errs.append(
                f"estimated VMEM working set {need} B exceeds the budget "
                f"{vmem_budget} B for block={meta.block}, bn={bn}, n={n} — "
                "shrink bn or the block")
    return errs


def assert_launch_ok(meta, backend: str, *, n: int, bn: int = 512,
                     op: str = "spmm",
                     vmem_budget: int = workspace.DEFAULT_VMEM_BUDGET):
    """Raise ``LaunchError`` if the resolved launch violates any static
    contract — the ``REPRO_VERIFY_LAUNCH=1`` hook in
    ``ops.resolve_backend``."""
    errs = verify_launch(meta, backend, n=n, bn=bn, op=op,
                         vmem_budget=vmem_budget)
    if errs:
        raise LaunchError(
            f"pre-launch verification failed for backend={backend!r}, "
            f"op={op!r}, n={n}, bn={bn}:\n  - " + "\n  - ".join(errs))


def verify_chunk_schedule(bounds, n: int, *, block=None, bn: int = 512,
                          vmem_budget: int =
                          workspace.DEFAULT_VMEM_BUDGET) -> list:
    """Invariants of an overlap chunk schedule (``dist_spmm
    .chunk_schedule``): the chunks must partition ``[0, n)`` EXACTLY —
    contiguous, strictly ascending, non-empty, no gaps or overlaps — or
    the pipelined concat is not bit-identical to the single-shot panel
    (dropped/duplicated columns).  With ``block`` given, each chunk's
    double-buffered working set must also fit the VMEM budget (chunk
    widths never exceed the full panel, so this catches only schedules
    someone hand-built wrong).  Returns error strings (empty = ok)."""
    errs = []
    try:
        bounds = [(int(lo), int(hi)) for lo, hi in bounds]
    except (TypeError, ValueError):
        return [f"chunk schedule {bounds!r} is not a list of (start, stop)"]
    if not bounds:
        return [f"chunk schedule empty for panel width n={n}"]
    if bounds[0][0] != 0:
        errs.append(f"first chunk starts at {bounds[0][0]}, not 0")
    if bounds[-1][1] != n:
        errs.append(f"last chunk stops at {bounds[-1][1]}, not n={n} — "
                    "the schedule does not cover the panel")
    for i, (lo, hi) in enumerate(bounds):
        if hi <= lo:
            errs.append(f"chunk {i} ({lo}, {hi}) is empty or descending")
    for i in range(1, len(bounds)):
        prev_hi, lo = bounds[i - 1][1], bounds[i][0]
        if lo != prev_hi:
            errs.append(
                f"chunk {i} starts at {lo} but chunk {i - 1} stopped at "
                f"{prev_hi} — {'overlap (columns accumulated twice)' if lo < prev_hi else 'gap (columns dropped)'}")
    if block is not None and not errs:
        from repro.kernels import ops
        for i, (lo, hi) in enumerate(bounds):
            need = workspace.spmm_cell_bytes(
                tuple(block), ops._clamp_bn(bn, hi - lo)) * 2
            if need > vmem_budget:
                errs.append(
                    f"chunk {i} width {hi - lo}: working set {need} B "
                    f"exceeds the VMEM budget {vmem_budget} B")
    return errs


def verify_page_table(mask, seq_len: int, block,
                      resident_pages=None) -> list:
    """Paged-KV page-table invariants (PR 8): the table
    (``models.attention.decode_page_table``) must cover EXACTLY the mask
    support — every stored block-column of the mask BCSR appears exactly
    once among the row's live slots, in ascending order (the
    sequential-fold bitwise contract), with dead slots only in the tail
    — and the placement (``serve.paged_kv.page_placement``) must respect
    the device page budget.  Returns human-readable error strings."""
    from repro.models import attention as A
    from repro.serve import paged_kv as PK
    pages, live, meta = A.decode_page_table(mask, seq_len, block)
    a = A.attention_mask_bcsr(mask, seq_len, block)
    errs = []
    nbr, nbc = meta.n_block_rows, meta.n_block_cols
    if pages.shape != live.shape or \
            pages.shape != (nbr, max(meta.max_bpr, 1)):
        errs.append(f"page-table shape {pages.shape} != "
                    f"({nbr}, {max(meta.max_bpr, 1)})")
        return errs
    if pages.size and (pages.min() < 0 or pages.max() >= nbc):
        errs.append(f"page id out of range [0, {nbc})")
    for i in range(nbr):
        want = np.sort(a.col_ids[a.row_ids == i]).tolist()
        got = pages[i][live[i]].tolist()
        if got != want:
            errs.append(f"row {i}: live pages {got} != mask support {want}"
                        " (coverage must be exact — no gaps, no extras)")
        count = int(live[i].sum())
        if live[i][:count].sum() != count:
            errs.append(f"row {i}: dead slots not a tail suffix")
    pspec = PK.PagePlacementSpec(resident_pages=resident_pages)
    resident = PK.page_placement(mask, seq_len, block, pspec)
    budget = nbc if resident_pages is None else \
        max(0, min(nbc, int(resident_pages)))
    if resident.size != nbc:
        errs.append(f"placement size {resident.size} != n_pages {nbc}")
    if int(resident.sum()) > budget:
        errs.append(f"resident-budget overflow: {int(resident.sum())} "
                    f"pages resident > budget {budget}")
    return errs


def verify_summary(meta, n: int, op: str = "spmm") -> dict:
    """Compact dict for ``launch.dryrun`` reports: meta invariants (and,
    for sharded metas, per-shard checks) re-proved at report time."""
    if hasattr(meta, "shard_metas"):
        errs = verify_sharded_meta(meta)
        checked = f"sharded_meta[{meta.n_shards}]"
    else:
        errs = verify_meta(meta)
        checked = "meta"
    return {"ok": not errs, "checked": checked, "op": op, "n": n,
            "errors": list(errs)}


# ------------------------------------------------------------ structure zoo
@dataclasses.dataclass
class ZooCase:
    """One realized structure: the meta plus the sorted host entry list
    the schedules are built from, and which families apply to it."""
    name: str
    meta: object
    row_ids: np.ndarray
    col_ids: np.ndarray
    families: tuple


def structure_zoo():
    """The metas the acceptance gate runs the verifier over: every
    producer in the repo — ``prepare_sparse_meta`` on random/ragged/
    reordered structures, ``attention_mask_meta`` for each mask family,
    the sharded path, and the deterministic sparse-linear weight
    patterns.  Yields ``ZooCase``s (host numpy only — cheap)."""
    from repro.core import bcsr as bcsr_lib
    from repro.core import sparse_linear as SL
    from repro.core.attention_mask import banded, blockwise_causal, local_global
    from repro.kernels import ops
    from repro.launch import dist_spmm

    def prepared(name, a, families=("spmm", "sddmm"), **kw):
        host, meta = ops._prepare_sparse_host(
            a, reorder=kw.pop("reorder", "identity"),
            reorder_granularity=kw.pop("granularity", "element"),
            tau=0.7, max_candidates=None, n_shards=kw.pop("n_shards", 1))
        return ZooCase(name, meta, host["row_ids"], host["col_ids"],
                       tuple(families))

    yield prepared("rand_uniform_256",
                   bcsr_lib.random_bcsr_exact(0, (256, 256), (16, 16), 64))
    yield prepared("rand_ragged_250x200",
                   bcsr_lib.random_bcsr_exact(1, (250, 200), (16, 16), 40))
    yield prepared("rand_wide_block_32x16",
                   bcsr_lib.random_bcsr_exact(2, (256, 256), (32, 16), 32))
    skew = bcsr_lib.random_bcsr(3, (256, 256), (16, 16), 0.15,
                                fill_density=0.5)
    yield prepared("rand_skew_identity", skew)
    yield prepared("rand_skew_jaccard", skew, reorder="jaccard")

    from repro.models import attention as A
    for mname, spec, seq in (("mask_banded", banded(32), 128),
                             ("mask_local_global", local_global(32, 16), 128),
                             ("mask_causal", blockwise_causal(), 64)):
        a = A.attention_mask_bcsr(spec, seq, (16, 16))
        meta = A.attention_mask_meta(spec, seq, (16, 16))
        yield ZooCase(mname, meta, a.row_ids, a.col_ids,
                      ("spmm", "sddmm", "attn"))

    a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), 80)
    host, smeta = dist_spmm._prepare_sharded_host(a, 4)
    yield ZooCase("sharded_4", smeta, host["row_ids"], host["col_ids"],
                  ("spmm", "sddmm"))
    # over-budgeted shards: leftover slots pad (row 0, col 0) with
    # DUPLICATE entries, so shard-local max_bpr can exceed n_block_cols —
    # the dims-derived budgets of the model-weight path hit this
    host, smeta = dist_spmm._prepare_sharded_host(a, 4, nnzb_per_shard=60)
    yield ZooCase("sharded_4_padded", smeta, host["row_ids"],
                  host["col_ids"], ("spmm", "sddmm"))

    spec = SL.SparsitySpec(density=0.3, block=(16, 16))
    pat = SL._pattern_for(11, 96, 64, spec)
    yield prepared("linear_d30_64x96", pat, granularity="block_row")


def run_verify(vmem_budget: int = workspace.DEFAULT_VMEM_BUDGET,
               n_values=(64, 512)) -> list:
    """The CLI pass: prove every zoo meta's invariants and every
    applicable schedule's contracts, plus grid/VMEM feasibility at a few
    N values.  Returns ``Finding``s (empty = the tree's structural
    contracts hold)."""
    findings = []

    def emit(case, msgs):
        findings.extend(Finding("launch-verify", f"zoo:{case.name}", 0, m)
                        for m in msgs)

    for case in structure_zoo():
        if hasattr(case.meta, "shard_metas"):
            emit(case, verify_sharded_meta(case.meta))
            metas = list(zip(case.meta.shard_metas,
                             case.row_ids, case.col_ids))
        else:
            emit(case, verify_meta(case.meta))
            metas = [(case.meta, case.row_ids, case.col_ids)]
        for m, rows, cols in metas:
            for family in case.families:
                sched = build_schedule(family, rows, cols, m)
                emit(case, verify_schedule(family, sched[0], sched[1],
                                           rows, cols, m, row_len=sched[2]))
                backend = "fused" if family == "attn" else "row_loop"
                op = family if family != "attn" else "attn"
                for n in n_values:
                    emit(case, [e for e in verify_launch(
                        m, backend, n=n, op=op, vmem_budget=vmem_budget)
                        if e])
        # overlap chunk schedules: the pipelined dispatch is only
        # bit-identical if every (n, n_chunks) schedule partitions the
        # panel exactly and each chunk's working set stays within VMEM
        from repro.launch.dist_spmm import chunk_schedule
        blk = (case.meta.shard_metas[0].block
               if hasattr(case.meta, "shard_metas") else case.meta.block)
        for n in n_values:
            for k in (1, 2, 4):
                emit(case, [f"chunk schedule n={n} k={k}: {e}"
                            for e in verify_chunk_schedule(
                                chunk_schedule(n, k), n, block=blk,
                                vmem_budget=vmem_budget)])

    # paged-KV page tables: exact mask-support coverage + placement
    # budgets, per mask family, with and without an offload budget
    from repro.core.attention_mask import (banded, blockwise_causal,
                                           local_global)
    for mname, spec, seq in (("mask_banded", banded(32), 128),
                             ("mask_local_global", local_global(32, 16), 128),
                             ("mask_causal", blockwise_causal(), 64)):
        for budget in (None, 2):
            findings.extend(
                Finding("launch-verify", f"paged:{mname}", 0, m)
                for m in verify_page_table(spec, seq, (16, 16),
                                           resident_pages=budget))
    return findings
