"""Shared workspace / VMEM byte estimators — ONE implementation.

These formulas used to live in two places: the autotuner's ``pick_bn``
(private ``working`` expression) and ``benchmarks/bench_attention.py``
(composed-vs-fused workspace fields that gate the benchmark diff).  The
launch verifier needs the same numbers, so they are unified here and the
other call sites delegate.  The formulas are DETERMINISTIC contracts —
``BENCH_attention.baseline.json`` pins two of them bit-for-bit — so any
change here is a baseline refresh, not a tweak.

All sizes are bytes per kernel instance (per head for attention).

>>> spmm_cell_bytes((16, 16), 512)
49664
>>> attn_fused_state_bytes((16, 16), 64)
24576
"""
from __future__ import annotations

# Mirrors ``autotune._VMEM_BUDGET``: conservative per-core VMEM slice
# available to one kernel's working set (full VMEM is ~16 MiB; half is
# left for double-buffering headroom and the compiler's own temps).
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20


def spmm_cell_bytes(block: tuple[int, int], bn: int) -> int:
    """Working-set bytes of one (block, bn) SpMM/SDDMM grid cell: the
    bf16 A-block + bf16 B-panel + f32 accumulator ``pick_bn`` budgets.

    >>> spmm_cell_bytes((32, 32), 256) == (32*32 + 32*256)*2 + 32*256*4
    True
    """
    h, w = block
    return (h * w + w * bn) * 2 + (h * bn) * 4


def fits_vmem(block: tuple[int, int], bn: int,
              budget: int = DEFAULT_VMEM_BUDGET) -> bool:
    """True iff a (block, bn) cell double-buffers inside ``budget`` —
    the exact feasibility predicate ``autotune.pick_bn`` uses.

    >>> fits_vmem((16, 16), 512)
    True
    >>> fits_vmem((128, 128), 65536)
    False
    """
    return spmm_cell_bytes(block, bn) * 2 <= budget


def attn_composed_workspace_bytes(meta) -> int:
    """Peak intermediate bytes of the composed SDDMM -> softmax -> SpMM
    attention path per head instance: it materializes the f32 scores AND
    probs tensors between its three launches (``2 * nnzb * h * w * 4``).
    """
    h, w = meta.block
    return 2 * meta.nnzb * h * w * 4


def attn_fused_state_bytes(block: tuple[int, int], head_dim: int) -> int:
    """Per-block-row VMEM running state of the fused one-kernel attention
    path: the (h, 128) max and denominator lanes plus the (h, dpad)
    context accumulator, all f32.  O(L * d) total — independent of nnzb.
    """
    h, _ = block
    dpad = max(-(-head_dim // 128), 1) * 128
    return h * (2 * 128 + dpad) * 4
