"""Fault-tolerant checkpointing: async, atomic, mesh-agnostic.

Layout:  <dir>/step_<N>/ {manifest.json, arrays.npz}
  * atomic: written to step_<N>.tmp then os.rename'd — a crash mid-save never
    corrupts the latest checkpoint.
  * async: a single background thread drains a depth-1 queue (a save that is
    still running skips the next request rather than stalling the step loop).
  * mesh-agnostic / elastic: arrays are saved as full logical tensors with
    their tree paths; ``restore`` re-shards onto WHATEVER mesh/shardings the
    relaunch uses (device counts may differ — elastic scaling).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

# numpy's savez cannot store ml_dtypes (bfloat16, fp8, ...): view them as
# raw unsigned ints and record the true dtype in the manifest
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _encode(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _unflatten_into(like, flat: Dict[str, np.ndarray]):
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"expected {leaf.shape}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._async = async_save
        self._err: Optional[BaseException] = None
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any], block: bool = False):
        """state: pytree dict, e.g. {"params": ..., "opt": ..., "step": N}."""
        host_state = jax.tree.map(np.asarray, state)   # pull off device
        if not self._async or block:
            self._write(step, host_state)
            return
        try:
            self._q.put_nowait((step, host_state))
        except queue.Full:
            pass  # previous save still running — skip (depth-1 policy)

    def _worker(self):
        while True:
            step, state = self._q.get()
            try:
                self._write(step, state)
            except BaseException as e:  # surfaced on next wait()
                self._err = e

    def _write(self, step: int, state):
        import uuid
        flat = _flatten(state)
        # unique tmp dir: an async save and a blocking save of the same step
        # must never collide (atomic rename publishes whichever finishes)
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        encoded, dtypes = {}, {}
        for k, v in flat.items():
            encoded[k], dtypes[k] = _encode(v)
        np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        """Drain pending async saves (used before shutdown / asserts)."""
        self._q.join() if False else None
        while not self._q.empty():
            import time
            time.sleep(0.05)
        # one extra beat to let an in-flight write finish
        import time
        time.sleep(0.05)
        if self._err:
            raise self._err

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; device_put with
        ``shardings`` (a matching pytree) if given — this is the elastic
        re-shard path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: _decode(z[k], manifest["leaves"][k]["dtype"])
                    for k in z.files}
        state = _unflatten_into(like, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda arr, s: jax.device_put(arr, s), state, shardings)
        return state, step
