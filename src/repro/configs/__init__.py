"""Config registry: ``get_config(name)`` / ``get_config(name + ':smoke')``."""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeCell, cell_applicable
from repro.configs.archs import ARCHS, smoke_config


def get_config(name: str) -> ModelConfig:
    smoke = False
    if name.endswith(":smoke"):
        name, smoke = name[: -len(":smoke")], True
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    return smoke_config(cfg) if smoke else cfg


def list_archs():
    return sorted(ARCHS)
