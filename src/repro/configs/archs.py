"""The 10 assigned architectures (exact configs per the assignment) plus the
paper's own ``smat-ffn`` arch (block-sparse FFN LM — the SpMM technique as a
first-class training feature).

Sources noted inline; dimensions follow the assignment block verbatim.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.attention_mask import AttnSparsitySpec, banded
from repro.core.sparse_linear import SparsitySpec


ARCHS = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --------------------------------------------------------------------- [ssm]
# SSD (state-space duality), arXiv:2405.21060
_register(ModelConfig(
    name="mamba2-1.3b", family="ssm", layout="ssd",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
))

# --------------------------------------------------------------------- [moe]
# DeepSeek-V2(-Lite), arXiv:2405.04434 — MLA kv_lora=512, shared+routed top-6
_register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", layout="mla_moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, q_lora_rank=None,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    n_experts=64, n_shared_experts=2, moe_top_k=6, expert_d_ff=1408,
))

_register(ModelConfig(
    name="deepseek-v2-236b", family="moe", layout="mla_moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    n_experts=160, n_shared_experts=2, moe_top_k=6, expert_d_ff=1536,
))

# --------------------------------------------------------------------- [vlm]
# Pixtral-12B: pixtral-ViT (STUB frontend) + mistral-nemo backbone
_register(ModelConfig(
    name="pixtral-12b", family="vlm", layout="attn_mlp",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1_000_000.0,
    input_mode="tokens+patches", patch_tokens=1024,
))

# ------------------------------------------------------------------- [dense]
# H2O-Danube-1.8B, arXiv:2401.16818 — llama+mistral mix, sliding window
_register(ModelConfig(
    name="h2o-danube-1.8b", family="dense", layout="attn_mlp",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000, sliding_window=4096,
))

# Minitron-4B (pruned Nemotron), arXiv:2407.14679
_register(ModelConfig(
    name="minitron-4b", family="dense", layout="attn_mlp",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256000,
))

# Qwen2.5-14B — GQA + QKV bias
_register(ModelConfig(
    name="qwen2.5-14b", family="dense", layout="attn_mlp",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0,
))

# Gemma2-27B, arXiv:2408.00118 — local/global alternation, logit softcaps
_register(ModelConfig(
    name="gemma2-27b", family="dense", layout="gemma_pair",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, mlp_act="gelu",
))

# ------------------------------------------------------------------ [hybrid]
# Zamba2-7B, arXiv:2411.15242 — Mamba2 backbone + shared attention block
_register(ModelConfig(
    name="zamba2-7b", family="hybrid", layout="zamba",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    hybrid_unit_len=5, hybrid_n_units=13, hybrid_tail=3,
))

# ------------------------------------------------------------------- [audio]
# MusicGen-medium, arXiv:2306.05284 — decoder over EnCodec tokens (stub
# frontend: 4 codebooks, vocab 2048 each)
_register(ModelConfig(
    name="musicgen-medium", family="audio", layout="attn_mlp",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    input_mode="codebooks", n_codebooks=4,
))

# --------------------------------------------------- the paper's own arch
# LM whose FFN weights are 90% block-sparse, multiplied by the SMaT kernels.
_register(ModelConfig(
    name="smat-ffn-1.3b", family="dense", layout="attn_mlp",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=32000,
    ffn_sparsity=SparsitySpec(density=0.10, block=(128, 128), backend="xla"),
))

# Both sparse workloads at once: block-sparse FFN weights AND block-sparse
# attention scores (banded mask, SDDMM -> block softmax -> SpMM).  The
# banded mask bounds the attended window, so this arch qualifies for the
# 500k decode cell like the SWA archs do.  backend="xla" mirrors the
# ffn_sparsity spec above: the registered config must stay CPU-lowerable
# for the whole-fleet dryrun (backend="auto" can resolve to a
# non-interpret Pallas variant there); flip to "auto" on real TPUs.
_register(ModelConfig(
    name="smat-attn-1.3b", family="dense", layout="attn_mlp",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=32000,
    ffn_sparsity=SparsitySpec(density=0.10, block=(128, 128), backend="xla"),
    attn_sparsity=AttnSparsitySpec(mask=banded(4096), block=(128, 128),
                                   backend="xla"),
))


# ---------------------------------------------------------------- smoke view
def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers, small
    width, tiny vocab; one forward/train step must run and be NaN-free."""
    kw = dict(
        name=cfg.name + ":smoke",
        n_layers=2 if cfg.layout != "gemma_pair" else 2,
        d_model=128,
        vocab_size=512,
        d_ff=256 if cfg.d_ff else 0,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
                  head_dim=32)
    if cfg.use_mla:
        kw.update(kv_lora_rank=64,
                  q_lora_rank=64 if cfg.q_lora_rank else None,
                  rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
    if cfg.n_experts:
        kw.update(n_experts=4, n_shared_experts=min(cfg.n_shared_experts, 1),
                  moe_top_k=2, expert_d_ff=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.layout == "zamba":
        kw.update(hybrid_unit_len=2, hybrid_n_units=2, hybrid_tail=1,
                  n_layers=5)
    if cfg.sliding_window:
        kw.update(sliding_window=64)
    if cfg.patch_tokens:
        kw.update(patch_tokens=8)
    if cfg.ffn_sparsity is not None:
        kw.update(ffn_sparsity=SparsitySpec(
            density=0.3, block=(16, 16), backend=cfg.ffn_sparsity.backend,
            bn=128, interpret=True))
    if cfg.attn_sparsity is not None:
        kw.update(attn_sparsity=dataclasses.replace(
            cfg.attn_sparsity, mask=banded(32), block=(16, 16),
            bn=128, interpret=True))
    return dataclasses.replace(cfg, **kw)
