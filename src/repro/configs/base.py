"""Model/shape configuration schema + the assigned shape cells."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.attention_mask import AttnSparsitySpec
from repro.core.sparse_linear import SparsitySpec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    layout: str                 # attn_mlp | gemma_pair | mla_moe | ssd | zamba
    n_layers: int               # total layers (for gemma_pair: 2*n_repeats)
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention details
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None     # SWA window (h2o-danube, gemma2 local)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None

    # --- MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "gather"    # gather (default) | einsum (GShard arm)

    # --- SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): units of (unit_len x mamba) + shared attn, + tail
    hybrid_unit_len: int = 5
    hybrid_n_units: int = 13
    hybrid_tail: int = 3

    # --- modality stubs
    input_mode: str = "tokens"      # tokens | tokens+patches | codebooks
    n_codebooks: int = 1
    patch_tokens: int = 0           # pixtral: leading positions fed by stub ViT

    # --- the paper's technique: block-sparse FFN weights
    ffn_sparsity: Optional[SparsitySpec] = None

    # --- the paper's second workload: block-sparse attention (scores
    # sampled on a static BCSR mask via SDDMM -> block softmax -> SpMM;
    # specs live in core.attention_mask, the layer in models.attention)
    attn_sparsity: Optional[AttnSparsitySpec] = None

    dtype: str = "bfloat16"
    mlp_act: str = "silu"           # silu (gated) | gelu (gated, gemma2)

    # ------------------------------------------------------------------ props
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing: SSM/hybrid state, bounded SWA
        window (gemma2 counts: half its layers are local), or a bounded
        block-sparse attention mask (banded / local+global) — see
        docs/ARCHITECTURE.md "Shape cells & applicability"."""
        if self.attn_sparsity is not None and \
                self.attn_sparsity.mask.kind in ("banded", "local_global"):
            return True
        return self.family in ("ssm", "hybrid") or \
            self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-style

    def param_count(self) -> int:
        """Analytic parameter count (drives 6*N*D MODEL_FLOPS accounting)."""
        d = self.d_model
        n = 0
        # embeddings + head
        if self.input_mode == "codebooks":
            n += self.n_codebooks * self.vocab_size * d * 2
        else:
            n += self.vocab_size * d * 2
        # blocks
        if self.layout == "ssd":
            n += self.n_layers * _ssd_params(self)
        elif self.layout == "zamba":
            n_mamba = self.hybrid_unit_len * self.hybrid_n_units + \
                self.hybrid_tail
            n += n_mamba * _ssd_params(self)
            n += _attn_params(self) + _mlp_params(self)  # shared block (once)
        elif self.layout == "mla_moe":
            n += self.n_layers * (_mla_params(self) + _moe_params(self))
        elif self.layout == "gemma_pair":
            n += self.n_layers * (_attn_params(self) + _mlp_params(self))
        else:
            n += self.n_layers * (_attn_params(self) + _mlp_params(self))
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k + shared only)."""
        if self.layout != "mla_moe":
            return self.param_count()
        d = self.d_model
        active_experts = self.moe_top_k + self.n_shared_experts
        per_layer = _mla_params(self) + \
            3 * d * self.expert_d_ff * active_experts + d * self.n_experts
        return self.vocab_size * d * 2 + self.n_layers * per_layer


def _attn_params(cfg: ModelConfig) -> int:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return d * h * hd + 2 * d * kv * hd + h * hd * d


def _mlp_params(cfg: ModelConfig) -> int:
    if cfg.ffn_sparsity is not None:
        return int(3 * cfg.d_model * cfg.d_ff * cfg.ffn_sparsity.density)
    return 3 * cfg.d_model * cfg.d_ff  # gated: up, gate, down


def _mla_params(cfg: ModelConfig) -> int:
    d, h = cfg.d_model, cfg.n_heads
    qd = h * (cfg.nope_head_dim + cfg.rope_head_dim)
    n = 0
    if cfg.q_lora_rank:
        n += d * cfg.q_lora_rank + cfg.q_lora_rank * qd
    else:
        n += d * qd
    n += d * (cfg.kv_lora_rank + cfg.rope_head_dim)           # down kv + rope
    n += cfg.kv_lora_rank * h * (cfg.nope_head_dim + cfg.v_head_dim)
    n += h * cfg.v_head_dim * d                               # out proj
    return n


def _moe_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    n = d * cfg.n_experts                                     # router
    n += 3 * d * cfg.expert_d_ff * cfg.n_experts              # routed
    n += 3 * d * cfg.expert_d_ff * cfg.n_shared_experts       # shared
    return n


def _ssd_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.d_inner
    g, ns, hh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    d_xbc = di + 2 * g * ns
    in_proj = d * (2 * di + 2 * g * ns + hh)
    conv = cfg.ssm_conv_width * d_xbc
    return in_proj + conv + 2 * hh + di * d + di              # A,D,out,norm


# ------------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason) — long_500k skips pure full-attention archs
    (see docs/ARCHITECTURE.md "Shape cells & applicability")."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic sequence mixing (see "
                       "docs/ARCHITECTURE.md)")
    return True, ""
