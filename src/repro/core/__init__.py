from repro.core.bcsr import (BCSR, from_csr, from_dense, from_scipy,
                             random_bcsr, random_bcsr_exact)
from repro.core.sparse_linear import (SparsitySpec, apply_sparse_linear,
                                      init_sparse_linear,
                                      sparse_linear_specs)
from repro.core import reorder, topology, perf_model
from repro.core import permute
from repro.core.permute import SCHEMES, permute_bcsr
