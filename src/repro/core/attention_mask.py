"""Static attention-mask specs for block-sparse attention.

Pure host-side dataclasses + the element-level predicate — the LEAF layer
of the attention subsystem, importable from anywhere (``repro.configs``
declares arch defaults with these; ``repro.models.attention`` builds the
BCSR pipeline and the actual SDDMM/softmax/SpMM layer on top and
re-exports everything here, so ``from repro.models import attention as A;
A.banded(...)`` remains the user-facing spelling).

Keeping the specs below ``configs`` preserves the one-directional layer
map (``docs/ARCHITECTURE.md``): core imports nothing above it, configs
imports core, models imports everything.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnMaskSpec:
    """Static element-level attention mask pattern (hashable).

    ``kind`` picks the predicate; every kind is causal.  ``window_cap``
    intersects an additional sliding-window bound (used when a config
    combines ``sliding_window`` with sparse attention).  Build instances
    with the ``banded`` / ``local_global`` / ``blockwise_causal``
    constructors below.
    """
    kind: str                     # banded | local_global | blockwise_causal
    bandwidth: int = 0            # banded: k > q - bandwidth
    window: int = 0               # local_global: local window
    n_global: int = 0             # local_global: always-visible prefix keys
    window_cap: int = 0           # optional extra sliding-window intersect


def banded(bandwidth: int) -> AttnMaskSpec:
    """Sliding-window (banded) causal mask: query q sees keys
    ``(q - bandwidth, q]``.

    >>> from repro.models import attention as A
    >>> spec = A.banded(32)
    >>> meta = A.attention_mask_meta(spec, seq_len=128, block=(16, 16))
    >>> (meta.shape, meta.nnzb > 0, meta.max_bpr)
    ((128, 128), True, 3)
    """
    if bandwidth < 1:
        raise ValueError(f"bandwidth must be >= 1, got {bandwidth}")
    return AttnMaskSpec(kind="banded", bandwidth=bandwidth)


def local_global(window: int, n_global: int) -> AttnMaskSpec:
    """Local sliding window + a globally visible key prefix (the
    longformer/big-bird shape): query q sees keys ``(q - window, q]`` and
    keys ``< n_global``.

    >>> from repro.models import attention as A
    >>> m_lg = A.attention_mask_meta(A.local_global(32, 16), 128, (16, 16))
    >>> m_b = A.attention_mask_meta(A.banded(32), 128, (16, 16))
    >>> m_lg.nnzb > m_b.nnzb        # the global column strip adds blocks
    True
    """
    if window < 1 or n_global < 0:
        raise ValueError(f"bad local_global({window}, {n_global})")
    return AttnMaskSpec(kind="local_global", window=window,
                        n_global=n_global)


def blockwise_causal() -> AttnMaskSpec:
    """Plain causal attention realized blockwise — every block on or below
    the block diagonal is stored, the diagonal blocks mask element-causally
    inside.  Numerically identical to dense causal attention (the oracle
    the tests pin), at dense-causal cost: use it as the correctness anchor,
    the banded/local_global specs for actual sparsity wins."""
    return AttnMaskSpec(kind="blockwise_causal")


def mask_allowed(spec: AttnMaskSpec, q_pos, k_pos):
    """Element-level predicate ``[..., Lq, Sk]`` — works on numpy (host
    mask construction) and jnp (decode-step bias) index arrays alike."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    ok = (k <= q) & (k >= 0)
    if spec.kind == "banded":
        ok = ok & (k > q - spec.bandwidth)
    elif spec.kind == "local_global":
        ok = ok & ((k > q - spec.window) | (k < spec.n_global))
    elif spec.kind != "blockwise_causal":
        raise ValueError(f"unknown mask kind {spec.kind!r}")
    if spec.window_cap:
        ok = ok & (k > q - spec.window_cap)
    return ok


@dataclasses.dataclass(frozen=True)
class AttnSparsitySpec:
    """Config for block-sparse attention (the second workload toggle —
    ``ModelConfig.attn_sparsity``).

    ``mask`` is the static pattern; ``block`` the BCSR tile of the score
    matrix (lane/sublane-aligned on real TPUs, anything in interpret
    mode).  ``backend`` feeds BOTH ops — with ``"auto"`` the attention
    layer first arbitrates fused-vs-composed through the ``op="attn"``
    family, then (composed) the SDDMM and the SpMM resolve independently
    from their own v6 fingerprint families; ``"fused"`` forces the
    single-launch ``kernels.bcsr_attn`` path (bit-for-bit equal forward,
    composed backward).  ``shards > 0`` row-partitions the score
    structure through ``launch.dist_spmm`` for the context product
    (shard_map under a compatible ambient mesh from
    ``dist_spmm.use_spmm_mesh``, identical in-process math otherwise) —
    sharded specs always run composed.

    ``paged_decode`` controls the serving decode path (PR 8): ``"auto"``
    gathers KV through the mask-BCSR page table whenever that touches
    strictly fewer pages than the cache holds (banded / local_global
    masks), ``"force"`` takes the paged path whenever it is structurally
    possible (cache_len divisible by the mask block width), ``"off"``
    keeps the dense-bias decode.  All three are bitwise-identical to the
    full-table run of the same machinery — the paged gather only skips
    pages whose softmax contribution is exactly zero."""
    mask: AttnMaskSpec = dataclasses.field(default_factory=blockwise_causal)
    block: Tuple[int, int] = (16, 16)
    backend: str = "auto"   # pallas | row_loop | xla | dense | auto | fused
    bn: int = 512
    interpret: bool = False
    shards: int = 0                 # >0: row-shard the score structure
    paged_decode: str = "auto"      # auto | force | off (serving decode)
