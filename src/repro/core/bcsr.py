"""BCSR (Blocked Compressed Sparse Row) format — the paper's core data structure.

A sparse matrix ``A`` of logical shape ``(M, K)`` is tiled into dense blocks of
shape ``(h, w)``; only blocks containing at least one nonzero are stored.  On
GPU SMaT picks ``h x w`` to match the MMA instruction tile (16x8 for FP16
m16n8k16).  On TPU the analogous choice is the MXU tile: ``h`` a multiple of
the sublane pack (8 for f32 / 16 for bf16) and ``w`` a multiple of the 128-wide
lane dimension.  The default production block is 128x128.

Arrays (mirroring the paper's Figure 1, plus ``row_ids`` which the TPU
nnz-streamed kernel prefetches):

  vals     [nnzb, h, w]   dense block values (zero-padded)
  col_ids  [nnzb]         block-column index of each block
  row_ids  [nnzb]         block-row index of each block (sorted, row-major)
  rowptr   [n_brows + 1]  CSR-style offsets into col_ids/vals per block-row

The host-side representation is NumPy; ``device_arrays`` returns the pytree
consumed by the kernels.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

try:  # scipy is available in this environment; used for fast host conversion
    import scipy.sparse as _sp
except Exception:  # pragma: no cover
    _sp = None


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def rowptr_from_rows(row_ids: np.ndarray, n_block_rows: int) -> np.ndarray:
    """CSR-style offsets [n_block_rows + 1] from (sorted) block-row ids —
    the single rebuild used by every constructor/permuter."""
    rowptr = np.zeros(n_block_rows + 1, dtype=np.int32)
    np.add.at(rowptr, np.asarray(row_ids) + 1, 1)
    return np.cumsum(rowptr).astype(np.int32)


@dataclasses.dataclass
class BCSR:
    """Host-side blocked-CSR matrix (numpy)."""

    vals: np.ndarray      # [nnzb, h, w]
    col_ids: np.ndarray   # [nnzb] int32
    row_ids: np.ndarray   # [nnzb] int32
    rowptr: np.ndarray    # [n_brows + 1] int32
    shape: Tuple[int, int]
    block: Tuple[int, int]

    # ------------------------------------------------------------------ stats
    @property
    def nnzb(self) -> int:
        return int(self.vals.shape[0])

    @property
    def n_block_rows(self) -> int:
        return _ceil_div(self.shape[0], self.block[0])

    @property
    def n_block_cols(self) -> int:
        return _ceil_div(self.shape[1], self.block[1])

    @property
    def nnz(self) -> int:
        return int(np.count_nonzero(self.vals))

    @property
    def padding_ratio(self) -> float:
        """Fraction of stored values that are explicit zeros (paper's padding)."""
        total = self.vals.size
        return 1.0 - self.nnz / max(total, 1)

    @property
    def density(self) -> float:
        return self.nnz / (self.shape[0] * self.shape[1])

    def blocks_per_row(self) -> np.ndarray:
        return np.diff(self.rowptr)

    def dispatch_stats(self) -> Tuple[int, int, int]:
        """(max_bpr, padding_ratio_pct, bpr_cv_pct) — the structure stats
        the kernel autotuner fingerprints on.  Single source of truth:
        ``ops.prepare_sparse`` and ``autotune.fingerprint_bcsr`` must agree
        bit-for-bit or cached decisions stop matching at lookup time."""
        bpr = self.blocks_per_row().astype(np.float64)
        mean = float(bpr.mean()) if bpr.size else 0.0
        cv = float(bpr.std() / mean) if mean > 0 else 0.0
        return (int(bpr.max()) if bpr.size else 0,
                int(round(self.padding_ratio * 100)),
                int(round(cv * 100)))

    def block_bounds(self) -> Tuple[int, int]:
        """Paper Eq. 2 bounds on n_e for this matrix's nnz."""
        h, w = self.block
        n, m = self.shape
        nnz = self.nnz
        lo = _ceil_div(nnz, h * w)
        hi = min(_ceil_div(n, h) * _ceil_div(m, w), nnz)
        return lo, hi

    def stats(self) -> dict:
        bpr = self.blocks_per_row()
        lo, hi = self.block_bounds()
        return {
            "shape": self.shape,
            "block": self.block,
            "nnz": self.nnz,
            "nnzb": self.nnzb,
            "padding_ratio": self.padding_ratio,
            "blocks_per_row_mean": float(bpr.mean()) if bpr.size else 0.0,
            "blocks_per_row_std": float(bpr.std()) if bpr.size else 0.0,
            "blocks_per_row_max": int(bpr.max()) if bpr.size else 0,
            "n_e_lower_bound": lo,
            "n_e_upper_bound": hi,
        }

    # ------------------------------------------------------------- conversion
    def to_dense(self) -> np.ndarray:
        h, w = self.block
        M, K = self.shape
        out = np.zeros((self.n_block_rows * h, self.n_block_cols * w),
                       dtype=self.vals.dtype)
        for s in range(self.nnzb):
            i, j = int(self.row_ids[s]), int(self.col_ids[s])
            out[i * h:(i + 1) * h, j * w:(j + 1) * w] = self.vals[s]
        return out[:M, :K]

    def to_scipy(self) -> "_sp.csr_matrix":
        """Nonzero structure as scipy CSR (host preprocessing: reordering
        works on element rows).  Stored-but-zero values are dropped — this
        is the *structure* view, not a value-preserving round-trip for
        matrices with explicitly stored zeros."""
        if _sp is None:  # pragma: no cover - scipy present in target env
            return None
        h, w = self.block
        s, i, j = np.nonzero(self.vals)
        rows = self.row_ids[s].astype(np.int64) * h + i
        cols = self.col_ids[s].astype(np.int64) * w + j
        m = _sp.coo_matrix((self.vals[s, i, j], (rows, cols)),
                           shape=self.shape)
        return m.tocsr()

    def transpose(self) -> "BCSR":
        """Block-structure transpose (used for dX = A^T @ dY in the VJP)."""
        order = np.lexsort((self.row_ids, self.col_ids))  # sort by (col, row)
        t_vals = np.ascontiguousarray(
            np.transpose(self.vals[order], (0, 2, 1)))
        t_rows = self.col_ids[order].astype(np.int32)
        t_cols = self.row_ids[order].astype(np.int32)
        n_brows_t = self.n_block_cols
        rowptr = rowptr_from_rows(t_rows, n_brows_t)
        return BCSR(t_vals, t_cols, t_rows, rowptr,
                    (self.shape[1], self.shape[0]),
                    (self.block[1], self.block[0]))

    def ensure_nonempty_rows(self, return_mask: bool = False):
        """Pad so every block-row holds >= 1 block (required by the
        nnz-streamed kernel so each output tile is visited/zeroed).

        With ``return_mask=True`` returns ``(padded, real_mask)`` where
        ``real_mask[s]`` is False exactly for the entries this call
        appended.  The padding is tagged BEFORE the lexsort, so genuinely
        zero original blocks (e.g. ``random_bcsr(fill_density<1)``) stay
        marked real — their gradients must not be masked."""
        bpr = self.blocks_per_row()
        empty = np.flatnonzero(bpr == 0)
        if empty.size == 0:
            if return_mask:
                return self, np.ones(self.nnzb, dtype=bool)
            return self
        h, w = self.block
        pad_vals = np.zeros((empty.size, h, w), dtype=self.vals.dtype)
        vals = np.concatenate([self.vals, pad_vals], axis=0)
        col_ids = np.concatenate([self.col_ids,
                                  np.zeros(empty.size, np.int32)])
        row_ids = np.concatenate([self.row_ids, empty.astype(np.int32)])
        real = np.concatenate([np.ones(self.nnzb, dtype=bool),
                               np.zeros(empty.size, dtype=bool)])
        order = np.lexsort((col_ids, row_ids))
        vals, col_ids, row_ids = vals[order], col_ids[order], row_ids[order]
        real = real[order]
        rowptr = rowptr_from_rows(row_ids, self.n_block_rows)
        padded = BCSR(vals, col_ids.astype(np.int32),
                      row_ids.astype(np.int32), rowptr, self.shape,
                      self.block)
        if return_mask:
            return padded, real
        return padded

    def astype(self, dtype) -> "BCSR":
        return dataclasses.replace(self, vals=self.vals.astype(dtype))

    def device_arrays(self):
        """The pytree handed to kernels: (vals, row_ids, col_ids, rowptr)."""
        return self.vals, self.row_ids, self.col_ids, self.rowptr


# ---------------------------------------------------------------- constructors
def from_dense(a: np.ndarray, block: Tuple[int, int]) -> BCSR:
    """Block a dense matrix, keeping only nonzero blocks."""
    h, w = block
    M, K = a.shape
    nbr, nbc = _ceil_div(M, h), _ceil_div(K, w)
    padded = np.zeros((nbr * h, nbc * w), dtype=a.dtype)
    padded[:M, :K] = a
    blocks = padded.reshape(nbr, h, nbc, w).transpose(0, 2, 1, 3)
    mask = np.abs(blocks).sum(axis=(2, 3)) != 0  # [nbr, nbc]
    row_ids, col_ids = np.nonzero(mask)
    vals = np.ascontiguousarray(blocks[row_ids, col_ids])
    rowptr = rowptr_from_rows(row_ids, nbr)
    return BCSR(vals, col_ids.astype(np.int32), row_ids.astype(np.int32),
                rowptr, (M, K), (h, w))


def from_csr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
             shape: Tuple[int, int], block: Tuple[int, int]) -> BCSR:
    """CSR -> BCSR, the paper's input path (Figure 1, left).

    Uses a scipy round-trip for speed on large host matrices; falls back to a
    pure-numpy bucketing implementation when scipy is unavailable.
    """
    h, w = block
    M, K = shape
    nbr, nbc = _ceil_div(M, h), _ceil_div(K, w)
    if _sp is not None:
        csr = _sp.csr_matrix((data, indices, indptr), shape=shape)
        coo = csr.tocoo()
        brow = (coo.row // h).astype(np.int64)
        bcol = (coo.col // w).astype(np.int64)
        bid = brow * nbc + bcol
        uniq, inv = np.unique(bid, return_inverse=True)
        nnzb = uniq.size
        vals = np.zeros((nnzb, h, w), dtype=data.dtype)
        # accumulate — duplicate COO coordinates must sum like
        # scipy's sum_duplicates, not keep-last
        np.add.at(vals, (inv, coo.row % h, coo.col % w), coo.data)
        row_ids = (uniq // nbc).astype(np.int32)
        col_ids = (uniq % nbc).astype(np.int32)
    else:  # pragma: no cover - scipy present in target env
        rows = np.repeat(np.arange(M), np.diff(indptr))
        brow = rows // h
        bcol = indices // w
        bid = brow * nbc + bcol
        uniq, inv = np.unique(bid, return_inverse=True)
        nnzb = uniq.size
        vals = np.zeros((nnzb, h, w), dtype=data.dtype)
        np.add.at(vals, (inv, rows % h, indices % w), data)
        row_ids = (uniq // nbc).astype(np.int32)
        col_ids = (uniq % nbc).astype(np.int32)
    rowptr = rowptr_from_rows(row_ids, nbr)
    return BCSR(vals, col_ids, row_ids, rowptr, shape, block)


def from_scipy(mat, block: Tuple[int, int]) -> BCSR:
    csr = mat.tocsr()
    return from_csr(csr.indptr, csr.indices, csr.data, csr.shape, block)


def random_bcsr_exact(key: int, shape: Tuple[int, int],
                      block: Tuple[int, int], nnzb: int,
                      dtype=np.float32) -> BCSR:
    """Random block-sparse matrix with EXACTLY ``nnzb`` blocks, every
    block-row and block-col covered (no padding entries needed).  Used for
    scan-stacked sparse layers where all layers must share nnzb.
    """
    rng = np.random.default_rng(key)
    h, w = block
    nbr, nbc = _ceil_div(shape[0], h), _ceil_div(shape[1], w)
    assert nnzb >= max(nbr, nbc), "need >= one block per row and col"
    assert nnzb <= nbr * nbc
    # cover every row and col first (diagonal-ish assignment)
    base_rows = np.arange(max(nbr, nbc)) % nbr
    base_cols = np.arange(max(nbr, nbc)) % nbc
    chosen = set(zip(base_rows.tolist(), base_cols.tolist()))
    while len(chosen) < nnzb:
        need = nnzb - len(chosen)
        rr = rng.integers(0, nbr, size=need * 2)
        cc = rng.integers(0, nbc, size=need * 2)
        for r, c in zip(rr.tolist(), cc.tolist()):
            if len(chosen) >= nnzb:
                break
            chosen.add((r, c))
    pairs = np.array(sorted(chosen), dtype=np.int64)[:nnzb]
    # note: sorted(set) may drop below nnzb if duplicates; loop above prevents
    row_ids = pairs[:, 0].astype(np.int32)
    col_ids = pairs[:, 1].astype(np.int32)
    vals = (rng.standard_normal((nnzb, h, w)) / math.sqrt(w)).astype(dtype)
    rowptr = rowptr_from_rows(row_ids, nbr)
    return BCSR(vals, col_ids, row_ids, rowptr, shape, block)


def random_bcsr(key: int, shape: Tuple[int, int], block: Tuple[int, int],
                block_density: float, dtype=np.float32,
                fill_density: float = 1.0) -> BCSR:
    """Random block-sparse matrix: a ``block_density`` fraction of blocks are
    nonzero; within each block a ``fill_density`` fraction of entries are
    nonzero (fill < 1 models the paper's padding)."""
    rng = np.random.default_rng(key)
    h, w = block
    nbr, nbc = _ceil_div(shape[0], h), _ceil_div(shape[1], w)
    mask = rng.random((nbr, nbc)) < block_density
    row_ids, col_ids = np.nonzero(mask)
    nnzb = row_ids.size
    vals = (rng.standard_normal((nnzb, h, w)) / math.sqrt(w)).astype(dtype)
    if fill_density < 1.0:
        keep = rng.random((nnzb, h, w)) < fill_density
        vals = np.where(keep, vals, 0).astype(dtype)
    rowptr = rowptr_from_rows(row_ids, nbr)
    return BCSR(vals, col_ids.astype(np.int32), row_ids.astype(np.int32),
                rowptr, shape, block)
