"""Optional native kernel for the packed-bitmask Jaccard clustering.

The numpy implementation in ``core.permute`` amortizes the greedy scan into
vectorized rounds, but per-cluster numpy call overhead caps it around ~30x
over the pure-Python reference.  This module compiles (once, at first use,
with the system C compiler) a ~40-line kernel that runs the EXACT reference
algorithm — one sequential pass per cluster with the union growing as rows
join, ``reorder.jaccard_rows`` semantics bit-for-bit — over the same packed
uint64 bitmasks, which removes all interpreter overhead (>100x on the 4k-row
bench matrices).

No toolchain, no problem: every entry point degrades silently to ``None``
and callers fall back to the numpy rounds (same tau/max_candidates
semantics, marginally different greedy tie-walking).  Set
``REPRO_NO_NATIVE_JACCARD=1`` to force the fallback (used by the parity
tests and reproducible-baseline runs).
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
import tempfile
from typing import Optional

import numpy as np

_SRC = r"""
#include <stdint.h>

/* Greedy Jaccard row clustering over packed block-column bitmasks.
   Inputs are in scan order (rows pre-sorted by first block-column):
     packed [n*W] uint64, pop [n] int64.
   Exact `reorder.jaccard_rows` semantics: open a cluster at the first
   unclustered row, scan the (at most max_candidates) unclustered rows
   after it in order, join when 1 - inter/union < tau with the union
   growing as rows join.  Writes the position permutation to perm. */
long jaccard_cluster(const uint64_t* packed, const int64_t* pop,
                     long n, long W, double tau, long max_candidates,
                     uint64_t* pc, unsigned char* clustered, long* perm)
{
    long out = 0;
    long start = 0;
    while (start < n) {
        while (start < n && clustered[start]) start++;
        if (start >= n) break;
        long seed = start;
        clustered[seed] = 1;
        perm[out++] = seed;
        int64_t pc_pop = pop[seed];
        for (long w = 0; w < W; ++w) pc[w] = packed[seed * W + w];
        long scanned = 0;
        for (long c = seed + 1; c < n; ++c) {
            if (clustered[c]) continue;
            if (max_candidates >= 0 && ++scanned > max_candidates) break;
            const uint64_t* row = packed + c * W;
            int64_t inter = 0;
            for (long w = 0; w < W; ++w)
                inter += (int64_t)__builtin_popcountll(row[w] & pc[w]);
            int64_t uni = pop[c] + pc_pop - inter;
            double dist = (uni == 0) ? 0.0
                                     : 1.0 - (double)inter / (double)uni;
            if (dist < tau) {
                clustered[c] = 1;
                perm[out++] = c;
                pc_pop = 0;
                for (long w = 0; w < W; ++w) {
                    pc[w] |= row[w];
                    pc_pop += (int64_t)__builtin_popcountll(pc[w]);
                }
            }
        }
    }
    return out;
}
"""

_lib: Optional[ctypes.CDLL] = None
_tried = False


def _build() -> Optional[ctypes.CDLL]:
    """Compile (or reuse the cached .so for) the kernel; None on failure."""
    tag = hashlib.sha256(_SRC.encode()).hexdigest()[:16]
    cache = os.path.join(tempfile.gettempdir(), f"repro_jaccard_{tag}.so")
    if not os.path.exists(cache):
        src_path = os.path.join(tempfile.gettempdir(),
                                f"repro_jaccard_{tag}.c")
        with open(src_path, "w") as f:
            f.write(_SRC)
        tmp_out = f"{cache}.tmp.{os.getpid()}"
        built = False
        for extra in (["-march=native"], []):
            try:
                r = subprocess.run(
                    ["cc", "-O3", "-shared", "-fPIC", *extra, src_path,
                     "-o", tmp_out],
                    capture_output=True, timeout=120)
            except (OSError, subprocess.SubprocessError):
                return None
            if r.returncode == 0:
                built = True
                break
        if not built:
            return None
        os.replace(tmp_out, cache)   # atomic: concurrent builders race safely
    lib = ctypes.CDLL(cache)
    fn = lib.jaccard_cluster
    fn.restype = ctypes.c_long
    fn.argtypes = [ctypes.c_void_p, ctypes.c_void_p, ctypes.c_long,
                   ctypes.c_long, ctypes.c_double, ctypes.c_long,
                   ctypes.c_void_p, ctypes.c_void_p, ctypes.c_void_p]
    return lib


def get_kernel() -> Optional[ctypes.CDLL]:
    global _lib, _tried
    if os.environ.get("REPRO_NO_NATIVE_JACCARD"):
        return None
    if not _tried:
        _tried = True
        try:
            _lib = _build()
        except Exception:
            _lib = None
    return _lib


def jaccard_cluster(packed: np.ndarray, pop: np.ndarray, tau: float,
                    max_candidates: Optional[int]) -> Optional[np.ndarray]:
    """Run the native greedy clustering; returns the position permutation
    (indices into the scan-ordered inputs) or None when no kernel."""
    lib = get_kernel()
    if lib is None:
        return None
    n, w = packed.shape
    packed = np.ascontiguousarray(packed)
    pop = np.ascontiguousarray(pop, dtype=np.int64)
    pc = np.zeros(w, np.uint64)
    clustered = np.zeros(n, np.uint8)
    perm = np.empty(n, dtype=np.int64 if ctypes.sizeof(ctypes.c_long) == 8
                    else np.int32)
    count = lib.jaccard_cluster(
        packed.ctypes.data, pop.ctypes.data, n, w, float(tau),
        -1 if max_candidates is None else int(max_candidates),
        pc.ctypes.data, clustered.ctypes.data, perm.ctypes.data)
    if count != n:  # pragma: no cover - defensive
        return None
    return perm.astype(np.int64, copy=False)
