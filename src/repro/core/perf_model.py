"""The paper's empirical performance model (Section III) + TPU roofline terms.

Eq. 1:  T_tot = T_e * n_e + T_init

  n_e  — number of nonzero BCSR blocks (elementary MMA computations)
  T_e  — time of one elementary computation (one MXU block-matmul here)
  T_init — startup / warm-up / finalization overhead

The paper fits (T_e, T_init) on band matrices of varying bandwidth and shows
the fit matches measurement; we reproduce that experiment in
``benchmarks/bench_perf_model.py`` (CPU-measured for the fit, TPU-modeled for
the roofline numbers).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

# ------------------------------------------------------ TPU v5e-class constants
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per direction)

# A100-SXM4-40GB constants (the paper's platform, for cross-checks)
A100_PEAK_FP16_TC = 312e12
A100_HBM_BW = 1.555e12


@dataclasses.dataclass
class LinearFit:
    t_e: float
    t_init: float
    r2: float

    def predict(self, n_e: np.ndarray) -> np.ndarray:
        return self.t_e * np.asarray(n_e, dtype=np.float64) + self.t_init


def fit(n_e: Sequence[float], t_tot: Sequence[float]) -> LinearFit:
    """Least-squares fit of Eq. 1 on measured (n_e, T_tot) pairs."""
    x = np.asarray(n_e, dtype=np.float64)
    y = np.asarray(t_tot, dtype=np.float64)
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
    return LinearFit(t_e=float(coef[0]), t_init=float(coef[1]),
                     r2=1.0 - ss_res / ss_tot)


# ------------------------------------------------------------- TPU block model
def block_mma_time(h: int, w: int, n: int,
                   bytes_per_el: int = 2,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW) -> Tuple[float, float, float]:
    """Roofline time of ONE elementary block computation on TPU:
    an (h x w) @ (w x n) MXU matmul with its HBM traffic.

    Returns (t_compute, t_memory, t_e = max of both).  This is the TPU
    analogue of the paper's single-MMA-instruction T_e: the A block must be
    streamed from HBM every time (sparse blocks are never reused), while the
    B tile is reused across a block-row, so we charge A fully and B/C
    amortized per block.
    """
    flops = 2.0 * h * w * n
    t_comp = flops / peak_flops
    bytes_moved = (h * w) * bytes_per_el          # A block (always streamed)
    bytes_moved += (w * n) * bytes_per_el         # B tile (worst case, no reuse)
    t_mem = bytes_moved / hbm_bw
    return t_comp, t_mem, max(t_comp, t_mem)


def spmm_model_time(n_e: int, h: int, w: int, n: int,
                    t_init: float = 5e-6, **kw) -> float:
    """Eq. 1 instantiated with the TPU block roofline T_e."""
    _, _, t_e = block_mma_time(h, w, n, **kw)
    return t_e * n_e + t_init


def spmm_effective_gflops(nnz: int, n: int, t_tot: float) -> float:
    """Paper's effective-FLOP/s metric: useful flops = 2*nnz*N (zeros in
    padding don't count)."""
    return 2.0 * nnz * n / t_tot / 1e9


def dense_gemm_time(m: int, k: int, n: int,
                    bytes_per_el: int = 2,
                    peak_flops: float = PEAK_FLOPS_BF16,
                    hbm_bw: float = HBM_BW) -> float:
    """cuBLAS-arm model: dense MXU GEMM roofline (for the crossover study)."""
    t_comp = 2.0 * m * k * n / peak_flops
    t_mem = (m * k + k * n + m * n) * bytes_per_el / hbm_bw
    return max(t_comp, t_mem)


def csr_spmm_time(nnz: int, n: int,
                  bytes_per_el: int = 4,
                  hbm_bw: float = HBM_BW,
                  gather_overhead: float = 8.0) -> float:
    """cuSPARSE-arm model: scalar CSR SpMM is gather-bound; each nonzero
    triggers ~(index + value + N-row access) irregular traffic.  The
    ``gather_overhead`` multiplier captures non-coalesced access (fitted to
    the paper's cuSPARSE curves, which sit ~1-2 orders below peak)."""
    bytes_moved = nnz * (4 + bytes_per_el + n * bytes_per_el) * gather_overhead
    return bytes_moved / hbm_bw
