"""Permutation subsystem: fast block-densifying reordering, wired end-to-end.

``core.reorder`` holds the paper-faithful *reference* implementations
(Section IV-C); its greedy Jaccard clustering is an O(n^2) pure-Python loop
over per-row sets — fine for unit tests, unusable as a pipeline stage.  This
module makes the permutation a first-class preprocessing step:

  * ``jaccard_rows_fast`` — the same greedy clustering over packed
    block-column bitmasks: each row's block-column set is a uint64 bitmask
    row, so a Jaccard distance is an AND + popcount.  With a C toolchain,
    a tiny compiled kernel (``core.native``) runs the exact reference
    single-pass greedy over the bitmasks (>= 100x on the 4k-row bench
    matrices, bit-identical permutations); otherwise a vectorized-numpy
    path scans candidates in batched rounds against the growing union
    (fixpoint — ~30x, same ``tau`` / ``max_candidates`` semantics).  See
    ``benchmarks/bench_reorder.py`` for the measured numbers.
  * ``SCHEMES`` — THE dispatch table (exported from ``repro.core``):
    every scheme is a callable ``fn(csr, *, block, tau, max_candidates,
    n_shards) -> row_perm`` (or ``(row_perm, col_perm)`` for the row+col
    ablation).  ``reorder.reorder()`` and ``ops.prepare_sparse(reorder=...)``
    both consume it, so registering a scheme here makes it reachable from
    the whole pipeline.
  * ``permute_bcsr`` — applies a scheme to a host BCSR and returns the
    permuted matrix together with the row permutation, at two granularities:
    ``element`` re-blocks the row-permuted CSR (the paper's preprocessing —
    nnzb can shrink), ``block_row`` permutes whole block-rows (nnzb is
    preserved exactly — required for scan-stacked model weights whose leaf
    shapes must be static).

The op layer (``kernels.ops``) stores ``row_perm`` / ``inv_perm`` as pytree
leaves and undoes the permutation on the way out (C = P^T (A' B)), so every
consumer sees original row order; see ``prepare_sparse``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import scipy.sparse as sp

from repro.core import bcsr as bcsr_lib
from repro.core import native
from repro.core.reorder import identity as _identity_rows
from repro.core.reorder import rcm as _rcm_rows
from repro.core.reorder import shard_balance as _shard_balance_brows

try:  # numpy >= 2.0
    _popcount = np.bitwise_count
except AttributeError:  # pragma: no cover - env pins numpy 2.x
    _POP8 = np.array([bin(i).count("1") for i in range(256)], np.uint8)

    def _popcount(x):
        flat = np.ascontiguousarray(x).view(np.uint8)
        return _POP8[flat].reshape(*x.shape, x.dtype.itemsize).sum(-1)


def _max_bcol(pc: np.ndarray) -> int:
    """Largest block-column set in a packed mask (-1 if empty).

    The uint64 view preserves ``packbits`` byte order, so byte k covers
    bcols [8k, 8k+8) with the byte's MSB = bcol 8k."""
    b = pc.view(np.uint8)
    nz = np.flatnonzero(b)
    if nz.size == 0:
        return -1
    k = int(nz[-1])
    v = int(b[k])
    return 8 * k + 7 - ((v & -v).bit_length() - 1)


def _row_popcount(masked: np.ndarray) -> np.ndarray:
    """Per-row popcount of a [R, W] uint64 array -> int64 [R].

    Manual column accumulation: ``uint8.sum(axis=1)`` goes through numpy's
    generic pairwise reduction, which costs ~7x more than W strided adds
    for the tiny W (2-16 words) these masks have."""
    c = _popcount(masked)
    if c.ndim == 1:
        return c.astype(np.int64)
    inter = c[:, 0].astype(np.int64)
    for w in range(1, c.shape[1]):
        inter += c[:, w]
    return inter


# ----------------------------------------------------------- packed patterns
def pack_block_patterns(csr: sp.csr_matrix, block_w: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-row block-column sets as packed uint64 bitmasks.

    Returns (packed [n, n_words], popcount [n], first_block_col [n];
    -1 for empty rows).  One row of ``packed`` is the indicator of the
    row's nonzero block-columns — the set the greedy clustering works on.
    """
    n, m = csr.shape
    nbc = -(-m // block_w)
    n_words = max(-(-nbc // 64), 1)
    indptr = np.asarray(csr.indptr)
    lens = np.diff(indptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), lens)
    bcols = np.asarray(csr.indices, dtype=np.int64) // block_w
    # dense indicator -> packbits: one C pass, no ufunc.at scatter.  The
    # bcol -> bit mapping is packbits's big-endian byte order; every
    # consumer only ANDs/ORs/popcounts the masks, so any fixed bijection
    # is fine.
    ind = np.zeros((n, nbc), bool)
    ind[rows, bcols] = True
    packed8 = np.packbits(ind, axis=1)
    if packed8.shape[1] != n_words * 8:
        packed8 = np.pad(packed8,
                         ((0, 0), (0, n_words * 8 - packed8.shape[1])))
    packed = np.ascontiguousarray(packed8).view(np.uint64)
    pop = _row_popcount(packed)
    has = lens > 0
    if getattr(csr, "has_sorted_indices", False):
        first = np.full(n, -1, np.int64)
        first[has] = bcols[indptr[:-1][has]]   # min bcol: indices sorted
    else:
        first = np.where(has, ind.argmax(axis=1), -1).astype(np.int64)
    return packed, pop, first


# ------------------------------------------------------ vectorized clustering
def jaccard_rows_fast(csr: sp.csr_matrix, block_w: int = 128,
                      tau: float = 0.7,
                      max_candidates: Optional[int] = None) -> np.ndarray:
    """Greedy Jaccard row clustering on packed bitmasks (paper IV-C).

    Same greedy scheme as ``reorder.jaccard_rows``: open a cluster at the
    first unclustered row (rows pre-ordered by first block-column), merge
    every candidate whose Jaccard distance to the cluster's column-pattern
    union is below ``tau``, with ``max_candidates`` capping the scan window
    per cluster.  With the ``core.native`` kernel available, the reference
    single-pass greedy runs verbatim — permutations are bit-identical to
    ``reorder.jaccard_rows``.

    The numpy fallback replaces the reference's sequential growing-union
    pass with batched ROUNDS to a fixpoint (each round tests all remaining
    candidates against the current union, joins them together, repeats
    until nothing joins).  A candidate rejected mid-pass by the reference
    can therefore join in a later round here (and vice versa), so the
    fallback's clustering may differ slightly from the reference —
    typically reducing blocks as well or better; same tau/max_candidates
    meaning.  Within the rounds scheme these steps are exact (not
    heuristic):
      * the accept test is the cross-form ``inter > (1-tau)*union``
        (same predicate as ``1 - inter/union < tau``, no division);
      * union-growth rounds update intersections incrementally — only the
        words the union actually gained (``delta``) are re-popcounted, and
        a round where the union does not grow is a fixpoint;
      * candidates with ``pop <= (1-tau) * |union|`` are dropped
        permanently (they can never pass: inter <= pop and the union only
        grows).
    """
    n = csr.shape[0]
    if n == 0:
        return np.zeros(0, np.int64)
    packed, pop, first = pack_block_patterns(csr, block_w)
    order = np.argsort(first, kind="stable").astype(np.int64)
    # native kernel (core.native): the exact reference single-pass greedy
    # over these bitmasks, compiled at first use; None without a toolchain
    native_perm = native.jaccard_cluster(
        np.ascontiguousarray(packed[order]), pop[order], tau,
        max_candidates)
    if native_perm is not None:
        return order[native_perm]
    # working copies in scan order.  Compaction is LAZY: clustered rows are
    # masked out via ``alive`` and the arrays are physically rebuilt only
    # once >40% of rows died — round 1 stays a contiguous slice op and the
    # O(R) copies happen ~log times total instead of once per cluster.
    rem_ids = order
    rem_packed = np.ascontiguousarray(packed[order])
    rem_pop = pop[order]
    rem_first = first[order]                   # nondecreasing
    alive = np.ones(n, bool)
    n_alive = n
    start = 0                   # first alive position
    perm = np.empty(n, np.int64)
    out = 0
    one_m_tau = 1.0 - tau
    while n_alive:
        if n_alive < 0.6 * rem_ids.size:        # compact
            rem_ids = rem_ids[alive]
            rem_packed = np.ascontiguousarray(rem_packed[alive])
            rem_pop = rem_pop[alive]
            rem_first = rem_first[alive]
            alive = np.ones(n_alive, bool)
            start = 0
        while not alive[start]:
            start += 1
        R = rem_ids.size
        pc = rem_packed[start].copy()
        pc_pop = int(rem_pop[start])
        perm[out] = rem_ids[start]
        out += 1
        alive[start] = False
        n_alive -= 1
        if max_candidates is None or max_candidates >= n_alive:
            cap_end = R
        else:                   # cap counts ALIVE candidates, like the ref
            cnt = np.cumsum(alive[start + 1:])
            cap_end = min(
                start + 2 + int(np.searchsorted(cnt, max_candidates)), R)
        # exact window bound: candidates are sorted by first block-col, so
        # anything whose first col exceeds the union's max col has empty
        # intersection (dist 1) and cannot join; the window re-extends when
        # the union grows
        scan_end = start + 1
        cand = np.arange(0)
        inter = c_pop = np.arange(0)
        live = np.zeros(0, bool)

        def _extend(scan_end, cand, inter, c_pop, live, pc, pc_pop):
            hi = int(np.searchsorted(rem_first, _max_bcol(pc), "right"))
            hi = max(min(cap_end, hi), scan_end)
            if hi > scan_end:
                ext = np.arange(scan_end, hi)
                # fresh candidates: full intersection against current pc
                # (one contiguous pass — dead rows are wasted AND lanes,
                # bounded by the 60% compaction threshold)
                inter = np.concatenate([
                    inter, _row_popcount(rem_packed[scan_end:hi] & pc)])
                cand = np.concatenate([cand, ext])
                c_pop = np.concatenate([c_pop, rem_pop[scan_end:hi]])
                live = np.concatenate([live, alive[scan_end:hi]])
            return hi, cand, inter, c_pop, live

        if pc_pop == 0:
            # empty-pattern seed: no column span, but empty candidates
            # (union == 0 -> dist 0) join when tau > 0; they sort first
            hi = int(np.searchsorted(rem_first, -1, "right"))
            hi = max(min(cap_end, hi), scan_end)
            cand = np.arange(scan_end, hi)
            inter = np.zeros(cand.size, np.int64)
            c_pop = rem_pop[scan_end:hi]
            live = alive[scan_end:hi].copy()
            scan_end = hi
        else:
            scan_end, cand, inter, c_pop, live = _extend(
                scan_end, cand, inter, c_pop, live, pc, pc_pop)
        while cand.size:
            union = c_pop + pc_pop - inter
            # dist < tau  <=>  inter > (1-tau) * union, with the union==0
            # corner (both patterns empty -> dist 0) accepted when tau > 0
            accept = inter > one_m_tau * union
            if pc_pop == 0 and tau > 0:
                accept |= union == 0
            accept &= live
            if not accept.any():
                break
            jpos = cand[accept]
            perm[out:out + jpos.size] = rem_ids[jpos]
            out += jpos.size
            alive[jpos] = False
            n_alive -= jpos.size
            delta = np.bitwise_or.reduce(rem_packed[jpos], axis=0) & ~pc
            keep = ~accept & live
            cand, inter, c_pop = cand[keep], inter[keep], c_pop[keep]
            live = np.ones(cand.size, bool)
            if not delta.any():
                # union unchanged -> distances unchanged: fixpoint
                break
            pc |= delta
            pc_pop = int(_popcount(pc).sum())
            bound = c_pop > one_m_tau * pc_pop
            cand, inter, c_pop = cand[bound], inter[bound], c_pop[bound]
            live = live[bound]
            if cand.size:
                # incremental: pc gained exactly delta (disjoint from the
                # old pc), so inter grows by the overlap with delta's
                # nonzero words only
                dw = np.flatnonzero(delta)
                inter = inter + _row_popcount(
                    rem_packed[cand][:, dw] & delta[dw])
            scan_end, cand, inter, c_pop, live = _extend(
                scan_end, cand, inter, c_pop, live, pc, pc_pop)
    assert out == n
    return perm


def jaccard_rows_cols_fast(csr: sp.csr_matrix,
                           block: Tuple[int, int] = (128, 128),
                           tau: float = 0.7,
                           max_candidates: Optional[int] = None
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Row+column ablation (paper VI-F) on the fast clustering: cluster
    rows, then columns of the row-permuted matrix."""
    row_perm = jaccard_rows_fast(csr, block[1], tau, max_candidates)
    permuted = csr[row_perm]
    col_perm = jaccard_rows_fast(permuted.T.tocsr(), block[0], tau,
                                 max_candidates)
    return row_perm, col_perm


# --------------------------------------------------- block-row level schemes
def _block_indicator(csr: sp.csr_matrix, block: Tuple[int, int]
                     ) -> sp.csr_matrix:
    """Block-granularity indicator: (n_block_rows, n_block_cols) CSR with a
    stored 1 wherever the blocked matrix has a nonzero block."""
    h, w = block
    n, m = csr.shape
    nbr, nbc = -(-n // h), -(-m // w)
    coo = csr.tocoo()
    brow = coo.row // h
    bcol = coo.col // w
    data = np.ones(brow.size, np.int8)
    ind = sp.coo_matrix((data, (brow, bcol)), shape=(nbr, nbc))
    ind.sum_duplicates()
    return ind.tocsr()


def _pin_partial_last(brperm: np.ndarray, nbr: int, partial: bool
                      ) -> np.ndarray:
    """Keep a partial trailing block-row at the end so expanding a block-row
    permutation to element rows never shifts full blocks across block
    boundaries."""
    if not partial:
        return brperm
    last = nbr - 1
    return np.concatenate([brperm[brperm != last], [last]])


def _expand_block_row_perm(brperm: np.ndarray, h: int, n_rows: int
                           ) -> np.ndarray:
    """Block-row permutation -> element row permutation (the partial
    trailing block-row, if any, must already be pinned last)."""
    return np.concatenate(
        [np.arange(br * h, min((br + 1) * h, n_rows)) for br in brperm]
    ).astype(np.int64)


def shard_bins(bpr: np.ndarray, n_shards: int, *,
               rows_per_shard: Optional[int] = None,
               max_load: Optional[int] = None) -> np.ndarray:
    """Capacitated equal-cardinality LPT: block-row -> shard assignment.

    The bin-assignment primitive behind ``shard_balance`` (and the
    partitioned execution path in ``launch.dist_spmm``): block-rows are
    placed heaviest-first onto the least-loaded shard, subject to every
    shard receiving at most ``rows_per_shard`` block-rows (default
    ``ceil(n_brows / n_shards)``).  The cardinality cap is what makes the
    partition STATIC-shape friendly — each shard owns exactly
    ``rows_per_shard`` block-row slots (trailing slots virtual/empty), so
    per-shard operands keep fixed shapes across structures of the same
    dims.

    ``max_load`` optionally caps per-shard nonzero-block counts (the
    model-weight path derives it from dims so scan-stacked layers share
    leaf shapes); assignment that cannot fit raises rather than silently
    producing ragged shards.

    Returns ``assign [n_brows] int64`` with values in ``[0, n_shards)``.
    """
    bpr = np.asarray(bpr, dtype=np.int64)
    n_brows = bpr.size
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    rps = rows_per_shard or -(-max(n_brows, 1) // n_shards)
    if rps * n_shards < n_brows:
        raise ValueError(
            f"rows_per_shard={rps} x n_shards={n_shards} cannot hold "
            f"{n_brows} block-rows")
    order = np.argsort(-bpr, kind="stable")   # heaviest first
    load = np.zeros(n_shards, dtype=np.int64)
    count = np.zeros(n_shards, dtype=np.int64)
    assign = np.empty(n_brows, dtype=np.int64)
    for br in order:
        elig = count < rps
        if max_load is not None:
            fits = elig & (load + bpr[br] <= max_load)
            if fits.any():
                elig = fits
            elif not elig.any():
                raise ValueError("shard_bins: no shard has row capacity left")
            else:
                raise ValueError(
                    f"shard_bins: block-row with {int(bpr[br])} blocks "
                    f"cannot fit any shard under max_load={max_load} "
                    f"(loads={load.tolist()}); raise the per-shard nnzb "
                    "budget or lower n_shards")
        cand = np.flatnonzero(elig)
        s = cand[np.argmin(load[cand])]
        assign[br] = s
        load[s] += bpr[br]
        count[s] += 1
    return assign


def split_heavy_rows(bpr: np.ndarray, max_load: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Entry-granular fragments of a block-row load vector.

    The LPT in :func:`shard_bins` places whole block-rows, so a single
    block-row heavier than the per-shard budget can never fit — the
    extreme-skew failure mode of the partitioned execution path
    (``launch.dist_spmm``).  This splits each such row into near-equal
    CONTIGUOUS entry ranges of at most ``max_load`` blocks; the fragments
    are what the LPT then places (the row's partial products recombine
    with a sum at gather time).

    Returns ``(frag_row, frag_start, frag_len)``, one entry per fragment
    in ascending (row, start) order: the owning block-row, the offset of
    the fragment's first entry within the row, and its entry count.  Rows
    at or under ``max_load`` come back as a single fragment, so with no
    heavy row this is the identity table ``(arange, zeros, bpr)``.

    >>> import numpy as np
    >>> fr, fs, fl = split_heavy_rows(np.array([2, 7, 1]), 3)
    >>> fr.tolist(), fs.tolist(), fl.tolist()
    ([0, 1, 1, 1, 2], [0, 0, 3, 5, 0], [2, 3, 2, 2, 1])
    """
    if max_load < 1:
        raise ValueError(f"max_load must be >= 1, got {max_load}")
    bpr = np.asarray(bpr, dtype=np.int64)
    rows, starts, lens = [], [], []
    for r, load in enumerate(bpr):
        load = int(load)
        k = max(-(-load // int(max_load)), 1)
        base, rem = divmod(load, k)
        off = 0
        for i in range(k):
            size = base + (1 if i < rem else 0)
            rows.append(r)
            starts.append(off)
            lens.append(size)
            off += size
    return (np.asarray(rows, np.int64), np.asarray(starts, np.int64),
            np.asarray(lens, np.int64))


def shard_balance_rows(csr: sp.csr_matrix, block: Tuple[int, int] = (128, 128),
                       n_shards: int = 8) -> np.ndarray:
    """Element-row permutation from the block-row LPT shard balancing
    (``reorder.shard_balance``): block-rows are packed so per-shard
    nonzero-block counts even out; rows inside a block-row keep their order
    (block density untouched)."""
    h, _ = block
    ind = _block_indicator(csr, block)
    rowptr = np.asarray(ind.indptr)
    nbr = ind.shape[0]
    brperm = _shard_balance_brows(None, rowptr, n_shards)
    brperm = _pin_partial_last(brperm, nbr, csr.shape[0] % h != 0)
    return _expand_block_row_perm(brperm, h, csr.shape[0])


# --------------------------------------------------------------- BCSR entry
def _bcsr_permute_block_rows(a: bcsr_lib.BCSR, brperm: np.ndarray
                             ) -> bcsr_lib.BCSR:
    """Permute whole block-rows of a BCSR in place of a CSR round-trip:
    exact same blocks, relabeled and re-sorted — nnzb is preserved."""
    new_rows = invert_perm(brperm)[a.row_ids].astype(np.int32)
    order = np.lexsort((a.col_ids, new_rows))
    vals = a.vals[order]
    col_ids = a.col_ids[order].astype(np.int32)
    row_ids = new_rows[order]
    rowptr = bcsr_lib.rowptr_from_rows(row_ids, a.n_block_rows)
    return bcsr_lib.BCSR(vals, col_ids, row_ids, rowptr, a.shape, a.block)


def _block_row_perm(a: bcsr_lib.BCSR, scheme: str, tau: float,
                    max_candidates: Optional[int], n_shards: int
                    ) -> np.ndarray:
    """Block-row permutation for a scheme, computed on the block structure
    (patterns are block-granular already, so the bitmask clustering runs
    with block_w=1 on the indicator matrix)."""
    nbr = a.n_block_rows
    if scheme == "shard_balance":
        return _shard_balance_brows(a.row_ids, a.rowptr, n_shards)
    ind = sp.csr_matrix(
        (np.ones(a.nnzb, np.int8), a.col_ids, a.rowptr),
        shape=(nbr, a.n_block_cols))
    if scheme == "jaccard":
        return jaccard_rows_fast(ind, block_w=1, tau=tau,
                                 max_candidates=max_candidates)
    if scheme == "rcm":
        graph = (ind @ ind.T).tocsr()   # block-row connectivity (square)
        return np.asarray(sp.csgraph.reverse_cuthill_mckee(
            graph, symmetric_mode=True), dtype=np.int64)
    raise ValueError(f"scheme {scheme!r} has no block-row form")


def permute_bcsr(a: bcsr_lib.BCSR, scheme: str = "jaccard", *,
                 tau: float = 0.7, max_candidates: Optional[int] = None,
                 n_shards: int = 8, granularity: str = "element"
                 ) -> Tuple[bcsr_lib.BCSR, np.ndarray]:
    """Apply a registered reorder scheme to a host BCSR.

    Returns ``(a_permuted, row_perm)`` with ``a_permuted[i] ==
    a[row_perm[i]]`` row-wise.  ``granularity="element"`` permutes
    individual rows and re-blocks from the NONZERO structure
    (block-densifying — nnzb can change; explicitly-stored zero blocks do
    NOT survive the re-block, so their entries leave the trainable
    support); ``granularity="block_row"`` permutes whole block-rows (nnzb
    and every stored entry preserved exactly — the form model weights use
    so stacked leaf shapes stay static and zero blocks stay trainable).
    ``shard_balance`` is inherently block-granular and ignores
    ``granularity``.
    """
    if scheme not in SCHEMES:
        raise ValueError(f"unknown reorder scheme {scheme!r}; "
                         f"options: {sorted(SCHEMES)}")
    n_rows = a.shape[0]
    if scheme == "identity":
        return a, np.arange(n_rows, dtype=np.int64)
    h = a.block[0]
    if granularity == "block_row" or scheme == "shard_balance":
        brperm = _block_row_perm(a, scheme, tau, max_candidates, n_shards)
        brperm = _pin_partial_last(brperm, a.n_block_rows, n_rows % h != 0)
        return (_bcsr_permute_block_rows(a, brperm),
                _expand_block_row_perm(brperm, h, n_rows))
    if granularity != "element":
        raise ValueError(f"granularity must be 'element' or 'block_row', "
                         f"got {granularity!r}")
    csr = a.to_scipy()
    perm = SCHEMES[scheme](csr, block=a.block, tau=tau,
                           max_candidates=max_candidates, n_shards=n_shards)
    if isinstance(perm, tuple):
        raise ValueError(
            f"scheme {scheme!r} returns a column permutation too; "
            "prepare_sparse only supports row permutations (the paper "
            "rejects column permutation — it would permute B)")
    perm = np.asarray(perm, dtype=np.int64)
    return bcsr_lib.from_scipy(csr[perm].tocsr(), a.block), perm


def invert_perm(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=perm.dtype)
    return inv


# ------------------------------------------------------------------ registry
# THE dispatch table (satellite: single source of dispatch — ``reorder()``
# and ``prepare_sparse(reorder=...)`` both consume it; re-exported as
# ``repro.core.SCHEMES`` and ``reorder.SCHEMES``).  Uniform signature:
#   fn(csr, *, block=(h, w), tau, max_candidates, n_shards)
#     -> row_perm  |  (row_perm, col_perm)
def _s_identity(csr, *, block=(128, 128), tau=0.7, max_candidates=None,
                n_shards=8):
    return _identity_rows(csr)


def _s_jaccard(csr, *, block=(128, 128), tau=0.7, max_candidates=None,
               n_shards=8):
    return jaccard_rows_fast(csr, block_w=block[1], tau=tau,
                             max_candidates=max_candidates)


def _s_jaccard_rows_cols(csr, *, block=(128, 128), tau=0.7,
                         max_candidates=None, n_shards=8):
    return jaccard_rows_cols_fast(csr, block=block, tau=tau,
                                  max_candidates=max_candidates)


def _s_rcm(csr, *, block=(128, 128), tau=0.7, max_candidates=None,
           n_shards=8):
    return _rcm_rows(csr)


def _s_shard_balance(csr, *, block=(128, 128), tau=0.7, max_candidates=None,
                     n_shards=8):
    return shard_balance_rows(csr, block=block, n_shards=n_shards)


SCHEMES: Dict[str, object] = {
    "identity": _s_identity,
    "jaccard": _s_jaccard,
    "jaccard_rows_cols": _s_jaccard_rows_cols,
    "rcm": _s_rcm,
    "shard_balance": _s_shard_balance,
}
