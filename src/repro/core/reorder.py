"""Block-densifying matrix reordering (paper Section IV-C).

SMaT preprocesses the sparse matrix with a row permutation ``A' = P A`` that
minimizes the number of nonzero BCSR blocks.  The paper evaluates several
schemes and settles on Sylos Labini et al.'s greedy Jaccard-similarity row
clustering; it also ablates row+column permutation and rejects the column part
(insufficient block reduction vs. the cost of permuting B).

We implement:
  * ``jaccard_rows``   — Sylos Labini greedy clustering (paper's choice).
  * ``jaccard_rows_cols`` — the paper's row+column ablation.
  * ``rcm``            — Reverse Cuthill-McKee (bandwidth minimization).
  * ``identity``       — no-op (band matrices are already block-dense).
  * ``shard_balance``  — beyond-paper: reorder *clusters* so nonzero blocks
    are balanced across mesh shards (the TPU analogue of the paper's
    warp-load-balance observation on ``mip1``).

All routines operate host-side on scipy CSR and return permutation arrays;
they run once at preprocessing time, exactly as in the paper.

NOTE: the implementations here are the O(n^2) pure-Python *references*.
The production pipeline (``ops.prepare_sparse(reorder=...)``) dispatches
through ``core.permute.SCHEMES``, whose ``jaccard`` entry is the vectorized
packed-bitmask clustering; ``reorder()`` below uses the same table.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp


# --------------------------------------------------------------------- helpers
def _row_block_patterns(csr: sp.csr_matrix, block_w: int):
    """Per-row sorted arrays of *block-column* indices (the clustering works on
    block granularity: two rows are similar if their nonzero block-columns
    overlap)."""
    indptr, indices = csr.indptr, csr.indices
    out = []
    for r in range(csr.shape[0]):
        cols = indices[indptr[r]:indptr[r + 1]] // block_w
        out.append(np.unique(cols))
    return out


def _jaccard_distance(a: np.ndarray, b_set: set) -> float:
    if len(a) == 0 and len(b_set) == 0:
        return 0.0
    inter = sum(1 for x in a if x in b_set)
    union = len(a) + len(b_set) - inter
    return 1.0 - inter / union if union else 0.0


# ------------------------------------------------------- Sylos Labini greedy
def jaccard_rows(csr: sp.csr_matrix, block_w: int = 128, tau: float = 0.7,
                 max_candidates: Optional[int] = None) -> np.ndarray:
    """Greedy Jaccard row clustering (Sylos Labini et al., paper IV-C).

    Iteratively: open a cluster with the first unclustered row; merge every
    unclustered row whose Jaccard distance to the cluster's column-pattern
    union is below ``tau``.  Returns the row permutation (cluster
    concatenation order).

    ``max_candidates`` caps the scan per cluster for very large matrices
    (candidate rows are pre-bucketed by their first block-column, which keeps
    the scan near-linear in practice without changing results much).
    """
    n = csr.shape[0]
    patterns = _row_block_patterns(csr, block_w)
    unclustered = np.ones(n, dtype=bool)
    # bucket rows by first block-col so cluster scans touch plausible rows 1st
    first_col = np.array([p[0] if len(p) else -1 for p in patterns])
    order_by_first = np.argsort(first_col, kind="stable")
    perm = []
    for seed in order_by_first:
        if not unclustered[seed]:
            continue
        unclustered[seed] = False
        cluster = [seed]
        pc = set(patterns[seed].tolist())
        scanned = 0
        for cand in order_by_first:
            if not unclustered[cand]:
                continue
            scanned += 1
            if max_candidates is not None and scanned > max_candidates:
                break
            if _jaccard_distance(patterns[cand], pc) < tau:
                unclustered[cand] = False
                cluster.append(cand)
                pc.update(patterns[cand].tolist())
        perm.extend(cluster)
    return np.asarray(perm, dtype=np.int64)


def jaccard_rows_cols(csr: sp.csr_matrix, block: Tuple[int, int] = (128, 128),
                      tau: float = 0.7) -> Tuple[np.ndarray, np.ndarray]:
    """Paper ablation: cluster rows, then apply the same procedure to columns
    of the row-permuted matrix.  Returns (row_perm, col_perm)."""
    row_perm = jaccard_rows(csr, block[1], tau)
    permuted = csr[row_perm]
    col_perm = jaccard_rows(permuted.T.tocsr(), block[0], tau)
    return row_perm, col_perm


# --------------------------------------------------------------------- others
def rcm(csr: sp.csr_matrix) -> np.ndarray:
    """Reverse Cuthill-McKee bandwidth-minimizing permutation [29].

    scipy's RCM needs a square adjacency; rectangular matrices use the
    row-connectivity graph A A^T (rows adjacent when they share a column)."""
    n, m = csr.shape
    if n == m:
        sym = csr + csr.T
    else:
        sym = csr @ csr.T
    return np.asarray(
        sp.csgraph.reverse_cuthill_mckee(sym.tocsr(), symmetric_mode=True),
        dtype=np.int64)


def identity(csr: sp.csr_matrix) -> np.ndarray:
    return np.arange(csr.shape[0], dtype=np.int64)


def shard_balance(row_ids: np.ndarray, rowptr: np.ndarray,
                  n_shards: int) -> np.ndarray:
    """Beyond-paper: permute *block-rows* so per-shard nonzero-block counts are
    balanced (greedy LPT bin packing).  Returns a block-row permutation; rows
    inside a block-row keep their order so block density is untouched.

    This is the mesh-level analogue of the paper's observation that ``mip1``'s
    8.4x stddev reduction (load balance across warps) mattered more than the
    1.8x block-count reduction.
    """
    bpr = np.diff(rowptr)
    n_brows = bpr.size
    order = np.argsort(-bpr, kind="stable")  # heaviest first
    shard_load = np.zeros(n_shards, dtype=np.int64)
    shard_members: list[list[int]] = [[] for _ in range(n_shards)]
    for br in order:
        s = int(np.argmin(shard_load))
        shard_load[s] += bpr[br]
        shard_members[s].append(int(br))
    perm = [br for members in shard_members for br in sorted(members)]
    return np.asarray(perm, dtype=np.int64)


# ------------------------------------------------------------------ dispatcher
def reorder(csr: sp.csr_matrix, scheme: str = "jaccard",
            block_w: int = 128, tau: float = 0.7, **opts) -> np.ndarray:
    """Dispatch through the single ``SCHEMES`` table (defined in
    ``core.permute``, which maps ``jaccard`` to the vectorized bitmask
    clustering).  Extra ``opts`` (``max_candidates``, ``n_shards``) pass
    straight to the scheme."""
    from repro.core import permute  # local: permute imports this module
    if scheme not in permute.SCHEMES:
        raise ValueError(f"unknown reorder scheme {scheme!r}; "
                         f"options: {sorted(permute.SCHEMES)}")
    return permute.SCHEMES[scheme](csr, block=(block_w, block_w), tau=tau,
                                   **opts)


def apply_perm(csr: sp.csr_matrix, row_perm: Optional[np.ndarray] = None,
               col_perm: Optional[np.ndarray] = None) -> sp.csr_matrix:
    out = csr
    if row_perm is not None:
        out = out[row_perm]
    if col_perm is not None:
        out = out[:, col_perm]
    return out.tocsr()


# The single dispatch table lives in ``core.permute`` (it maps ``jaccard``
# to the vectorized implementation and registers ``jaccard_rows_cols`` /
# ``shard_balance``); ``reorder.SCHEMES`` resolves to it lazily (PEP 562)
# because ``permute`` imports the reference routines defined above.
def __getattr__(name):
    if name == "SCHEMES":
        from repro.core.permute import SCHEMES
        return SCHEMES
    raise AttributeError(name)
