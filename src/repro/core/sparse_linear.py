"""BlockSparseLinear — the paper's technique as a first-class model layer.

A linear layer ``y = x @ W^T (+ b)`` whose weight ``W [out, in]`` is stored in
BCSR and multiplied with the SMaT kernels: the forward pass is
``C = W @ x^T`` (sparse x dense SpMM), the backward pass uses the transposed
block structure (dx) and the SDDMM kernel (dW) — all through
``kernels.ops.spmm``'s custom VJP.

Patterns are generated with exact nnzb and full row/col coverage so layers
can be stacked along a scan axis (all leaves share shapes).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcsr as bcsr_lib
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SparsitySpec:
    """Config for a block-sparse weight (the paper's technique toggle).

    ``backend="auto"`` routes every apply through the
    ``repro.kernels.autotune`` registry: the variant and N-tile are picked
    from the weight's structure fingerprint (cached analytic pick unless a
    measured sweep already ran).  ``tune_n > 0`` additionally runs the
    timed micro-sweep once at ``init_sparse_linear`` time with ``N =
    tune_n`` — set it to the expected activation token count (batch x seq
    of a training/serving step) so the warmed cache bucket is the one
    apply-time lookups actually hit.

    ``reorder`` applies a block-row permutation to the weight at init
    (``core.permute.SCHEMES``: jaccard | rcm | shard_balance | identity).
    Block-row granularity keeps nnzb static, so scan-stacked layers keep
    sharing leaf shapes; ``ops.spmm`` un-permutes outputs, so the layer's
    math is unchanged.  ``shard_balance`` balances per-shard nonzero-block
    loads over ``reorder_shards`` shards (0 = derive from the runtime
    device count via ``launch.sharding.spmm_shard_count``).
    """
    density: float = 0.1            # fraction of nonzero blocks
    block: Tuple[int, int] = (128, 128)
    backend: str = "pallas"         # pallas | row_loop | xla | dense | auto
    bn: int = 512
    interpret: bool = False
    tune_n: int = 0                 # measured sweep at init for this N
    reorder: str = "identity"       # weight row-permutation scheme
    reorder_shards: int = 0         # shard_balance bins (0 = auto)


def _nnzb_for(spec: SparsitySpec, out_dim: int, in_dim: int) -> int:
    h, w = spec.block
    nbr, nbc = -(-out_dim // h), -(-in_dim // w)
    nnzb = int(round(spec.density * nbr * nbc))
    nnzb = max(nnzb, max(nbr, nbc))
    # round up to a multiple of 16 so the nnz dimension shards over the
    # `model` mesh axis (dropped to the cap when the matrix is tiny)
    nnzb = min(-(-nnzb // 16) * 16, nbr * nbc)
    return nnzb


def _reorder_shards(spec: SparsitySpec) -> int:
    if spec.reorder_shards:
        return spec.reorder_shards
    from repro.launch.sharding import spmm_shard_count  # local: layering
    return spmm_shard_count()


def init_sparse_linear(key: int, in_dim: int, out_dim: int,
                       spec: SparsitySpec, dtype=jnp.bfloat16):
    """Returns (params, meta): params is a pytree of device arrays (vals is
    the trainable leaf; index arrays — including the ``reorder`` row
    permutation — ride along), meta is static."""
    a = bcsr_lib.random_bcsr_exact(
        key, (out_dim, in_dim), spec.block, _nnzb_for(spec, out_dim, in_dim),
        dtype=np.float32)
    n_shards = _reorder_shards(spec)
    # block_row granularity: the permutation relabels whole block-rows, so
    # nnzb (and every leaf shape) matches sparse_linear_specs exactly
    arrays, meta = ops.prepare_sparse(
        a, dtype=dtype, reorder=spec.reorder,
        reorder_granularity="block_row", n_shards=n_shards)
    if spec.backend == "auto" and spec.tune_n > 0:
        from repro.kernels import autotune
        autotune.get_autotuner().tune(
            a, spec.tune_n, interpret=spec.interpret, reorder=spec.reorder,
            reorder_granularity="block_row", n_shards=n_shards)
    params = {
        "vals": arrays.vals,
        "row_ids": arrays.row_ids,
        "col_ids": arrays.col_ids,
        "real_mask": arrays.real_mask,
        "t_perm": arrays.t_perm,
        "t_row_ids": arrays.t_row_ids,
        "t_col_ids": arrays.t_col_ids,
        "row_perm": arrays.row_perm,
        "inv_perm": arrays.inv_perm,
    }
    return params, meta


def sparse_linear_specs(in_dim: int, out_dim: int, spec: SparsitySpec,
                        dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree (dry-run path — no host work, no allocation)."""
    h, w = spec.block
    nnzb = _nnzb_for(spec, out_dim, in_dim)
    nbr, nbc = -(-out_dim // h), -(-in_dim // w)
    sds = jax.ShapeDtypeStruct
    params = {
        "vals": sds((nnzb, h, w), dtype),
        "row_ids": sds((nnzb,), jnp.int32),
        "col_ids": sds((nnzb,), jnp.int32),
        "real_mask": sds((nnzb,), jnp.bool_),
        "t_perm": sds((nnzb,), jnp.int32),
        "t_row_ids": sds((nnzb,), jnp.int32),
        "t_col_ids": sds((nnzb,), jnp.int32),
        "row_perm": sds((out_dim,), jnp.int32),
        "inv_perm": sds((out_dim,), jnp.int32),
    }
    meta = ops.SparseMeta(shape=(out_dim, in_dim), block=spec.block,
                          n_block_rows=nbr, n_block_cols=nbc,
                          nnzb=nnzb, nnzb_t=nnzb, reorder=spec.reorder)
    return params, meta


def apply_sparse_linear(params: dict, meta: ops.SparseMeta, x: jnp.ndarray,
                        spec: SparsitySpec) -> jnp.ndarray:
    """y[..., out] = x[..., in] @ W^T via C = W @ x^T.

    The token dim of the SpMM is sharded over ALL mesh axes (weights are
    replicated — see launch/sharding.py BCSR rules): each chip streams the
    full nonzero-block list against its token slice, which is exactly the
    paper's kernel with B = the local activation panel (§Perf C2)."""
    from repro.launch.constrain import BATCH, MODEL, constrain
    arrays = ops.SparseArrays(
        vals=params["vals"], row_ids=params["row_ids"],
        col_ids=params["col_ids"], real_mask=params["real_mask"],
        t_perm=params["t_perm"], t_row_ids=params["t_row_ids"],
        t_col_ids=params["t_col_ids"],
        row_perm=params.get("row_perm"), inv_perm=params.get("inv_perm"))
    lead = x.shape[:-1]
    in_dim = x.shape[-1]
    xt = x.reshape(-1, in_dim).T                     # [K, T]
    xt = constrain(xt, None, BATCH + (MODEL,))       # tokens over all axes
    c = ops.spmm(arrays, meta, xt, backend=spec.backend, bn=spec.bn,
                 interpret=spec.interpret)           # [M, T]
    c = constrain(c, None, BATCH + (MODEL,))
    return c.T.reshape(*lead, meta.shape[0])


def sparse_param_flops(meta: ops.SparseMeta) -> int:
    """FLOPs per token of this layer (2 * nnzb * h * w) — used by the
    roofline's MODEL_FLOPS accounting for sparse archs."""
    h, w = meta.block
    return 2 * meta.nnzb * h * w
