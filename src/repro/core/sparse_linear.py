"""BlockSparseLinear — the paper's technique as a first-class model layer.

A linear layer ``y = x @ W^T (+ b)`` whose weight ``W [out, in]`` is stored in
BCSR and multiplied with the SMaT kernels: the forward pass is
``C = W @ x^T`` (sparse x dense SpMM), the backward pass uses the transposed
block structure (dx) and the SDDMM kernel (dW) — all through
``kernels.ops.spmm``'s custom VJP.

Patterns are generated with exact nnzb and full row/col coverage so layers
can be stacked along a scan axis (all leaves share shapes).  Patterns are
STRUCTURAL and deterministic in a python-int seed, which is what makes the
static structure-metadata pipeline work: ``sparse_linear_meta`` re-derives
the exact init-time meta (true ``max_bpr``/padding/skew stats, per-shard
``ShardedMeta``) from ``(seed, dims, spec)`` alone — no params needed — so
the model apply path (``models.layers.mlp``) dispatches on real structure
stats while the stats ride as hashable STATIC aux data, never as pytree
leaves (see ``docs/ARCHITECTURE.md``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcsr as bcsr_lib
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SparsitySpec:
    """Config for a block-sparse weight (the paper's technique toggle).

    ``backend="auto"`` routes every apply through the
    ``repro.kernels.autotune`` registry: the variant and N-tile are picked
    from the weight's structure fingerprint (cached analytic pick unless a
    measured sweep already ran).  ``tune_n > 0`` additionally runs the
    timed micro-sweep once at ``init_sparse_linear`` time with ``N =
    tune_n`` — set it to the expected activation token count (batch x seq
    of a training/serving step) so the warmed cache bucket is the one
    apply-time lookups actually hit.

    ``reorder`` applies a block-row permutation to the weight at init
    (``core.permute.SCHEMES``: jaccard | rcm | shard_balance | identity).
    Block-row granularity keeps nnzb static, so scan-stacked layers keep
    sharing leaf shapes; ``ops.spmm`` un-permutes outputs, so the layer's
    math is unchanged.  ``shard_balance`` balances per-shard nonzero-block
    loads over ``reorder_shards`` shards (0 = derive from the runtime
    device count via ``launch.sharding.spmm_shard_count``).

    ``shards > 0`` (or ``shards="auto"``) switches the layer to the
    PARTITIONED execution path (``launch.dist_spmm``): the weight is
    split over block-rows into load-balanced slices with static per-shard
    schedules, each shard resolves its own kernel variant from its REAL
    structure stats (the per-shard ``SparseMeta`` inside the returned
    ``ShardedMeta``), and the apply runs as a ``shard_map`` when a
    compatible mesh is active (``dist_spmm.use_spmm_mesh``) or as the
    in-process equivalent otherwise.  ``shards="auto"`` resolves the
    shard count through the autotuner's shard-count axis
    (``resolved_shards`` — a DIMS-ONLY pseudo meta feeds
    ``Autotuner.pick_shards``, so scan-stacked layers sharing this spec
    resolve the same S and keep identical leaf shapes).  Per-shard slice
    shapes are derived from the layer dims alone (``shard_shapes``), so
    scan-stacked layers with different structures still share every leaf
    shape.  ``shard_cols`` adds the optional 2D column split over the
    activation panel; ``shard_chunks`` sets the overlap pipeline depth
    the sharded apply runs with (``spmm_sharded(n_chunks=...)`` — chunked
    execution is bit-identical to single-shot, so the default is on).

    Example — a partitioned block-sparse layer, applied and then
    re-derived statically (no params) via ``sparse_linear_meta``:

    >>> import jax.numpy as jnp
    >>> from repro.core.sparse_linear import (SparsitySpec,
    ...     apply_sparse_linear, init_sparse_linear, sparse_linear_meta)
    >>> spec = SparsitySpec(density=0.3, block=(16, 16), backend="auto",
    ...                     shards=2)
    >>> params, meta = init_sparse_linear(0, 64, 96, spec,
    ...                                   dtype=jnp.float32)
    >>> (meta.n_shards, all(m.max_bpr > 0 for m in meta.shard_metas))
    (2, True)
    >>> x = jnp.ones((2, 3, 64), jnp.float32)
    >>> apply_sparse_linear(params, meta, x, spec).shape
    (2, 3, 96)
    >>> sparse_linear_meta(0, 64, 96, spec) == meta    # static re-derivation
    True
    """
    density: float = 0.1            # fraction of nonzero blocks
    block: Tuple[int, int] = (128, 128)
    backend: str = "pallas"         # pallas | row_loop | xla | dense | auto
    bn: int = 512
    interpret: bool = False
    tune_n: int = 0                 # measured sweep at init for this N
    reorder: str = "identity"       # weight row-permutation scheme
    reorder_shards: int = 0         # shard_balance bins (0 = auto)
    shards: object = 0              # >0 | "auto": partitioned execution
    shard_cols: int = 1             # optional column split over activations
    shard_chunks: int = 2           # overlap pipeline depth (sharded path)


def is_sharded(spec: SparsitySpec) -> bool:
    """True when the spec selects the partitioned execution path — an
    explicit shard count OR the ``"auto"`` sentinel (which may still
    resolve to S=1; the layer then runs the sharded code path with one
    shard, keeping leaf layouts uniform across a spec)."""
    return spec.shards == "auto" or \
        (isinstance(spec.shards, int) and spec.shards > 0)


def resolved_shards(spec: SparsitySpec, out_dim: int, in_dim: int,
                    max_shards: Optional[int] = None) -> int:
    """The spec's effective shard count for a layer of these dims.

    Explicit ``shards=N`` passes through; ``shards="auto"`` asks the
    autotuner's shard-count axis (``Autotuner.pick_shards``) with a
    DIMS-ONLY pseudo meta — the same ``_nnzb_for`` budget the leaf shapes
    use, deliberately NOT any one layer's drawn structure, so every
    scan-stacked layer sharing the spec resolves the same S and the leaf
    shapes stay shared.  ``max_shards`` defaults to the runtime mesh/
    device size (``launch.sharding.spmm_shard_count``); the resolution is
    deterministic in (dims, spec, max_shards) and cached under the v7
    ``shards|...`` key."""
    if not is_sharded(spec):
        return 0
    if spec.shards != "auto":
        return int(spec.shards)
    from repro.kernels import autotune
    h, w = spec.block
    nbr, nbc = -(-out_dim // h), -(-in_dim // w)
    nnzb = _nnzb_for(spec, out_dim, in_dim)
    pseudo = ops.SparseMeta(
        shape=(out_dim, in_dim), block=spec.block, n_block_rows=nbr,
        n_block_cols=nbc, nnzb=nnzb, nnzb_t=nnzb, reorder=spec.reorder)
    if max_shards is None:
        from repro.launch.sharding import spmm_shard_count  # local: layering
        max_shards = max(spmm_shard_count(), 1)
    choice = autotune.get_autotuner().pick_shards(
        pseudo, spec.tune_n or 512, max_shards=max_shards,
        n_chunks=max(spec.shard_chunks, 1))
    return choice.n_shards


def _nnzb_for(spec: SparsitySpec, out_dim: int, in_dim: int) -> int:
    h, w = spec.block
    nbr, nbc = -(-out_dim // h), -(-in_dim // w)
    nnzb = int(round(spec.density * nbr * nbc))
    nnzb = max(nnzb, max(nbr, nbc))
    # round up to a multiple of 16 so the nnz dimension shards over the
    # `model` mesh axis (dropped to the cap when the matrix is tiny)
    nnzb = min(-(-nnzb // 16) * 16, nbr * nbc)
    return nnzb


def _reorder_shards(spec: SparsitySpec) -> int:
    if spec.reorder_shards:
        return spec.reorder_shards
    from repro.launch.sharding import spmm_shard_count  # local: layering
    return spmm_shard_count()


def shard_shapes(spec: SparsitySpec, out_dim: int, in_dim: int,
                 n_shards: Optional[int] = None):
    """Dims-only per-shard static sizes: (rows_per_shard, nnzb_per_shard,
    nnzb_t_per_shard).

    Scan-stacked layers share one spec but draw different structures, so
    the per-shard budgets CANNOT depend on any one layer's LPT outcome.
    The entry budget is the balanced average plus 25% skew headroom (and a
    small-case floor) plus one slot per row for virtual-row sentinels;
    ``prepare_sharded`` raises if a structure is too skewed to fit, which
    for the near-uniform ``random_bcsr_exact`` patterns does not happen.
    ``n_shards`` overrides the spec's count (the resolved value when
    ``spec.shards="auto"``)."""
    h, w = spec.block
    S = n_shards if n_shards is not None \
        else resolved_shards(spec, out_dim, in_dim)
    nbr, nbc = -(-out_dim // h), -(-in_dim // w)
    nnzb = _nnzb_for(spec, out_dim, in_dim)
    rps = -(-nbr // S)
    eff = min(S, nbr)
    avg = -(-nnzb // eff)
    nnzb_ps = min(nnzb + rps, avg + max(avg // 4, 8) + rps)
    return rps, nnzb_ps, nnzb_ps + nbc


def _pattern_for(seed: int, in_dim: int, out_dim: int,
                 spec: SparsitySpec) -> bcsr_lib.BCSR:
    """THE weight pattern of ``(seed, dims, spec)`` — single construction
    site shared by ``init_sparse_linear`` (arrays + meta) and
    ``sparse_linear_meta`` (meta only), so the two derivations can never
    drift apart."""
    return bcsr_lib.random_bcsr_exact(
        seed, (out_dim, in_dim), spec.block,
        _nnzb_for(spec, out_dim, in_dim), dtype=np.float32)


@functools.lru_cache(maxsize=None)
def sparse_linear_meta(seed: int, in_dim: int, out_dim: int,
                       spec: SparsitySpec):
    """True structure meta of the layer ``init_sparse_linear(seed, ...)``
    builds, derived WITHOUT allocating params — memoized host work.

    Patterns are deterministic in the python-int ``seed``, so the meta
    (real ``max_bpr`` / padding / skew stats after the spec's ``reorder``;
    the full per-shard ``ShardedMeta`` when ``spec.shards > 0``) is a pure
    static function of ``(seed, dims, spec)``.  The model path uses this
    at trace time (``models.layers.mlp``) so ``backend="auto"`` resolves
    heterogeneous per-shard kernel picks and ``row_loop`` sizes its static
    schedule from the permuted structure — identically to dispatching on
    the meta ``init_sparse_linear`` returned."""
    a = _pattern_for(seed, in_dim, out_dim, spec)
    if is_sharded(spec):
        from repro.launch import dist_spmm  # local: layering
        S = resolved_shards(spec, out_dim, in_dim)
        rps, nnzb_ps, _ = shard_shapes(spec, out_dim, in_dim, n_shards=S)
        return dist_spmm.prepare_sharded_meta(
            a, S, col_shards=spec.shard_cols,
            reorder=spec.reorder, rows_per_shard=rps,
            nnzb_per_shard=nnzb_ps)
    return ops.prepare_sparse_meta(
        a, reorder=spec.reorder, reorder_granularity="block_row",
        n_shards=_reorder_shards(spec))


def _merge_two(m0: ops.SparseMeta, m1: ops.SparseMeta) -> ops.SparseMeta:
    static0 = dataclasses.replace(m0, max_bpr=0, padding_ratio_pct=0,
                                  bpr_cv_pct=0)
    static1 = dataclasses.replace(m1, max_bpr=0, padding_ratio_pct=0,
                                  bpr_cv_pct=0)
    if static0 != static1:
        raise ValueError(
            f"cannot merge metas with different static structure:\n"
            f"  {static0}\n  {static1}")
    return dataclasses.replace(
        m0, max_bpr=max(m0.max_bpr, m1.max_bpr),
        padding_ratio_pct=max(m0.padding_ratio_pct, m1.padding_ratio_pct),
        bpr_cv_pct=max(m0.bpr_cv_pct, m1.bpr_cv_pct))


def merge_sparse_metas(metas):
    """Conservative merge of per-layer structure metas into ONE stack meta.

    Scan-stacked layers share a spec (identical shapes / nnzb / budgets)
    but draw different structures; the scanned body traces once, so it
    must dispatch on a single static meta.  The merge keeps the shared
    static fields and takes the elementwise MAX of the stats
    (``max_bpr`` — so a ``row_loop`` schedule covers every layer —
    padding, and skew); for ``ShardedMeta`` the per-shard metas merge
    shard-wise, preserving cross-shard heterogeneity.  Raises if the
    metas' static structure differs (different specs were mixed)."""
    metas = list(metas)
    if not metas:
        raise ValueError("merge_sparse_metas needs at least one meta")
    first = metas[0]
    if isinstance(first, ops.SparseMeta):
        out = first
        for m in metas[1:]:
            out = _merge_two(out, m)
        return out
    # ShardedMeta: merge shard-wise (lazy import keeps core -> launch
    # layering one-directional at module load)
    from repro.launch import dist_spmm  # local: layering
    if not isinstance(first, dist_spmm.ShardedMeta):
        raise TypeError(f"unknown meta type {type(first).__name__}")
    for m in metas[1:]:
        if dataclasses.replace(m, shard_metas=()) != \
                dataclasses.replace(first, shard_metas=()):
            raise ValueError(
                "cannot merge ShardedMetas with different static structure")
    shard_metas = tuple(
        functools.reduce(_merge_two, [m.shard_metas[s] for m in metas])
        for s in range(first.n_shards))
    return dataclasses.replace(first, shard_metas=shard_metas)


def init_sparse_linear(key: int, in_dim: int, out_dim: int,
                       spec: SparsitySpec, dtype=jnp.bfloat16):
    """Returns (params, meta): params is a pytree of device arrays (vals is
    the trainable leaf; index arrays — including the ``reorder`` row
    permutation — ride along), meta is static.

    With ``spec.shards > 0`` the params carry the row-partitioned index
    structure from ``launch.dist_spmm.prepare_sharded`` instead (``vals``
    stays the flat trainable leaf) and ``meta`` is a ``ShardedMeta``.

    The returned meta carries the layer's TRUE structure stats and is
    reproducible without params: ``sparse_linear_meta(key, in_dim,
    out_dim, spec)`` returns an equal meta (the specs-vs-init contract
    ``tests/test_static_meta.py`` pins)."""
    a = _pattern_for(key, in_dim, out_dim, spec)
    if is_sharded(spec):
        from repro.launch import dist_spmm  # local: layering
        S = resolved_shards(spec, out_dim, in_dim)
        rps, nnzb_ps, _ = shard_shapes(spec, out_dim, in_dim, n_shards=S)
        sharr, smeta = dist_spmm.prepare_sharded(
            a, S, col_shards=spec.shard_cols, dtype=dtype,
            reorder=spec.reorder, rows_per_shard=rps,
            nnzb_per_shard=nnzb_ps)
        if spec.backend == "auto" and spec.tune_n > 0:
            # sharded analogue of the unsharded tune() below: measured
            # winners land under each shard's v7 fingerprint
            dist_spmm.tune_shards(sharr, smeta, spec.tune_n,
                                  interpret=spec.interpret)
        params = {
            "vals": sharr.vals,
            "shard_src": sharr.src_index,
            "shard_row_ids": sharr.row_ids,
            "shard_col_ids": sharr.col_ids,
            "shard_mask": sharr.real_mask,
            "shard_t_perm": sharr.t_perm,
            "shard_t_row_ids": sharr.t_row_ids,
            "shard_t_col_ids": sharr.t_col_ids,
            "gather_rows": sharr.gather_rows,
        }
        return params, smeta
    n_shards = _reorder_shards(spec)
    # block_row granularity: the permutation relabels whole block-rows, so
    # nnzb (and every leaf shape) matches sparse_linear_specs exactly
    arrays, meta = ops.prepare_sparse(
        a, dtype=dtype, reorder=spec.reorder,
        reorder_granularity="block_row", n_shards=n_shards)
    if spec.backend == "auto" and spec.tune_n > 0:
        from repro.kernels import autotune
        autotune.get_autotuner().tune(
            a, spec.tune_n, interpret=spec.interpret, reorder=spec.reorder,
            reorder_granularity="block_row", n_shards=n_shards)
    params = {
        "vals": arrays.vals,
        "row_ids": arrays.row_ids,
        "col_ids": arrays.col_ids,
        "real_mask": arrays.real_mask,
        "t_perm": arrays.t_perm,
        "t_row_ids": arrays.t_row_ids,
        "t_col_ids": arrays.t_col_ids,
        "row_perm": arrays.row_perm,
        "inv_perm": arrays.inv_perm,
    }
    return params, meta


def sparse_linear_specs(in_dim: int, out_dim: int, spec: SparsitySpec,
                        dtype=jnp.bfloat16, seed: Optional[int] = None):
    """ShapeDtypeStruct pytree for the layer (dry-run / scan planning).

    With ``spec.shards > 0`` the specs mirror the partitioned layout of
    ``init_sparse_linear`` exactly — every per-shard size comes from
    ``shard_shapes`` (dims only), so specs and real params always agree.

    ``seed`` controls the returned META's stats.  With the layer's actual
    init seed, the meta is the TRUE structure meta (``sparse_linear_meta``
    — real per-shard stats, real post-reorder ``max_bpr``), equal to what
    ``init_sparse_linear(seed, ...)`` returns; the params stay
    ShapeDtypeStructs either way.  With ``seed=None`` (pure dims-only
    mode, no host work at all) the stats are zero: ``auto`` dispatch falls
    back to the streaming kernel and ``row_loop`` raises — fine for
    shape/sharding proofs, wrong for kernel-choice questions."""
    if seed is not None:
        params, _ = sparse_linear_specs(in_dim, out_dim, spec, dtype)
        return params, sparse_linear_meta(seed, in_dim, out_dim, spec)
    h, w = spec.block
    nnzb = _nnzb_for(spec, out_dim, in_dim)
    nbr, nbc = -(-out_dim // h), -(-in_dim // w)
    sds = jax.ShapeDtypeStruct
    if is_sharded(spec):
        from repro.launch import dist_spmm  # local: layering
        S = resolved_shards(spec, out_dim, in_dim)
        rps, nnzb_ps, nnzb_t_ps = shard_shapes(spec, out_dim, in_dim,
                                               n_shards=S)
        params = {
            "vals": sds((nnzb, h, w), dtype),
            "shard_src": sds((S, nnzb_ps), jnp.int32),
            "shard_row_ids": sds((S, nnzb_ps), jnp.int32),
            "shard_col_ids": sds((S, nnzb_ps), jnp.int32),
            "shard_mask": sds((S, nnzb_ps), jnp.bool_),
            "shard_t_perm": sds((S, nnzb_t_ps), jnp.int32),
            "shard_t_row_ids": sds((S, nnzb_t_ps), jnp.int32),
            "shard_t_col_ids": sds((S, nnzb_t_ps), jnp.int32),
            "gather_rows": sds((out_dim,), jnp.int32),
        }
        shard_meta = ops.SparseMeta(
            shape=(rps * h, in_dim), block=spec.block, n_block_rows=rps,
            n_block_cols=nbc, nnzb=nnzb_ps, nnzb_t=nnzb_t_ps,
            reorder="identity", n_shards=S)
        meta = dist_spmm.ShardedMeta(
            shape=(out_dim, in_dim), block=spec.block, n_shards=S,
            col_shards=spec.shard_cols, rows_per_shard=rps, nnzb=nnzb,
            nnzb_per_shard=nnzb_ps, nnzb_t_per_shard=nnzb_t_ps,
            shard_metas=(shard_meta,) * S, reorder=spec.reorder)
        return params, meta
    params = {
        "vals": sds((nnzb, h, w), dtype),
        "row_ids": sds((nnzb,), jnp.int32),
        "col_ids": sds((nnzb,), jnp.int32),
        "real_mask": sds((nnzb,), jnp.bool_),
        "t_perm": sds((nnzb,), jnp.int32),
        "t_row_ids": sds((nnzb,), jnp.int32),
        "t_col_ids": sds((nnzb,), jnp.int32),
        "row_perm": sds((out_dim,), jnp.int32),
        "inv_perm": sds((out_dim,), jnp.int32),
    }
    meta = ops.SparseMeta(shape=(out_dim, in_dim), block=spec.block,
                          n_block_rows=nbr, n_block_cols=nbc,
                          nnzb=nnzb, nnzb_t=nnzb, reorder=spec.reorder)
    return params, meta


def shard_balance_report(in_dim: int, out_dim: int, spec: SparsitySpec,
                         seed: int = 7919) -> dict:
    """Per-shard nnzb balance of the layer this spec + seed would build
    (host-only; the dry-run prints it so the partition quality is visible
    before any launch)."""
    from repro.launch import dist_spmm  # local: layering
    a = _pattern_for(seed, in_dim, out_dim, spec)
    S = resolved_shards(spec, out_dim, in_dim)
    rps, _, _ = shard_shapes(spec, out_dim, in_dim, n_shards=S)
    return dist_spmm.shard_balance_stats(a, S, rows_per_shard=rps)


def apply_sparse_linear(params: dict, meta, x: jnp.ndarray,
                        spec: SparsitySpec) -> jnp.ndarray:
    """y[..., out] = x[..., in] @ W^T via C = W @ x^T.

    Unsharded: the token dim of the SpMM is sharded over ALL mesh axes
    (weights are replicated — see launch/sharding.py BCSR rules): each
    chip streams the full nonzero-block list against its token slice,
    which is exactly the paper's kernel with B = the local activation
    panel (§Perf C2).

    Sharded (``meta`` is a ``ShardedMeta``): the weight's block-rows are
    partitioned instead — each shard streams only its balanced slice, as
    a ``shard_map`` over the mesh installed by ``dist_spmm.use_spmm_mesh``
    (in-process equivalent when none is), with the activation panel
    pipelined in ``spec.shard_chunks`` overlapped column chunks
    (bit-identical to single-shot; see ``dist_spmm.spmm_sharded``)."""
    from repro.launch.constrain import BATCH, MODEL, constrain
    lead = x.shape[:-1]
    in_dim = x.shape[-1]
    xt = x.reshape(-1, in_dim).T                     # [K, T]
    if is_sharded(spec):
        from repro.launch import dist_spmm  # local: layering
        sharr = dist_spmm.ShardedArrays(
            vals=params["vals"], src_index=params["shard_src"],
            row_ids=params["shard_row_ids"], col_ids=params["shard_col_ids"],
            real_mask=params["shard_mask"], t_perm=params["shard_t_perm"],
            t_row_ids=params["shard_t_row_ids"],
            t_col_ids=params["shard_t_col_ids"],
            gather_rows=params["gather_rows"])
        mesh = dist_spmm.current_spmm_mesh()
        if mesh is None:
            # in-process fallback under a TRAINING mesh: keep the token
            # panel sharded over all ambient axes, exactly like the
            # unsharded path (each chip runs every slice against its own
            # token slice)
            xt = constrain(xt, None, BATCH + (MODEL,))
        c = dist_spmm.spmm_sharded(
            sharr, meta, xt, backend=spec.backend, bn=spec.bn,
            interpret=spec.interpret, mesh=mesh,
            n_chunks=max(spec.shard_chunks, 1))
        if mesh is None:
            c = constrain(c, None, BATCH + (MODEL,))
        return c.T.reshape(*lead, meta.shape[0])
    arrays = ops.SparseArrays(
        vals=params["vals"], row_ids=params["row_ids"],
        col_ids=params["col_ids"], real_mask=params["real_mask"],
        t_perm=params["t_perm"], t_row_ids=params["t_row_ids"],
        t_col_ids=params["t_col_ids"],
        row_perm=params.get("row_perm"), inv_perm=params.get("inv_perm"))
    xt = constrain(xt, None, BATCH + (MODEL,))       # tokens over all axes
    c = ops.spmm(arrays, meta, xt, backend=spec.backend, bn=spec.bn,
                 interpret=spec.interpret)           # [M, T]
    c = constrain(c, None, BATCH + (MODEL,))
    return c.T.reshape(*lead, meta.shape[0])


def sparse_param_flops(meta: ops.SparseMeta) -> int:
    """FLOPs per token of this layer (2 * nnzb * h * w) — used by the
    roofline's MODEL_FLOPS accounting for sparse archs."""
    h, w = meta.block
    return 2 * meta.nnzb * h * w
