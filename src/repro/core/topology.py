"""Synthetic sparse-matrix generators matching the paper's evaluation suite.

The container is offline, so SuiteSparse downloads are replaced by generators
that reproduce each test matrix's *pattern class*, size and nnz (Table I).
The band-matrix generator reproduces the paper's synthetic sweep (Section
VI-C) exactly: 16k x 16k, bandwidth 64 .. 16384.
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import scipy.sparse as sp


def band(n: int, bandwidth: int, dtype=np.float32, seed: int = 0) -> sp.csr_matrix:
    """Band matrix: a_{ij} = 0 unless |i-j| <= bandwidth (paper VI-C)."""
    rng = np.random.default_rng(seed)
    diags = []
    offsets = []
    for k in range(-bandwidth, bandwidth + 1):
        m = n - abs(k)
        diags.append(rng.standard_normal(m).astype(dtype))
        offsets.append(k)
    return sp.diags(diags, offsets, shape=(n, n), format="csr")


def band_pattern(n: int, bandwidth: int, seed: int = 0) -> sp.csr_matrix:
    """Same sparsity pattern as ``band`` but built without materializing a
    dense diagonal list (fast for large bandwidth)."""
    if bandwidth >= n - 1:
        rng = np.random.default_rng(seed)
        return sp.csr_matrix(rng.standard_normal((n, n)).astype(np.float32))
    return band(n, bandwidth, seed=seed)


def power_law(n: int, avg_nnz_row: float, alpha: float = 2.1,
              seed: int = 0) -> sp.csr_matrix:
    """Power-law (scale-free) matrix — the `dc2` circuit-simulation adversary:
    extreme row skew, most rows nearly empty, a few very dense."""
    rng = np.random.default_rng(seed)
    # zipf-distributed row degrees scaled to the target average
    deg = rng.zipf(alpha, size=n).astype(np.float64)
    deg = np.minimum(deg * (avg_nnz_row / deg.mean()), n).astype(np.int64)
    deg = np.maximum(deg, 1)
    rows = np.repeat(np.arange(n), deg)
    cols = rng.integers(0, n, size=rows.size)
    vals = rng.standard_normal(rows.size).astype(np.float32)
    m = sp.coo_matrix((vals, (rows, cols)), shape=(n, n))
    m.sum_duplicates()
    return m.tocsr()


def mesh2d(side: int, seed: int = 0) -> sp.csr_matrix:
    """5-point 2D stencil (FEM/CFD class: cant, rma10, consph analogues)."""
    n = side * side
    main = np.full(n, 4.0, np.float32)
    off1 = np.full(n - 1, -1.0, np.float32)
    off1[np.arange(1, n) % side == 0] = 0  # row breaks
    offs = np.full(n - side, -1.0, np.float32)
    return sp.diags([offs, off1, main, off1, offs],
                    [-side, -1, 0, 1, side], format="csr")


def mesh3d(side: int, seed: int = 0) -> sp.csr_matrix:
    """7-point 3D stencil (cop20k_A / shipsec1 structural class)."""
    n = side ** 3
    main = np.full(n, 6.0, np.float32)
    o1 = np.full(n - 1, -1.0, np.float32)
    o1[np.arange(1, n) % side == 0] = 0
    o2 = np.full(n - side, -1.0, np.float32)
    o3 = np.full(n - side * side, -1.0, np.float32)
    return sp.diags([o3, o2, o1, main, o1, o2, o3],
                    [-side * side, -side, -1, 0, 1, side, side * side],
                    format="csr")


def blocked_random(n: int, nnz_target: int, cluster: int = 48,
                   seed: int = 0) -> sp.csr_matrix:
    """Clustered random matrix (mip1 / pdb1HYS class: dense local blocks from
    optimization constraints / molecular contact maps) — rows in the same
    cluster share most of their column support, so reordering pays off."""
    rng = np.random.default_rng(seed)
    n_clusters = max(n // cluster, 1)
    rows_l, cols_l = [], []
    remaining = nnz_target
    per_cluster = max(nnz_target // n_clusters, 1)
    for c in range(n_clusters):
        r0 = c * cluster
        rsz = min(cluster, n - r0)
        if rsz <= 0:
            break
        # each cluster picks a few column neighborhoods
        n_nbh = rng.integers(1, 4)
        for _ in range(n_nbh):
            c0 = int(rng.integers(0, max(n - cluster, 1)))
            cnt = per_cluster // n_nbh
            rr = rng.integers(r0, r0 + rsz, size=cnt)
            cc = rng.integers(c0, min(c0 + cluster, n), size=cnt)
            rows_l.append(rr)
            cols_l.append(cc)
        remaining -= per_cluster
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    # scatter the rows so the *input* ordering does not expose the clusters —
    # this is what the Jaccard reordering has to rediscover
    scatter = rng.permutation(n)
    rows = scatter[rows]
    vals = rng.standard_normal(rows.size).astype(np.float32)
    m = sp.coo_matrix((vals, (rows, cols)), shape=(n, n))
    m.sum_duplicates()
    return m.tocsr()


# --------------------------------------------------------------- paper Table I
# Pattern-matched stand-ins for the 9 SuiteSparse matrices (offline container).
# Sizes are scaled down ~8x from the originals so the full benchmark suite runs
# on one CPU core; sparsity and pattern class match Table I.
SUITE = {
    # name:            (generator, kwargs, paper_domain)
    "mip1":        (blocked_random, dict(n=8192, nnz_target=163_000, cluster=64), "optimization"),
    "conf5_4-8x8": (band,          dict(n=6144, bandwidth=24),                    "quantum chem."),
    "cant":        (mesh2d,        dict(side=88),                                 "2D/3D mesh"),
    "pdb1HYS":     (blocked_random, dict(n=4608, nnz_target=67_000, cluster=32),  "weighted graph"),
    "rma10":       (mesh2d,        dict(side=76),                                 "fluid dynamics"),
    "cop20k_A":    (mesh3d,        dict(side=24),                                 "2D/3D mesh"),
    "consph":      (mesh3d,        dict(side=22),                                 "2D/3D mesh"),
    "shipsec1":    (mesh3d,        dict(side=26),                                 "structural"),
    "dc2":         (power_law,     dict(n=14336, avg_nnz_row=7.0),                "circuit sim."),
}


def suite_matrix(name: str, seed: int = 0) -> sp.csr_matrix:
    gen, kwargs, _ = SUITE[name]
    return gen(seed=seed, **kwargs)


def suite_all(seed: int = 0) -> Dict[str, sp.csr_matrix]:
    return {name: suite_matrix(name, seed) for name in SUITE}
