"""Deterministic synthetic LM data pipeline.

Stateless generation keyed on (seed, step, host): every host materializes
ONLY its local batch shard (true multi-host input pipeline semantics), any
step can be regenerated after a restart (checkpoint stores just the step
counter), and a background prefetch thread hides generation latency.

The token stream is not iid noise: documents are Zipf-sampled n-gram chains,
so the CE loss actually decreases during the example training runs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.3
    ngram: int = 3
    prefetch: int = 2


def _doc_tokens(rng: np.random.Generator, vocab: int, length: int,
                zipf_a: float, ngram: int) -> np.ndarray:
    """Markov-ish chain: next token = hash(prev n-gram) perturbed — gives
    learnable local structure."""
    base = rng.zipf(zipf_a, size=length).astype(np.int64)
    toks = base % vocab
    # overwrite 75% of positions with an n-gram-determined token
    for i in range(ngram, length):
        if toks[i] % 4 != 0:
            h = (toks[i - 1] * 1000003 + toks[i - 2] * 10007 +
                 toks[i - 3]) % vocab
            toks[i] = h
    return toks.astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeCell, step: int,
               data_cfg: DataConfig = DataConfig(),
               host_id: int = 0, n_hosts: int = 1,
               local_batch: Optional[int] = None) -> Dict[str, np.ndarray]:
    """The batch for ``step`` as seen by ``host_id`` (numpy, host-local)."""
    B = local_batch or (shape.global_batch // n_hosts)
    L = shape.seq_len
    rng = np.random.default_rng(
        np.random.SeedSequence([data_cfg.seed, step, host_id]))
    batch: Dict[str, np.ndarray] = {}
    if cfg.input_mode == "codebooks":
        toks = np.stack([
            np.stack([_doc_tokens(rng, cfg.vocab_size, L + 1,
                                  data_cfg.zipf_a, data_cfg.ngram)
                      for _ in range(cfg.n_codebooks)], axis=-1)
            for _ in range(B)])
        batch["tokens"] = toks[:, :-1]
        batch["labels"] = toks[:, 1:]
    elif cfg.input_mode == "tokens+patches":
        lt = L - cfg.patch_tokens
        toks = np.stack([_doc_tokens(rng, cfg.vocab_size, lt + 1,
                                     data_cfg.zipf_a, data_cfg.ngram)
                         for _ in range(B)])
        batch["tokens"] = toks[:, :-1]
        batch["labels"] = toks[:, 1:]
        batch["patch_embeds"] = rng.standard_normal(
            (B, cfg.patch_tokens, cfg.d_model)).astype(np.float32)
    else:
        toks = np.stack([_doc_tokens(rng, cfg.vocab_size, L + 1,
                                     data_cfg.zipf_a, data_cfg.ngram)
                         for _ in range(B)])
        batch["tokens"] = toks[:, :-1]
        batch["labels"] = toks[:, 1:]
    return batch


class PrefetchIterator:
    """Background-thread prefetch of ``make_batch`` (restart-safe: seeded by
    step index)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeCell,
                 data_cfg: DataConfig = DataConfig(), start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1,
                 local_batch: Optional[int] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=data_cfg.prefetch)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = make_batch(cfg, shape, step, data_cfg, host_id, n_hosts,
                               local_batch)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
