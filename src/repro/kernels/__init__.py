"""SpMM kernel package.

  bcsr_spmm — Pallas TPU kernels (nnz_stream / row_loop / sddmm)
  ref       — pure-jnp oracles (the ``xla`` backend)
  ops       — jit-ready public API (``spmm`` with custom VJP + dispatch)
  autotune  — kernel-variant registry + fingerprint-cached autotuner
              (``ops.spmm(..., backend="auto")`` routes through it)
"""
from repro.kernels import ops
from repro.kernels.ops import prepare_sparse, spmm
