"""SpMM/SDDMM kernel package.

  bcsr_spmm — Pallas TPU kernels (nnz_stream / row_loop / sddmm /
              sddmm_row_loop)
  bcsr_attn — fused one-kernel block-sparse attention (flash-style
              single launch over the static schedule; bit-for-bit equal
              to the composed SDDMM -> softmax -> SpMM triple in f32)
  ref       — pure-jnp oracles (the ``xla`` backend, dense-masked sddmm)
  ops       — jit-ready public API (``spmm`` + ``sddmm``, mutually-dual
              custom VJPs + dispatch)
  autotune  — kernel-variant registry (spmm + sddmm + attn families) +
              fingerprint-cached autotuner (v6 ``op=``-scoped keys;
              ``backend="auto"`` routes through it)
"""
from repro.kernels import ops
from repro.kernels.bcsr_attn import bcsr_attn_fused
from repro.kernels.ops import prepare_sparse, sddmm, spmm
