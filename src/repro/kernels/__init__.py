"""SpMM/SDDMM kernel package.

  bcsr_spmm — Pallas TPU kernels (nnz_stream / row_loop / sddmm /
              sddmm_row_loop)
  ref       — pure-jnp oracles (the ``xla`` backend, dense-masked sddmm)
  ops       — jit-ready public API (``spmm`` + ``sddmm``, mutually-dual
              custom VJPs + dispatch)
  autotune  — kernel-variant registry (spmm + sddmm families) +
              fingerprint-cached autotuner (v5 ``op=``-scoped keys;
              ``backend="auto"`` routes through it)
"""
from repro.kernels import ops
from repro.kernels.ops import prepare_sparse, sddmm, spmm
