"""Kernel-variant registry + autotuned SpMM dispatch.

SMaT's headline speedups come from matching the kernel schedule and tile
parameters to the matrix's block structure; a single hardcoded
(nnz_stream, bn=512) leaves that on the table.  This module provides:

  * a **registry** of SpMM kernel variants (nnz_stream / row_loop / xla
    gather-scatter / dense fallback), each with its tunable ``bn``
    candidates and dispatch constraints;
  * a **fingerprint** of a BCSR operand's structure (nnzb, padding ratio,
    blocks-per-row skew, block shape, N-bucket) — the cache key;
  * an **autotuner** that, per fingerprint, either consults the paper's
    performance model (``core.perf_model``, Eq. 1 instantiated with the TPU
    block roofline) for an analytic pick, or runs a timed micro-sweep over
    the registered candidates; decisions are cached in-memory and mirrored
    to a JSON file so benchmarks and serving reuse them across processes.

Wiring: ``ops.spmm(..., backend="auto")`` resolves through
``get_autotuner().pick`` (static info only — trace-safe); explicit
``tune()`` calls (benchmarks, offline warmup) run the measured sweep and
overwrite the analytic entry.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Iterable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bcsr as bcsr_lib
from repro.core import perf_model as pm
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

# hardcoded pre-registry default — the baseline every pick must beat
DEFAULT_VARIANT = "nnz_stream"
DEFAULT_BN = 512

# VMEM budget for one grid cell's working set (A block + B tile + f32 acc),
# conservative vs the ~128 MiB/core so double buffering always fits.
_VMEM_BUDGET = 8 * 2 ** 20


# ------------------------------------------------------------------ registry
@dataclasses.dataclass(frozen=True)
class KernelVariant:
    """One dispatchable kernel schedule.

    ``op`` names the compute family the variant belongs to (``"spmm"`` |
    ``"sddmm"`` | ``"attn"`` — picks never cross families); ``backend`` is the
    ``ops.SpmmConfig.backend`` string the variant lowers to; ``model_time``
    maps (meta, n, bn) -> predicted seconds (paper Eq. 1 terms from
    ``core.perf_model``); ``supported`` gates dispatch on static metadata
    (e.g. row_loop needs a known max_bpr).
    """
    name: str
    backend: str
    bn_candidates: Tuple[int, ...]
    model_time: Callable[[ops.SparseMeta, int, int], float]
    supported: Callable[[ops.SparseMeta], bool] = lambda meta: True
    description: str = ""
    op: str = "spmm"


_REGISTRY: Dict[str, KernelVariant] = {}


def register_variant(v: KernelVariant) -> KernelVariant:
    if v.name in _REGISTRY:
        raise ValueError(f"variant {v.name!r} already registered")
    _REGISTRY[v.name] = v
    return v


def get_variant(name: str) -> KernelVariant:
    return _REGISTRY[name]


def variant_names(op: str = "spmm") -> Tuple[str, ...]:
    """Registered variant names of one compute family (``op=None`` lists
    every family)."""
    return tuple(n for n, v in _REGISTRY.items() if op is None or v.op == op)


def _bytes_per_el(dtype=jnp.bfloat16) -> int:
    return jnp.dtype(dtype).itemsize


def _n_tiles(n: int, bn: int) -> int:
    return max(-(-n // bn), 1)  # the kernel pads N up to a bn multiple


def _t_nnz_stream(meta: ops.SparseMeta, n: int, bn: int) -> float:
    h, w = meta.block
    return pm.spmm_model_time(meta.nnzb * _n_tiles(n, bn), h, w, bn)


def _t_row_loop(meta: ops.SparseMeta, n: int, bn: int) -> float:
    # static schedule pays max_bpr slots on EVERY block-row (SMaT's dc2
    # worst case); padding DMAs still move bytes.
    h, w = meta.block
    n_e = meta.n_block_rows * max(meta.max_bpr, 1) * _n_tiles(n, bn)
    return pm.spmm_model_time(n_e, h, w, bn)


def _t_xla(meta: ops.SparseMeta, n: int, bn: int) -> float:
    # gather + einsum + segment_sum: streams every stored element with
    # blocked (coalesced) access — modeled as CSR traffic at low overhead.
    h, w = meta.block
    return pm.csr_spmm_time(meta.nnzb * h * w, n, gather_overhead=2.0)


def _t_dense(meta: ops.SparseMeta, n: int, bn: int) -> float:
    h, w = meta.block
    return pm.dense_gemm_time(meta.n_block_rows * h, meta.n_block_cols * w, n)


register_variant(KernelVariant(
    name="nnz_stream", backend="pallas", bn_candidates=(128, 256, 512, 1024),
    model_time=_t_nnz_stream,
    description="nonzero-block-streamed Pallas kernel (skew-immune)"))
register_variant(KernelVariant(
    name="row_loop", backend="row_loop", bn_candidates=(128, 256, 512),
    model_time=_t_row_loop,
    supported=lambda meta: meta.max_bpr > 0,
    description="paper-faithful static 2D schedule (loop to max_bpr)"))
register_variant(KernelVariant(
    name="xla", backend="xla", bn_candidates=(512,),
    model_time=_t_xla,
    description="pure-jnp gather/segment-sum (shardable oracle path)"))
register_variant(KernelVariant(
    name="dense", backend="dense", bn_candidates=(512,),
    model_time=_t_dense,
    description="materialized dense GEMM (cuBLAS arm; wins at high density)"))


# SDDMM family (ops.sddmm): X @ Y^T sampled at the stored blocks.  The
# contraction runs over N (the bn-tiled axis), so the per-block elementary
# cost matches the SpMM block roofline with the same (h, w, bn) tile.
def _t_sddmm_stream(meta: ops.SparseMeta, n: int, bn: int) -> float:
    h, w = meta.block
    return pm.spmm_model_time(meta.nnzb * _n_tiles(n, bn), h, w, bn)


def _t_sddmm_row_loop(meta: ops.SparseMeta, n: int, bn: int) -> float:
    # static schedule: every (block-row, slot) pair pays its product, even
    # the padding slots that land in the sentinel output block
    h, w = meta.block
    n_e = meta.n_block_rows * max(meta.max_bpr, 1) * _n_tiles(n, bn)
    return pm.spmm_model_time(n_e, h, w, bn)


def _t_sddmm_xla(meta: ops.SparseMeta, n: int, bn: int) -> float:
    h, w = meta.block
    return pm.csr_spmm_time(meta.nnzb * h * w, n, gather_overhead=2.0)


def _t_sddmm_dense(meta: ops.SparseMeta, n: int, bn: int) -> float:
    # the full M x K product, then a block gather (charged as output reread)
    h, w = meta.block
    return pm.dense_gemm_time(meta.n_block_rows * h, n,
                              meta.n_block_cols * w)


register_variant(KernelVariant(
    name="sddmm_stream", backend="pallas", op="sddmm",
    bn_candidates=(128, 256, 512, 1024), model_time=_t_sddmm_stream,
    description="nonzero-block-streamed Pallas SDDMM (skew-immune)"))
register_variant(KernelVariant(
    name="sddmm_row_loop", backend="row_loop", op="sddmm",
    bn_candidates=(128, 256, 512), model_time=_t_sddmm_row_loop,
    supported=lambda meta: meta.max_bpr > 0,
    description="paper-faithful static (block-row x slot) SDDMM schedule"))
register_variant(KernelVariant(
    name="sddmm_xla", backend="xla", op="sddmm",
    bn_candidates=(512,), model_time=_t_sddmm_xla,
    description="pure-jnp gather/einsum SDDMM (shardable oracle path)"))
register_variant(KernelVariant(
    name="sddmm_dense", backend="dense", op="sddmm",
    bn_candidates=(512,), model_time=_t_sddmm_dense,
    description="dense-masked X Y^T + block gather (near-dense structures)"))


# Attention family (models.attention.block_sparse_attention under
# ``backend="auto"``): fused one-kernel flash-style path vs the composed
# SDDMM -> softmax -> SpMM triple.  These are attention-LEVEL variants —
# their ``backend`` strings ("fused" / "composed") are resolved by
# ``models.attention.resolve_attn_impl``, not by ``ops.SpmmConfig``.
def _t_attn_fused(meta: ops.SparseMeta, n: int, bn: int) -> float:
    # one launch, three passes (max / denom / accumulate) over the static
    # (block-row x slot) schedule — row_loop-style waste on short rows,
    # but zero scores/probs HBM traffic between phases
    h, w = meta.block
    n_e = meta.n_block_rows * max(meta.max_bpr, 1) * 3
    return pm.spmm_model_time(n_e, h, w, n)


def _t_attn_composed(meta: ops.SparseMeta, n: int, bn: int) -> float:
    # skew-immune streamed SDDMM + SpMM, plus the materialized [nnzb,h,w]
    # scores/probs tensors crossing HBM twice each between the three
    # launches (write+read for scores, write+read for probs), plus the
    # two extra launch latencies
    h, w = meta.block
    t = _t_sddmm_stream(meta, n, bn) + _t_nnz_stream(meta, n, bn)
    probs_bytes = 4.0 * meta.nnzb * h * w
    return t + 4.0 * probs_bytes / pm.HBM_BW + 2 * 5e-6


register_variant(KernelVariant(
    name="attn_fused", backend="fused", op="attn",
    bn_candidates=(512,), model_time=_t_attn_fused,
    supported=lambda meta: meta.max_bpr > 0,
    description="single-launch fused SDDMM+softmax+SpMM (flash-style, "
                "O(L*d) memory)"))
register_variant(KernelVariant(
    name="attn_composed", backend="composed", op="attn",
    bn_candidates=(512,), model_time=_t_attn_composed,
    description="three-dispatch composed path (materializes scores/probs)"))


# --------------------------------------------------------------- fingerprint
def _pow2_bucket(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 0 else 0


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Structure stats that determine the best (variant, bn) — the cache
    key.  Continuous stats are bucketed so near-identical matrices share
    entries (pad to 10%, skew to 25%, N to the next power of two).
    ``reorder`` is part of the key: a permuted matrix has a different
    blocks-per-row skew than its un-permuted twin, so cached picks must
    not alias across reorder schemes.  ``n_shards`` (v3) is part of the
    key too: a shard of a row-partitioned operand (``launch.dist_spmm``)
    has its own stats AND a different execution context (its N-tile shares
    the device with the other shards), so per-shard picks must not alias
    the unsharded twin's entries.  ``max_bpr`` (v4) carries the
    ``row_loop`` schedule bound EXACTLY (not bucketed): reordering shrinks
    it, the static schedule length is ``n_block_rows * max_bpr``, and two
    structures whose other stats coincide but whose schedule bounds differ
    must never share a cached ``row_loop`` decision.  ``op`` (v5) names
    the compute family: ``ops.spmm`` and ``ops.sddmm`` dispatch over the
    SAME structures with different optimal schedules (SDDMM contracts
    over the bn-tiled N axis instead of streaming it), so their picks
    must never alias.  v6 adds the ``attn`` family (fused one-kernel
    attention vs the composed triple — a third disjoint pick space over
    the same structures) and bumps the key prefix so v5 caches, which
    predate the family split, are invalidated wholesale rather than
    partially reused.  v7 adds ``n_chunks`` (``nk=``): the overlap depth
    of the communication-pipelined sharded execution
    (``dist_spmm.spmm_sharded(n_chunks=...)``).  It keys the SHARD-COUNT
    decisions (``pick_shards`` — the best S depends on how much of the B
    collective the pipeline can hide), NOT the kernel-variant picks:
    chunking never changes the per-shard kernel launch shape, and variant
    picks stay resolved at the full panel width (``nk=1``) so the chunked
    path dispatches bit-identically to the unchunked one even under
    measured caches."""
    n_block_rows: int
    n_block_cols: int
    block: Tuple[int, int]
    nnzb: int
    pad_bucket: int      # padding_ratio in 10% buckets
    skew_bucket: int     # blocks-per-row cv in 25% buckets
    n_bucket: int        # next pow2 of N
    reorder: str = "identity"
    n_shards: int = 1    # shard count of the partitioned operand (1 = whole)
    max_bpr: int = 0     # row_loop schedule bound (0 = unknown/dims-only)
    op: str = "spmm"     # compute family (spmm | sddmm | attn)
    n_chunks: int = 1    # B-panel overlap chunks (shard-count key axis)

    def key(self) -> str:
        h, w = self.block
        return (f"v7|op={self.op}"
                f"|nbr={self.n_block_rows}|nbc={self.n_block_cols}"
                f"|b={h}x{w}|nnzb={self.nnzb}|pad={self.pad_bucket}"
                f"|skew={self.skew_bucket}|n={self.n_bucket}"
                f"|ro={self.reorder}|ns={self.n_shards}|mb={self.max_bpr}"
                f"|nk={self.n_chunks}")


def _make_fingerprint(nbr: int, nbc: int, block, nnzb: int,
                      pad_pct: int, cv_pct: int, n: int,
                      reorder: str = "identity",
                      n_shards: int = 1, max_bpr: int = 0,
                      op: str = "spmm", n_chunks: int = 1) -> Fingerprint:
    """Single bucketing site for both fingerprint paths — the meta-side and
    BCSR-side keys must agree bit-for-bit or cached picks stop matching."""
    return Fingerprint(
        n_block_rows=nbr, n_block_cols=nbc, block=tuple(block), nnzb=nnzb,
        pad_bucket=pad_pct // 10, skew_bucket=cv_pct // 25,
        n_bucket=_pow2_bucket(n), reorder=reorder, n_shards=n_shards,
        max_bpr=max_bpr, op=op, n_chunks=n_chunks)


def fingerprint(meta: ops.SparseMeta, n: int,
                op: str = "spmm", n_chunks: int = 1) -> Fingerprint:
    """Fingerprint from the static meta ``prepare_sparse`` built (or a
    per-shard meta from ``dist_spmm.prepare_sharded`` — its ``n_shards``
    and ``max_bpr`` ride into the v7 key).  ``op`` selects the compute
    family's key space (``spmm`` | ``sddmm`` | ``attn``); ``n_chunks``
    (``nk=``) is the overlap depth — pass it only for shard-count
    decisions, kernel-variant picks keep the default 1."""
    return _make_fingerprint(meta.n_block_rows, meta.n_block_cols,
                             meta.block, meta.nnzb,
                             meta.padding_ratio_pct, meta.bpr_cv_pct, n,
                             reorder=meta.reorder, n_shards=meta.n_shards,
                             max_bpr=meta.max_bpr, op=op, n_chunks=n_chunks)


def fingerprint_bcsr(a: bcsr_lib.BCSR, n: int,
                     reorder: str = "identity",
                     op: str = "spmm") -> Fingerprint:
    """Fingerprint from a host BCSR — matches ``fingerprint`` of the meta
    ``prepare_sparse`` would build (same row padding applied first; both
    sides go through ``BCSR.dispatch_stats`` + ``_make_fingerprint``).
    ``reorder`` names the scheme that PRODUCED this matrix's structure —
    pass the same value given to ``prepare_sparse``; the matrix itself is
    not re-permuted here."""
    a_p = a.ensure_nonempty_rows()
    max_bpr, pad_pct, cv_pct = a_p.dispatch_stats()
    return _make_fingerprint(a_p.n_block_rows, a_p.n_block_cols, a_p.block,
                             a_p.nnzb, pad_pct, cv_pct, n, reorder=reorder,
                             max_bpr=max_bpr, op=op)


# -------------------------------------------------------------------- choice
@dataclasses.dataclass(frozen=True)
class KernelChoice:
    variant: str
    bn: int
    source: str = "analytic"    # analytic | measured | default
    predicted_us: float = 0.0

    @property
    def backend(self) -> str:
        return get_variant(self.variant).backend

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "KernelChoice":
        return KernelChoice(variant=d["variant"], bn=int(d["bn"]),
                            source=d.get("source", "analytic"),
                            predicted_us=float(d.get("predicted_us", 0.0)))


def default_variant(op: str = "spmm") -> str:
    """The hardcoded pre-registry default of one compute family — the
    baseline every pick must beat.  For ``attn`` that is the composed
    triple: the fused kernel must WIN the model comparison to dispatch."""
    if op == "attn":
        return "attn_composed"
    return DEFAULT_VARIANT if op == "spmm" else "sddmm_stream"


def default_choice(op: str = "spmm") -> KernelChoice:
    return KernelChoice(default_variant(op), DEFAULT_BN, source="default")


def pick_bn(meta: ops.SparseMeta, n: int,
            candidates: Iterable[int]) -> int:
    """Largest candidate whose per-cell working set fits the VMEM budget
    (wider N-tiles amortize the A-block stream; the budget caps them)."""
    h, w = meta.block
    feasible = []
    for bn in candidates:
        working = (h * w + w * bn) * 2 + (h * bn) * 4  # bf16 in, f32 acc
        if working * 2 <= _VMEM_BUDGET:                # double-buffered
            feasible.append(bn)
    if not feasible:
        feasible = [min(candidates)]
    # no point tiling wider than (padded) N
    fit_n = [bn for bn in feasible if bn <= max(n, min(feasible))]
    return max(fit_n or feasible)


def analytic_choice(meta: ops.SparseMeta, n: int,
                    op: str = "spmm") -> KernelChoice:
    """Model-based pick: paper Eq. 1 per variant of the ``op`` family,
    minimum predicted time."""
    best: Optional[Tuple[float, str, int]] = None
    for v in _REGISTRY.values():
        if v.op != op or not v.supported(meta):
            continue
        bn = pick_bn(meta, n, v.bn_candidates)
        t = float(v.model_time(meta, n, bn))
        if best is None or t < best[0]:
            best = (t, v.name, bn)
    if best is None:  # every variant gated off — keep the hardcoded default
        return default_choice(op)
    t, name, bn = best
    return KernelChoice(name, bn, source="analytic", predicted_us=t * 1e6)


# ----------------------------------------------------------- shard-count axis
# Candidate shard counts for the self-sizing distributed path
# (``dist_spmm``): powers of two up to the mesh/row limit, 1 = unsharded.
SHARD_CANDIDATES = (1, 2, 4, 8)

_T_INIT = 5e-6        # per-launch latency (matches pm.spmm_model_time)
_T_SHARD_SYNC = 5e-7  # cross-shard coordination cost per shard doubling


@dataclasses.dataclass(frozen=True)
class ShardChoice:
    """A cached shard-count decision (the S analogue of KernelChoice)."""
    n_shards: int
    source: str = "analytic"    # analytic | measured
    predicted_us: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "ShardChoice":
        return ShardChoice(n_shards=int(d["n_shards"]),
                           source=d.get("source", "analytic"),
                           predicted_us=float(d.get("predicted_us", 0.0)))


def shard_candidates(max_shards: int, n_block_rows: int) -> Tuple[int, ...]:
    """The S values ``pick_shards`` considers: ``SHARD_CANDIDATES`` capped
    by the mesh size AND the block-row count (a shard with zero row slots
    is pure overhead)."""
    cap = max(min(int(max_shards), max(int(n_block_rows), 1)), 1)
    cands = tuple(s for s in SHARD_CANDIDATES if s <= cap)
    return cands or (1,)


def _pipeline_time(t_comp: float, t_coll: float, n_chunks: int) -> float:
    """Total time of a ``k``-stage software pipeline that issues the
    collective for chunk ``i+1`` before the matmul over chunk ``i``: only
    the first chunk's collective is exposed; every later stage runs at the
    rate of the slower leg."""
    k = max(int(n_chunks), 1)
    return t_coll / k + t_comp / k + (k - 1) / k * max(t_comp, t_coll)


def analytic_shard_choice(meta: ops.SparseMeta, n: int, *,
                          max_shards: int = 8, n_chunks: int = 1,
                          op: str = "spmm") -> ShardChoice:
    """Model-based shard count for the partitioned execution path.

    The S=1 arm is the plain paper Eq. 1 (no collective: a single device
    already holds B).  For S>1 the per-shard work is the balanced LPT load
    (``ceil(nnzb/S)`` entries plus one virtual-row sentinel per row slot),
    the B broadcast crosses ICI once, and the two legs compose through the
    ``n_chunks``-deep overlap pipeline — so deeper chunking makes larger S
    win sooner, which is exactly why ``nk=`` is part of the cache key.
    A ``log2(S)`` coordination term keeps the model from racing to the
    mesh cap on structures whose compute no longer dominates.  Ties go to
    the SMALLER S (fewer moving parts at equal predicted time)."""
    h, w = meta.block
    nbr = max(meta.n_block_rows, 1)
    bn = pick_bn(meta, n, get_variant(default_variant("spmm")).bn_candidates)
    tiles = _n_tiles(n, bn)
    _, _, t_e = pm.block_mma_time(h, w, bn)
    t_coll = float(meta.shape[1]) * n * _bytes_per_el() / pm.ICI_BW
    best: Optional[Tuple[float, int]] = None
    for s in shard_candidates(max_shards, nbr):
        if s == 1:
            t = pm.spmm_model_time(meta.nnzb * tiles, h, w, bn)
        else:
            load = -(-meta.nnzb // s) + -(-nbr // s)
            t_comp = t_e * load * tiles
            t = (_T_INIT + _T_SHARD_SYNC * (s.bit_length() - 1)
                 + _pipeline_time(t_comp, t_coll, n_chunks))
        if best is None or t < best[0]:
            best = (t, s)
    t, s = best
    return ShardChoice(s, source="analytic", predicted_us=t * 1e6)


def shard_entry_key(fp: Fingerprint, max_shards: int) -> str:
    """Cache key of a shard-count decision: the mesh cap prefixed onto the
    structure's v7 fingerprint (which carries ``nk=``), so decisions made
    for different device budgets or overlap depths never alias."""
    return f"shards|max={int(max_shards)}|{fp.key()}"


# ----------------------------------------------------------------- autotuner
class Autotuner:
    """Fingerprint -> KernelChoice cache with analytic and measured fills.

    ``cache_path`` (or the ``REPRO_AUTOTUNE_CACHE`` environment variable —
    set it to a writable ``<path>.json`` to share tuned picks across
    processes, e.g. from an offline benchmark run into a serving process)
    mirrors the table to JSON; loading tolerates a missing or corrupt file
    (starts empty), saving is atomic (tmp + rename).  With neither set the
    cache is in-memory only.

    A cache MISS never blocks dispatch: ``pick`` falls back to the
    analytic perf-model choice (paper Eq. 1), so ``backend="auto"`` is
    always trace-safe.  Timed sweeps only run via explicit ``tune()`` /
    ``dist_spmm.tune_shards`` calls.

    >>> import numpy as np
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import autotune, ops
    >>> a = bcsr_lib.random_bcsr_exact(0, (256, 256), (16, 16), nnzb=64)
    >>> meta = ops.prepare_sparse_meta(a)
    >>> tuner = autotune.Autotuner()          # in-memory (no cache file)
    >>> choice = tuner.pick(meta, n=128)
    >>> choice.variant in autotune.variant_names()
    True
    >>> tuner.pick(meta, n=128) is choice     # cached under the v7 key
    True
    """

    def __init__(self, cache_path: Optional[str] = None):
        self.cache_path = cache_path or os.environ.get(
            "REPRO_AUTOTUNE_CACHE") or None
        self._mem: Dict[str, KernelChoice] = {}
        self._shards: Dict[str, ShardChoice] = {}
        if self.cache_path:
            self.load()

    # ------------------------------------------------------------- storage
    def load(self) -> None:
        try:
            with open(self.cache_path) as f:
                payload = json.load(f)
            for k, d in payload.get("entries", {}).items():
                if d.get("variant") in _REGISTRY:
                    self._mem[k] = KernelChoice.from_dict(d)
            for k, d in payload.get("shard_entries", {}).items():
                self._shards[k] = ShardChoice.from_dict(d)
        except (OSError, ValueError, KeyError, AttributeError, TypeError):
            pass  # absent/corrupt/wrong-shape cache -> start empty

    def save(self) -> None:
        if not self.cache_path:
            return
        payload = {"version": 1,
                   "entries": {k: c.to_dict() for k, c in self._mem.items()},
                   "shard_entries": {k: c.to_dict()
                                     for k, c in self._shards.items()}}
        tmp = f"{self.cache_path}.tmp.{os.getpid()}"
        try:
            os.makedirs(os.path.dirname(os.path.abspath(self.cache_path)),
                        exist_ok=True)
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.cache_path)
        except OSError:
            pass  # read-only FS: in-memory cache still works

    # -------------------------------------------------------------- lookup
    def get(self, fp: Fingerprint) -> Optional[KernelChoice]:
        return self._mem.get(fp.key())

    def put(self, fp: Fingerprint, choice: KernelChoice,
            persist: bool = True) -> None:
        self._mem[fp.key()] = choice
        if persist:
            self.save()

    def get_shards(self, fp: Fingerprint,
                   max_shards: int) -> Optional[ShardChoice]:
        return self._shards.get(shard_entry_key(fp, max_shards))

    def put_shards(self, fp: Fingerprint, max_shards: int,
                   choice: ShardChoice, persist: bool = True) -> None:
        self._shards[shard_entry_key(fp, max_shards)] = choice
        if persist:
            self.save()

    def pick_shards(self, meta: ops.SparseMeta, n: int, *,
                    max_shards: int = 8, n_chunks: int = 1,
                    op: str = "spmm") -> ShardChoice:
        """Cached shard count for this structure, analytic on a miss.

        The S analogue of ``pick``: static info only, trace-safe, never
        blocks dispatch.  Decisions key on
        ``shards|max=<mesh cap>|<v7 fingerprint>`` — the fingerprint
        carries ``nk=n_chunks``, so the same structure planned with and
        without overlap resolves (and caches) independently.  Measured
        winners land here via ``dist_spmm.tune_shard_count``."""
        fp = fingerprint(meta, n, op=op, n_chunks=n_chunks)
        hit = self.get_shards(fp, max_shards)
        if hit is not None:
            obs_trace.event("autotune.pick_shards", key=fp.key(),
                            max_shards=max_shards, n_shards=hit.n_shards,
                            source=hit.source, cache_hit=True)
            obs_metrics.counter("autotune.cache_hit", kind="shards").inc()
            return hit
        choice = analytic_shard_choice(meta, n, max_shards=max_shards,
                                       n_chunks=n_chunks, op=op)
        # cache in memory only — analytic resolutions are cheap to
        # recompute and may run inside first-trace paths (same policy as
        # pick())
        self._shards[shard_entry_key(fp, max_shards)] = choice
        obs_trace.event("autotune.pick_shards", key=fp.key(),
                        max_shards=max_shards, n_shards=choice.n_shards,
                        source=choice.source, cache_hit=False)
        obs_metrics.counter("autotune.cache_miss", kind="shards").inc()
        return choice

    def __len__(self) -> int:
        return len(self._mem)

    def pick(self, meta: ops.SparseMeta, n: int,
             op: str = "spmm") -> KernelChoice:
        """Cached choice for this structure, analytic on a miss.  Static
        info only — safe inside jit traces (``backend="auto"`` path).
        ``op`` selects the variant family (``spmm`` | ``sddmm`` | ``attn``)
        and its disjoint v7 key space."""
        fp = fingerprint(meta, n, op=op)
        hit = self.get(fp)
        if hit is not None:
            obs_trace.event("autotune.pick", key=fp.key(), op=op,
                            variant=hit.variant, bn=hit.bn,
                            source=hit.source, cache_hit=True)
            obs_metrics.counter("autotune.cache_hit", op=op).inc()
            return hit
        choice = analytic_choice(meta, n, op=op)
        # cache (no disk write: analytic picks are cheap to recompute and
        # pick() may run inside latency-sensitive first-trace paths)
        self.put(fp, choice, persist=False)
        obs_trace.event("autotune.pick", key=fp.key(), op=op,
                        variant=choice.variant, bn=choice.bn,
                        source=choice.source, cache_hit=False)
        obs_metrics.counter("autotune.cache_miss", op=op).inc()
        return choice

    # ------------------------------------------------------------- tuning
    def tune(self, a: bcsr_lib.BCSR, n: int, *, dtype=jnp.float32,
             interpret: bool = True, variants: Optional[Iterable[str]] = None,
             warmup: int = 1, iters: int = 3, rng_seed: int = 0,
             reorder: str = "identity",
             reorder_granularity: str = "element",
             n_shards: int = 8,
             op: str = "spmm") -> Tuple[KernelChoice, Dict[str, float]]:
        """Timed micro-sweep over the ``op`` family's (variant, bn)
        candidates.

        Always measures the family's hardcoded default (``nnz_stream`` /
        ``sddmm_stream``, bn=512) so the winner is never slower than it;
        returns (choice, {candidate: sec}).  The winner is cached (and
        persisted) under the matrix's v7 ``op=``-scoped fingerprint.
        ``reorder`` mirrors the ``prepare_sparse`` arguments so the sweep
        measures (and the fingerprint matches) the permuted structure the
        apply path will actually dispatch on.  For ``op="sddmm"`` the
        timed call is ``ops.sddmm(arrays, meta, x, y)`` with dense
        operands ``x [M, n]`` / ``y [K, n]`` (n = the contraction width).
        """
        arrays, meta = ops.prepare_sparse(
            a, dtype=dtype, reorder=reorder,
            reorder_granularity=reorder_granularity, n_shards=n_shards)
        fp = fingerprint(meta, n, op=op)
        rng = np.random.default_rng(rng_seed)
        if op == "sddmm":
            x = jnp.asarray(rng.standard_normal((meta.shape[0], n)),
                            dtype=dtype)
            y = jnp.asarray(rng.standard_normal((meta.shape[1], n)),
                            dtype=dtype)

            def _mk_fn(backend, bn):
                return jax.jit(lambda xx, yy: ops.sddmm(
                    arrays, meta, xx, yy, backend=backend, bn=bn,
                    interpret=interpret))
            operands = (x, y)
        else:
            b = jnp.asarray(rng.standard_normal((meta.shape[1], n)),
                            dtype=dtype)

            def _mk_fn(backend, bn):
                return jax.jit(lambda bb: ops.spmm(
                    arrays, meta, bb, backend=backend, bn=bn,
                    interpret=interpret))
            operands = (b,)

        names = tuple(variants) if variants else variant_names(op)
        cand: Dict[str, Tuple[str, int]] = {}
        for name in names:
            v = get_variant(name)
            if v.op != op or not v.supported(meta):
                continue
            bns = {pick_bn(meta, n, v.bn_candidates)}
            bns.update(bn for bn in v.bn_candidates if bn <= max(n, 128))
            for bn in sorted(bns):
                cand[f"{name}/bn{bn}"] = (name, bn)
        dv = default_variant(op)
        cand.setdefault(f"{dv}/bn{DEFAULT_BN}", (dv, DEFAULT_BN))

        timings: Dict[str, float] = {}
        with obs_trace.span("autotune.tune", key=fp.key(), op=op,
                            n_candidates=len(cand)):
            for label, (name, bn) in cand.items():
                fn = _mk_fn(get_variant(name).backend, bn)
                try:
                    jax.block_until_ready(fn(*operands))
                    for _ in range(max(warmup - 1, 0)):
                        jax.block_until_ready(fn(*operands))
                    ts = []
                    for _ in range(iters):
                        t0 = time.perf_counter()
                        jax.block_until_ready(fn(*operands))
                        ts.append(time.perf_counter() - t0)
                    timings[label] = float(np.median(ts))
                except Exception:  # variant not runnable — skip, don't die
                    continue

        default_label = f"{dv}/bn{DEFAULT_BN}"
        if not timings:
            choice = default_choice(op)
        else:
            best_label = min(timings, key=timings.get)
            # prefer the default on a tie within noise (2%)
            if (default_label in timings and
                    timings[default_label] <= timings[best_label] * 1.02):
                best_label = default_label
            name, bn = cand[best_label]
            choice = KernelChoice(name, bn, source="measured",
                                  predicted_us=timings[best_label] * 1e6)
        self.put(fp, choice, persist=True)
        obs_trace.event("autotune.tuned", key=fp.key(), op=op,
                        variant=choice.variant, bn=choice.bn,
                        n_candidates=len(timings))
        obs_metrics.counter("autotune.tuned", op=op).inc()
        return choice, timings


# ---------------------------------------------------------------- singleton
_DEFAULT_TUNER: Optional[Autotuner] = None


def get_autotuner() -> Autotuner:
    global _DEFAULT_TUNER
    if _DEFAULT_TUNER is None:
        _DEFAULT_TUNER = Autotuner()
    return _DEFAULT_TUNER


def set_autotuner(tuner: Optional[Autotuner]) -> None:
    """Swap the process-wide tuner (tests; serving with a shared cache)."""
    global _DEFAULT_TUNER
    _DEFAULT_TUNER = tuner
