"""Fused one-kernel block-sparse attention (flash-style) — SDDMM, block
softmax and the context SpMM in a SINGLE Pallas launch.

The composed path (PR 5) is three dispatches per head:

    scores = ops.sddmm(mask, Q, K)   # materializes [nnzb, h, w]
    probs  = block_softmax(scores)   # materializes [nnzb, h, w] again
    ctx    = ops.spmm(probs, V)

``bcsr_attn_fused`` walks the SAME static (block-row x slot) schedule the
``row_loop`` SDDMM uses (``ops._sddmm_row_loop_schedule``: padding slots
point at a sentinel entry) but never writes a score or prob block to HBM:
each grid cell recomputes its Q K^T block on the fly and folds it into
per-query-block running state held in VMEM scratch — O(L * d) memory and
one kernel launch instead of three.

**Bit-for-bit contract.**  The fused forward is pinned bitwise-equal (f32)
to the composed SDDMM -> ``block_softmax`` -> SpMM path.  A classic
flash-attention *rescaling* online softmax cannot satisfy that pin (its
running renormalisation reassociates the sums), so the kernel runs THREE
passes over the block-row's slots inside one launch — grid
``(G, n_block_rows, 3, max_bpr)`` with the slot axis innermost:

    phase 0   running row max     m  <- max(m, max(logits))
    phase 1   denominator         l  <- l + sum(exp(logits - m))
    phase 2   context             acc <- acc + (exp(logits - m) / l) @ V

Every elementary op replays the composed path exactly: the score block is
tiled over the contraction axis in the same order as ``ops._sddmm_impl``,
masked elements go to the same ``NEG_INF`` sentinel, the max is
order-insensitive, and phases 1/2 accumulate left-to-right in entry order
— which is bitwise what ``jax.ops.segment_sum`` computes for row-major
sorted segment ids.  Sentinel slots contribute exact ``+0.0`` terms, so
the static waste never perturbs the numbers.

One carve-out: the optional ``cap`` tanh soft-clip.  XLA's ``tanh``
lowering is not bitwise-stable across fusion contexts (even ``jit(f)``
vs eager ``f`` of the SAME composed graph differ in the last ulp), so
capped attention is pinned at float tolerance instead — the bit-for-bit
contract covers the standard ``cap=None`` path.

Backward is NOT fused: ``models.attention`` pairs this forward with the
composed dual-VJP path (SpMM and SDDMM are mutual duals), which the
bit-for-bit forward pin makes gradient-consistent.  A recompute-based
fused backward is an explicit non-goal (ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.attention_mask import NEG_INF
from repro.kernels.ops import _clamp_bn


def _attn_fused_kernel(idx_ref, col_ref, q_ref, k_ref, v_ref, em_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, max_bpr: int,
                       n_d_tiles: int, bn_d: int, n_v_tiles: int, bn_v: int,
                       scale: float, cap):
    p = pl.program_id(2)          # phase: 0 max | 1 denom | 2 accumulate
    t = pl.program_id(3)          # slot within the block-row's schedule
    first = t == 0
    last = t == max_bpr - 1

    q = q_ref[0]                  # [h, dpad]
    kb = k_ref[0]                 # [w, dpad]
    em = em_ref[0] != 0.0         # [h, w]; sentinel block -> all False

    # score block, tiled over the contraction axis exactly like the
    # composed SDDMM (same per-tile dots, same accumulation order)
    s = jnp.zeros(em.shape, jnp.float32)
    for j in range(n_d_tiles):
        sl = slice(j * bn_d, (j + 1) * bn_d)
        s += jax.lax.dot_general(
            q[:, sl], kb[:, sl],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    s = s * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    logits = jnp.where(em, s, NEG_INF)

    @pl.when(jnp.logical_and(p == 0, first))
    def _init_m():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, m_scr.dtype)

    @pl.when(p == 0)
    def _max():
        m_scr[...] = jnp.maximum(m_scr[...], jnp.max(logits, axis=1)[:, None])

    @pl.when(jnp.logical_and(p == 0, last))
    def _clamp_m():   # rows with no valid element (block_softmax clamp)
        m_scr[...] = jnp.maximum(m_scr[...], -1e30)

    @pl.when(jnp.logical_and(p == 1, first))
    def _init_l():
        l_scr[...] = jnp.zeros_like(l_scr)

    @pl.when(p == 1)
    def _denom():
        z = jnp.exp(logits - m_scr[:, :1])
        z = jnp.where(em, z, 0.0)
        l_scr[...] += z.sum(axis=1)[:, None]

    @pl.when(jnp.logical_and(p == 1, last))
    def _clamp_l():
        l_scr[...] = jnp.maximum(l_scr[...], 1e-30)

    @pl.when(jnp.logical_and(p == 2, first))
    def _init_acc():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(p == 2)
    def _ctx():
        z = jnp.exp(logits - m_scr[:, :1])
        z = jnp.where(em, z, 0.0)
        pb = z / l_scr[:, :1]
        for j in range(n_v_tiles):
            sl = slice(j * bn_v, (j + 1) * bn_v)
            acc_scr[:, sl] += jax.lax.dot(
                pb, v_ref[0][:, sl], preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(p == 2, last))
    def _flush():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def bcsr_attn_fused(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    emask: jnp.ndarray, flat_idx: jnp.ndarray,
                    flat_col: jnp.ndarray, *, n_block_rows: int,
                    n_block_cols: int, block, scale: float,
                    cap=None, bn: int = 512, out_dtype=None,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused block-sparse attention over a static BCSR mask schedule.

    q, k, v   ``[G, Lq, d]`` / ``[G, Lk, d]`` / ``[G, Lk, dv]`` — G folded
              (batch * heads) instances sharing one mask structure.
    emask     ``[nnzb, h, w]`` float 0/1 — valid (stored AND allowed AND
              non-padding) elements of each stored block, entries sorted
              row-major.  A zero sentinel block is appended internally.
    flat_idx  ``[nbr * max_bpr]`` entry index per (block-row, slot);
              padding slots hold the sentinel index ``nnzb``
              (``ops._sddmm_row_loop_schedule`` layout).
    flat_col  ``[nbr * max_bpr]`` block-col per (block-row, slot).
    scale     applied to the scores before the optional ``cap`` tanh
              soft-clip, exactly like ``models.attention.block_softmax``.

    Returns ``[G, Lq, dv]``; masked query rows get all-zero context.

    >>> import numpy as np, jax, jax.numpy as jnp
    >>> from repro.kernels import bcsr_attn
    >>> L, d = 8, 4
    >>> rng = np.random.default_rng(0)
    >>> q, k, v = (jnp.asarray(rng.standard_normal((1, L, d)), jnp.float32)
    ...            for _ in range(3))
    >>> # causal mask on a 2x2 block grid: stored blocks (0,0) (1,0) (1,1)
    >>> qpos = np.arange(L)[:, None]; kpos = np.arange(L)[None, :]
    >>> elem = (kpos <= qpos).reshape(2, 4, 2, 4).transpose(0, 2, 1, 3)
    >>> emask = elem[[0, 1, 1], [0, 0, 1]].astype(np.float32)
    >>> flat_idx = np.array([0, 3, 1, 2], np.int32)   # sentinel = nnzb = 3
    >>> flat_col = np.array([0, 0, 0, 1], np.int32)
    >>> out = bcsr_attn.bcsr_attn_fused(
    ...     q, k, v, emask, flat_idx, flat_col, n_block_rows=2,
    ...     n_block_cols=2, block=(4, 4), scale=0.5, interpret=True)
    >>> out.shape
    (1, 8, 4)
    >>> s = (q[0] @ k[0].T) * 0.5
    >>> p = jax.nn.softmax(jnp.where(kpos <= qpos, s, -2.0e38), axis=-1)
    >>> bool(jnp.allclose(out[0], p @ v[0], atol=1e-5))
    True
    """
    G, Lq, dq = q.shape
    _, Lk, dk = k.shape
    dv = v.shape[2]
    h, w = block
    nnzb = emask.shape[0]
    max_bpr = flat_idx.shape[0] // n_block_rows
    assert flat_idx.shape[0] == n_block_rows * max_bpr and max_bpr > 0
    assert n_block_rows * h >= Lq and n_block_cols * w >= Lk
    out_dtype = out_dtype or q.dtype

    # pad the contraction axis exactly like the composed ops._sddmm_impl:
    # common width for q and k, tiled at the clamped bn
    bn_d = _clamp_bn(bn, max(dq, dk))
    dpad = max(dq + ((-dq) % bn_d), dk + ((-dk) % bn_d))
    bn_d = min(bn_d, dpad)
    # ...and the V panel like the composed context SpMM (ops._fwd_impl)
    bn_v = _clamp_bn(bn, dv)
    vpad = dv + ((-dv) % bn_v)
    bn_v = min(bn_v, vpad)

    qp = jnp.pad(q, ((0, 0), (0, n_block_rows * h - Lq), (0, dpad - dq)))
    kp = jnp.pad(k, ((0, 0), (0, n_block_cols * w - Lk), (0, dpad - dk)))
    vp = jnp.pad(v, ((0, 0), (0, n_block_cols * w - Lk), (0, vpad - dv)))
    em_ext = jnp.concatenate(
        [jnp.asarray(emask, jnp.float32),
         jnp.zeros((1, h, w), jnp.float32)], axis=0)

    grid = (G, n_block_rows, 3, max_bpr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            # Q block-row i of instance g (constant across phases/slots —
            # one DMA per block-row)
            pl.BlockSpec((1, h, dpad),
                         lambda g, i, p, t, idx_ref, col_ref: (g, i, 0)),
            # K / V panels: data-dependent DMA via the prefetched schedule
            pl.BlockSpec((1, w, dpad),
                         lambda g, i, p, t, idx_ref, col_ref:
                         (g, col_ref[i * max_bpr + t], 0)),
            pl.BlockSpec((1, w, vpad),
                         lambda g, i, p, t, idx_ref, col_ref:
                         (g, col_ref[i * max_bpr + t], 0)),
            # element mask of the scheduled entry (sentinel -> zero block)
            pl.BlockSpec((1, h, w),
                         lambda g, i, p, t, idx_ref, col_ref:
                         (idx_ref[i * max_bpr + t], 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, h, vpad), lambda g, i, p, t, idx_ref, col_ref: (g, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 128), jnp.float32),   # running row max
            pltpu.VMEM((h, 128), jnp.float32),   # running denominator
            pltpu.VMEM((h, vpad), jnp.float32),  # context accumulator
        ],
    )
    kernel = functools.partial(
        _attn_fused_kernel, max_bpr=max_bpr, n_d_tiles=dpad // bn_d,
        bn_d=bn_d, n_v_tiles=vpad // bn_v, bn_v=bn_v, scale=scale, cap=cap)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, n_block_rows * h, vpad),
                                       out_dtype),
        interpret=interpret,
    )(flat_idx, flat_col, qp, kp, vp, em_ext)
    return out[:, :Lq, :dv]
