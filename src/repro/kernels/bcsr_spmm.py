"""Pallas TPU kernels for BCSR SpMM — the paper's contribution, MXU-native.

Four kernels:

  * ``bcsr_spmm_nnz_stream``  — production forward. The grid streams the
    *nonzero-block list* (beyond-paper: zero pipeline bubbles regardless of
    row skew — this removes SMaT's ``dc2`` worst case).  The BCSR index
    arrays are scalar-prefetched into SMEM and drive data-dependent
    HBM->VMEM DMA through the BlockSpec ``index_map`` — the TPU-idiomatic
    replacement for SMaT's ``ldmatrix`` + ``cuda::memcpy_async`` pipeline
    (Pallas double-buffers the DMA against the MXU automatically).

  * ``bcsr_spmm_row_loop``    — the paper-faithful *static schedule*: one
    output tile per (block-row x N-tile) grid cell, looping to
    ``max_blocks_per_row`` with masking, exactly like SMaT's warp-per-C-tile
    2D schedule (wasted iterations on short rows; used as the faithful
    baseline in benchmarks).

  * ``bcsr_sddmm``            — block-sampled dense-dense product
    (``X @ Y^T`` evaluated only at the stored blocks), streamed over the
    nonzero-block list.  It is both the backward pass of SpMM (dW of a
    sparse weight) and, since PR 5, the forward of the public
    ``ops.sddmm`` — the score kernel of block-sparse attention.

  * ``bcsr_sddmm_row_loop``   — the paper-faithful static-schedule SDDMM
    twin: one grid cell per (block-row x slot x N-tile), looping to
    ``max_blocks_per_row``; padding slots write into a sentinel output
    block (SMaT's static waste, mirrored from the SpMM ``row_loop``).

Blocks are ``(h, w)`` with ``h`` a sublane multiple (8 f32 / 16 bf16) and
``w`` a lane multiple (128) on real TPUs; ``interpret=True`` (CPU CI) accepts
any shape.  All kernels accumulate in f32 VMEM scratch regardless of input
dtype (MXU-native mixed precision; the paper uses fp16-in/fp16-out on TC —
documented deviation, see docs/ARCHITECTURE.md "Mixed-precision contract").
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# =============================================================== nnz-streamed
def _nnz_stream_kernel(row_ref, col_ref, vals_ref, b_ref, o_ref, acc_ref,
                       *, nnzb: int):
    s = pl.program_id(1)
    row = row_ref[s]
    prev_row = row_ref[jnp.maximum(s - 1, 0)]
    next_row = row_ref[jnp.minimum(s + 1, nnzb - 1)]
    is_first = jnp.logical_or(s == 0, prev_row != row)
    is_last = jnp.logical_or(s == nnzb - 1, next_row != row)

    @pl.when(is_first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        vals_ref[0], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(is_last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bcsr_spmm_nnz_stream(vals: jnp.ndarray, row_ids: jnp.ndarray,
                         col_ids: jnp.ndarray, b: jnp.ndarray,
                         n_block_rows: int, *, bn: int = 512,
                         out_dtype=None, interpret: bool = False):
    """C[nbr*h, N] = A_bcsr @ B.  Entries must be sorted row-major and every
    block-row must contain >= 1 entry (``BCSR.ensure_nonempty_rows``)."""
    nnzb, h, w = vals.shape
    K, N = b.shape
    assert K % w == 0, (K, w)
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    out_dtype = out_dtype or b.dtype
    grid = (N // bn, nnzb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            # A block s: data-dependent DMA driven by the prefetched ids
            pl.BlockSpec((1, h, w), lambda j, s, row_ref, col_ref: (s, 0, 0)),
            # B block (col_ids[s], j)
            pl.BlockSpec((w, bn),
                         lambda j, s, row_ref, col_ref: (col_ref[s], j)),
        ],
        out_specs=pl.BlockSpec(
            (h, bn), lambda j, s, row_ref, col_ref: (row_ref[s], j)),
        scratch_shapes=[pltpu.VMEM((h, bn), jnp.float32)],
    )
    kernel = functools.partial(_nnz_stream_kernel, nnzb=nnzb)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows * h, N), out_dtype),
        interpret=interpret,
    )(row_ids, col_ids, vals, b)


# ================================================================== row-loop
def _row_loop_kernel(idx_ref, col_ref, len_ref, vals_ref, b_ref, o_ref,
                     acc_ref, *, max_bpr: int):
    i = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(t < len_ref[i])
    def _mac():
        acc_ref[...] += jax.lax.dot(
            vals_ref[0], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(t == max_bpr - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bcsr_spmm_row_loop(vals: jnp.ndarray, flat_idx: jnp.ndarray,
                       flat_col: jnp.ndarray, row_len: jnp.ndarray,
                       b: jnp.ndarray, n_block_rows: int, *, bn: int = 512,
                       out_dtype=None, interpret: bool = False):
    """Paper-faithful static 2D schedule.

    flat_idx [nbr*max_bpr]  entry index per (row, slot); padding slots point
                            at entry 0 (their DMA still happens — faithful to
                            SMaT's static waste on short rows).
    flat_col [nbr*max_bpr]  block-col per (row, slot) (padding -> 0)
    row_len  [nbr]          nonzero blocks in each row
    """
    nnzb, h, w = vals.shape
    K, N = b.shape
    assert K % w == 0
    bn = min(bn, N)
    assert N % bn == 0
    out_dtype = out_dtype or b.dtype
    max_bpr = flat_idx.shape[0] // n_block_rows
    grid = (n_block_rows, N // bn, max_bpr)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, w),
                         lambda i, j, t, idx_ref, col_ref, len_ref:
                         (idx_ref[i * max_bpr + t], 0, 0)),
            pl.BlockSpec((w, bn),
                         lambda i, j, t, idx_ref, col_ref, len_ref:
                         (col_ref[i * max_bpr + t], j)),
        ],
        out_specs=pl.BlockSpec(
            (h, bn), lambda i, j, t, idx_ref, col_ref, len_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((h, bn), jnp.float32)],
    )
    kernel = functools.partial(_row_loop_kernel, max_bpr=max_bpr)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows * h, N), out_dtype),
        interpret=interpret,
    )(flat_idx, flat_col, row_len, vals, b)


# ===================================================================== SDDMM
def _sddmm_kernel(row_ref, col_ref, dc_ref, b_ref, dv_ref, acc_ref,
                  *, n_tiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [h, bn] x [w, bn]^T -> [h, w]
    acc_ref[...] += jax.lax.dot_general(
        dc_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == n_tiles - 1)
    def _flush():
        dv_ref[0] = acc_ref[...].astype(dv_ref.dtype)


def bcsr_sddmm(dc: jnp.ndarray, b: jnp.ndarray, row_ids: jnp.ndarray,
               col_ids: jnp.ndarray, h: int, w: int, *, bn: int = 512,
               out_dtype=None, interpret: bool = False):
    """dVals[s] = dC[block row_ids[s]] @ B[block col_ids[s]]^T — the sparse
    weight gradient, computed only at the stored blocks."""
    M, N = dc.shape
    K, _ = b.shape
    assert M % h == 0 and K % w == 0
    bn = min(bn, N)
    assert N % bn == 0
    nnzb = row_ids.shape[0]
    out_dtype = out_dtype or dc.dtype
    n_tiles = N // bn
    grid = (nnzb, n_tiles)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, bn),
                         lambda s, j, row_ref, col_ref: (row_ref[s], j)),
            pl.BlockSpec((w, bn),
                         lambda s, j, row_ref, col_ref: (col_ref[s], j)),
        ],
        out_specs=pl.BlockSpec(
            (1, h, w), lambda s, j, row_ref, col_ref: (s, 0, 0)),
        scratch_shapes=[pltpu.VMEM((h, w), jnp.float32)],
    )
    kernel = functools.partial(_sddmm_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nnzb, h, w), out_dtype),
        interpret=interpret,
    )(row_ids, col_ids, dc, b)


# ========================================================== SDDMM (row-loop)
def _sddmm_row_loop_kernel(idx_ref, col_ref, dc_ref, b_ref, dv_ref, acc_ref,
                           *, n_tiles: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [h, bn] x [w, bn]^T -> [h, w]
    acc_ref[...] += jax.lax.dot_general(
        dc_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == n_tiles - 1)
    def _flush():
        dv_ref[0] = acc_ref[...].astype(dv_ref.dtype)


def bcsr_sddmm_row_loop(dc: jnp.ndarray, b: jnp.ndarray,
                        flat_idx: jnp.ndarray, flat_col: jnp.ndarray,
                        n_block_rows: int, nnzb: int, h: int, w: int, *,
                        bn: int = 512, out_dtype=None,
                        interpret: bool = False):
    """Static-schedule SDDMM: the 2D (block-row x slot) grid of
    ``bcsr_spmm_row_loop``, sampling ``dC @ B^T`` at the stored blocks.

    flat_idx [nbr*max_bpr]  OUTPUT entry per (row, slot); padding slots
                            point at the sentinel entry ``nnzb`` (their
                            product is computed and discarded — faithful
                            static waste on short rows).
    flat_col [nbr*max_bpr]  block-col per (row, slot) (padding -> 0)

    Returns ``[nnzb, h, w]`` (the sentinel row is sliced off).
    """
    M, N = dc.shape
    K, _ = b.shape
    assert M % h == 0 and K % w == 0
    bn = min(bn, N)
    assert N % bn == 0
    out_dtype = out_dtype or dc.dtype
    max_bpr = flat_idx.shape[0] // n_block_rows
    n_tiles = N // bn
    grid = (n_block_rows, max_bpr, n_tiles)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, bn),
                         lambda i, t, j, idx_ref, col_ref: (i, j)),
            pl.BlockSpec((w, bn),
                         lambda i, t, j, idx_ref, col_ref:
                         (col_ref[i * max_bpr + t], j)),
        ],
        out_specs=pl.BlockSpec(
            (1, h, w), lambda i, t, j, idx_ref, col_ref:
            (idx_ref[i * max_bpr + t], 0, 0)),
        scratch_shapes=[pltpu.VMEM((h, w), jnp.float32)],
    )
    kernel = functools.partial(_sddmm_row_loop_kernel, n_tiles=n_tiles)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nnzb + 1, h, w), out_dtype),
        interpret=interpret,
    )(flat_idx, flat_col, dc, b)
    return out[:nnzb]
