"""Public jit-ready SpMM ops: backend dispatch + custom VJP.

``SparseMatrix`` is the device-side, kernel-ready form of a host ``BCSR``:
entries padded so every block-row is nonempty (nnz-stream kernel invariant),
plus the precomputed transpose structure used by the backward pass
(dX = A^T dY).  It is a registered pytree whose integer index arrays ride
along as leaves (sharded/replicated like any other param) while the shape
metadata is static.

Backends:
  * ``pallas``   — the nnz-streamed TPU kernel (``interpret=True`` on CPU).
                   ``nnz_stream`` is accepted as an alias.
  * ``row_loop`` — the paper-faithful static-schedule TPU kernel (one grid
                   cell per block-row x N-tile, masked loop to max_bpr).
                   Requires ``meta.max_bpr > 0`` (set by ``prepare_sparse``).
  * ``xla``      — pure-jnp reference path (shardable; used by the
                   512-device dry-run and as the CI oracle).
  * ``dense``    — materialize the padded dense matrix and ``jnp.dot`` (the
                   cuBLAS comparison arm of the paper).
  * ``auto``     — dispatch through ``repro.kernels.autotune``: the variant
                   registry picks (backend, bn) from the matrix's stats
                   fingerprint (cached analytic pick, or a previously
                   measured micro-sweep result).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcsr as bcsr_lib
from repro.kernels import bcsr_spmm as pk
from repro.kernels import ref


# ---------------------------------------------------------------------- types
class SparseArrays(NamedTuple):
    """Device arrays of a BCSR operand (pytree leaves).

    ``row_perm`` / ``inv_perm`` carry the block-densifying row permutation
    (paper IV-C) applied by ``prepare_sparse(reorder=...)``: the stored
    blocks are those of A' = P A, and ``spmm`` returns C = P^T (A' B) so
    callers always see ORIGINAL row order.  They default to None for
    hand-built operands (identity semantics)."""
    vals: jnp.ndarray        # [nnzb, h, w] — the only trainable leaf
    row_ids: jnp.ndarray     # [nnzb] int32, sorted row-major
    col_ids: jnp.ndarray     # [nnzb] int32
    real_mask: jnp.ndarray   # [nnzb] bool — False for padding entries
    t_perm: jnp.ndarray      # [nnzb_t] int32 into vals (nnzb == sentinel zero)
    t_row_ids: jnp.ndarray   # [nnzb_t] int32 (block-rows of A^T)
    t_col_ids: jnp.ndarray   # [nnzb_t] int32
    row_perm: Optional[jnp.ndarray] = None   # [M] int32: A'[i] = A[row_perm[i]]
    inv_perm: Optional[jnp.ndarray] = None   # [M] int32: argsort(row_perm)


@dataclasses.dataclass(frozen=True)
class SparseMeta:
    """Static (hashable) metadata of a sparse operand.

    The trailing stats fields feed the autotuner's fingerprint (and the
    ``row_loop`` backend, which needs ``max_bpr`` to size its static
    schedule).  They default to "unknown" so hand-built metas (e.g. the
    dry-run's dims-only ``sparse_linear_specs``) keep working — the
    autotuner simply won't propose ``row_loop`` for those.  Because the
    whole dataclass is hashable, a meta is safe to close over inside jit
    traces and to ride through scan-stacked model layers as STATIC aux
    data (never as a pytree leaf) — the contract
    ``docs/ARCHITECTURE.md`` spells out.
    """
    shape: Tuple[int, int]          # logical (M, K)
    block: Tuple[int, int]          # (h, w)
    n_block_rows: int
    n_block_cols: int
    nnzb: int
    nnzb_t: int
    max_bpr: int = 0                # max blocks per block-row (0 = unknown)
    padding_ratio_pct: int = 0      # % of stored values that are zeros
    bpr_cv_pct: int = 0             # blocks-per-row std/mean, in %
    reorder: str = "identity"       # row-permutation scheme baked into vals
                                    # (autotune fingerprints on it: permuted
                                    # matrices have different bpr skew)
    n_shards: int = 1               # 1 = whole matrix; >1 = this meta is one
                                    # shard of a row-partitioned operand
                                    # (launch.dist_spmm) — fingerprinted so
                                    # per-shard picks never alias the
                                    # unsharded twin's cache entries

    @property
    def row_loop_sched_len(self) -> int:
        """Length of the ``row_loop`` backend's static schedule (grid
        entries per N-tile): ``n_block_rows * max_bpr``.  0 when the bound
        is unknown (dims-only meta).  Reordering that clusters similar
        rows shrinks ``max_bpr`` and therefore this length — the quantity
        ``bench_reorder`` reports and the v4 autotune fingerprint keys on.
        """
        return self.n_block_rows * max(self.max_bpr, 0)


# accepted aliases -> canonical SpmmConfig.backend strings
_BACKEND_ALIASES = {"nnz_stream": "pallas"}
BACKENDS = ("pallas", "row_loop", "xla", "dense")


@dataclasses.dataclass(frozen=True)
class SpmmConfig:
    backend: str = "pallas"         # pallas | row_loop | xla | dense
    bn: int = 512                   # N-tile width for the Pallas grid
    interpret: bool = False
    out_dtype: Optional[str] = None


# ------------------------------------------------------------------- prepare
def _prepare_sparse_host(a: bcsr_lib.BCSR, *, reorder: str,
                         reorder_granularity: str, tau: float,
                         max_candidates: Optional[int], n_shards: int):
    """Host-side (numpy) portion of ``prepare_sparse``: permute, pad,
    build the transpose structure, and compute the static meta.  Returns
    ``(host_arrays_dict, meta)``; ``prepare_sparse`` converts the arrays
    to device, ``prepare_sparse_meta`` keeps only the meta (the static
    structure-metadata pipeline the model layers dispatch on)."""
    from repro.core import permute as permute_lib  # local: import cycle
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    nnzb_in = a.nnzb
    with obs_trace.span("prepare.reorder", scheme=reorder,
                        granularity=reorder_granularity):
        a, row_perm_np = permute_lib.permute_bcsr(
            a, reorder, tau=tau, max_candidates=max_candidates,
            n_shards=n_shards, granularity=reorder_granularity)
    if nnzb_in:
        obs_metrics.gauge("prepare.nnzb_reduction_pct", scheme=reorder).set(
            round(100.0 * (nnzb_in - a.nnzb) / nnzb_in, 2))
    # padding entries are tagged explicitly by ensure_nonempty_rows (before
    # its lexsort), so genuinely-zero original blocks — e.g. from
    # random_bcsr(fill_density<1) — keep real_mask=True and stay trainable.
    with obs_trace.span("prepare.meta"):
        a_p, real_mask = a.ensure_nonempty_rows(return_mask=True)

        # ---- transpose structure (entries of A^T in A^T row-major order) --
        order = np.lexsort((a_p.row_ids, a_p.col_ids))
        t_perm = order.astype(np.int32)
        t_row_ids = a_p.col_ids[order].astype(np.int32)
        t_col_ids = a_p.row_ids[order].astype(np.int32)
        # pad A^T's empty block-rows with the sentinel zero block (index
        # nnzb)
        n_brows_t = a_p.n_block_cols
        present = np.zeros(n_brows_t, dtype=bool)
        present[t_row_ids] = True
        empty = np.flatnonzero(~present).astype(np.int32)
        if empty.size:
            t_perm = np.concatenate(
                [t_perm, np.full(empty.size, a_p.nnzb, np.int32)])
            t_row_ids = np.concatenate([t_row_ids, empty])
            t_col_ids = np.concatenate([t_col_ids,
                                        np.zeros(empty.size, np.int32)])
            order_t = np.lexsort((t_col_ids, t_row_ids))
            t_perm, t_row_ids, t_col_ids = (
                t_perm[order_t], t_row_ids[order_t], t_col_ids[order_t])

        inv_perm_np = permute_lib.invert_perm(row_perm_np)
    host = {
        "vals": a_p.vals,
        "row_ids": a_p.row_ids,
        "col_ids": a_p.col_ids,
        "real_mask": real_mask,
        "t_perm": t_perm,
        "t_row_ids": t_row_ids,
        "t_col_ids": t_col_ids,
        "row_perm": row_perm_np,
        "inv_perm": inv_perm_np,
    }
    max_bpr, pad_pct, cv_pct = a_p.dispatch_stats()
    meta = SparseMeta(shape=a_p.shape, block=a_p.block,
                      n_block_rows=a_p.n_block_rows,
                      n_block_cols=a_p.n_block_cols,
                      nnzb=a_p.nnzb, nnzb_t=int(t_row_ids.shape[0]),
                      max_bpr=max_bpr, padding_ratio_pct=pad_pct,
                      bpr_cv_pct=cv_pct, reorder=reorder)
    obs_trace.event("prepare.done", shape=meta.shape, block=meta.block,
                    nnzb=meta.nnzb, nnzb_t=meta.nnzb_t,
                    max_bpr=meta.max_bpr, reorder=reorder)
    obs_metrics.gauge("prepare.nnzb", scheme=reorder).set(meta.nnzb)
    return host, meta


def prepare_sparse(a: bcsr_lib.BCSR, dtype=jnp.bfloat16, *,
                   reorder: str = "identity",
                   reorder_granularity: str = "element",
                   tau: float = 0.7, max_candidates: Optional[int] = None,
                   n_shards: int = 8
                   ) -> Tuple[SparseArrays, SparseMeta]:
    """Host BCSR -> kernel-ready device arrays + static meta.

    ``reorder`` applies a block-densifying row permutation first (any
    scheme in ``core.permute.SCHEMES`` that yields a pure row permutation:
    ``jaccard`` | ``rcm`` | ``shard_balance`` | ``identity``).  The
    permutation is transparent downstream: ``spmm`` un-permutes its output
    (C = P^T (A' B)) and the custom VJP carries P through dB and dvals, so
    results match ``reorder="identity"`` while the kernel streams the
    denser A'.  ``reorder_granularity="element"`` (default) re-blocks the
    permuted NONZERO structure — explicitly-stored zero blocks do not
    survive it; ``"block_row"`` permutes whole block-rows instead (nnzb
    and all stored entries preserved — the model-weight path, where
    stacked leaf shapes must be static and zero blocks stay trainable).

    The returned ``meta`` carries the POST-reorder structure stats
    (``max_bpr``, padding, skew) — the autotune fingerprint and the
    ``row_loop`` static schedule are both derived from the permuted
    structure, so clustering that densifies block-rows shrinks the
    schedule (``meta.row_loop_sched_len``).

    Example (a block-diagonal 32x32 with 8x8 blocks):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import ops
    >>> dense = np.kron(np.eye(4, dtype=np.float32), np.ones((8, 8)))
    >>> a = bcsr_lib.from_dense(dense.astype(np.float32), (8, 8))
    >>> arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    >>> (meta.nnzb, meta.max_bpr, meta.row_loop_sched_len)
    (4, 1, 4)
    """
    host, meta = _prepare_sparse_host(
        a, reorder=reorder, reorder_granularity=reorder_granularity,
        tau=tau, max_candidates=max_candidates, n_shards=n_shards)
    arrays = SparseArrays(
        vals=jnp.asarray(host["vals"], dtype=dtype),
        row_ids=jnp.asarray(host["row_ids"], dtype=jnp.int32),
        col_ids=jnp.asarray(host["col_ids"], dtype=jnp.int32),
        real_mask=jnp.asarray(host["real_mask"]),
        t_perm=jnp.asarray(host["t_perm"], dtype=jnp.int32),
        t_row_ids=jnp.asarray(host["t_row_ids"], dtype=jnp.int32),
        t_col_ids=jnp.asarray(host["t_col_ids"], dtype=jnp.int32),
        row_perm=jnp.asarray(host["row_perm"], dtype=jnp.int32),
        inv_perm=jnp.asarray(host["inv_perm"], dtype=jnp.int32),
    )
    return arrays, meta


def prepare_sparse_meta(a: bcsr_lib.BCSR, *, reorder: str = "identity",
                        reorder_granularity: str = "element",
                        tau: float = 0.7,
                        max_candidates: Optional[int] = None,
                        n_shards: int = 8) -> SparseMeta:
    """The static meta ``prepare_sparse`` would return, WITHOUT building
    device arrays — bit-identical by construction (same host pipeline).

    This is the backbone of the static structure-metadata pipeline: model
    layers re-derive the true post-reorder stats of a deterministic weight
    pattern at trace time (``core.sparse_linear.sparse_linear_meta``
    memoizes it), so ``backend="auto"`` and ``row_loop`` dispatch on real
    ``max_bpr``/padding/skew instead of dims-only zeros."""
    return _prepare_sparse_host(
        a, reorder=reorder, reorder_granularity=reorder_granularity,
        tau=tau, max_candidates=max_candidates, n_shards=n_shards)[1]


def prepare(a: bcsr_lib.BCSR, dtype=jnp.bfloat16, *,
            meta_only: bool = False, reorder: str = "identity",
            reorder_granularity: str = "element", tau: float = 0.7,
            max_candidates: Optional[int] = None, n_shards: int = 8):
    """Unified entry point for the local prepare twins (PR 8).

    ``meta_only=False`` (default) delegates to :func:`prepare_sparse` and
    returns ``(SparseArrays, SparseMeta)``; ``meta_only=True`` delegates
    to :func:`prepare_sparse_meta` and returns the ``SparseMeta`` alone
    (``dtype`` is ignored — meta is dtype-free by construction).  The
    twins stay as documented aliases; this is the name the package facade
    (``repro.prepare``) and the quickstart use.

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import ops
    >>> dense = np.kron(np.eye(4, dtype=np.float32), np.ones((8, 8)))
    >>> a = bcsr_lib.from_dense(dense, (8, 8))
    >>> arrays, meta = ops.prepare(a, dtype=jnp.float32)
    >>> ops.prepare(a, meta_only=True) == meta
    True
    """
    kw = dict(reorder=reorder, reorder_granularity=reorder_granularity,
              tau=tau, max_candidates=max_candidates, n_shards=n_shards)
    if meta_only:
        return prepare_sparse_meta(a, **kw)
    return prepare_sparse(a, dtype, **kw)


# ------------------------------------------------------------ forward pieces
def _clamp_bn(bn: int, n: int) -> int:
    """Effective N-tile width: the configured bn, capped at N rounded up to
    the 128-lane width (a wider tile would only multiply padding).  This is
    what makes bn a real tuning dimension — the seed code clamped every bn
    to 128 (``min(cfg.bn, max(128, 1))``), so 256/512/1024 all ran the same
    grid."""
    return max(min(bn, -(-n // 128) * 128), 1)


def _pad_b(b: jnp.ndarray, w: int, bn: int):
    K, N = b.shape
    k_pad = (-K) % w
    n_pad = (-N) % bn
    if k_pad or n_pad:
        b = jnp.pad(b, ((0, k_pad), (0, n_pad)))
    return b, N


def _sddmm_row_loop_schedule(row_ids: jnp.ndarray, col_ids: jnp.ndarray,
                             n_block_rows: int, max_bpr: int):
    """Traced (flat_idx, flat_col) for the static-schedule SDDMM kernel:
    per (row, slot), the OUTPUT entry index and block-col.  Padding slots
    point at the sentinel entry ``nnzb`` (the kernel computes and discards
    their product — the static waste the ``row_loop`` family pays)."""
    nnzb = row_ids.shape[0]
    ones = jnp.ones((nnzb,), jnp.int32)
    row_len = jax.ops.segment_sum(ones, row_ids, num_segments=n_block_rows)
    rowptr = jnp.concatenate([jnp.zeros((1,), row_len.dtype),
                              jnp.cumsum(row_len)])
    slot = jnp.arange(nnzb, dtype=jnp.int32) - rowptr[row_ids].astype(jnp.int32)
    pos = row_ids * max_bpr + slot
    flat_idx = jnp.full((n_block_rows * max_bpr,), nnzb, jnp.int32
                        ).at[pos].set(jnp.arange(nnzb, dtype=jnp.int32))
    flat_col = jnp.zeros((n_block_rows * max_bpr,), jnp.int32
                         ).at[pos].set(col_ids)
    return flat_idx, flat_col


def _row_loop_schedule(row_ids: jnp.ndarray, col_ids: jnp.ndarray,
                       n_block_rows: int, max_bpr: int):
    """Traced (jnp) version of ``make_row_loop_schedule``: builds the padded
    (flat_idx, flat_col, row_len) arrays from the sorted row-major entry
    list, so the static-schedule kernel is dispatchable straight from
    ``SparseArrays`` (inside jit, no host BCSR needed).  Padding slots point
    at entry 0 / column 0, matching the host builder."""
    nnzb = row_ids.shape[0]
    ones = jnp.ones((nnzb,), jnp.int32)
    row_len = jax.ops.segment_sum(ones, row_ids, num_segments=n_block_rows)
    rowptr = jnp.concatenate([jnp.zeros((1,), row_len.dtype),
                              jnp.cumsum(row_len)])
    slot = jnp.arange(nnzb, dtype=jnp.int32) - rowptr[row_ids].astype(jnp.int32)
    pos = row_ids * max_bpr + slot
    flat_idx = jnp.zeros((n_block_rows * max_bpr,), jnp.int32
                         ).at[pos].set(jnp.arange(nnzb, dtype=jnp.int32))
    flat_col = jnp.zeros((n_block_rows * max_bpr,), jnp.int32
                         ).at[pos].set(col_ids)
    return flat_idx, flat_col, row_len.astype(jnp.int32)


def _fwd_impl(cfg: SpmmConfig, meta: SparseMeta, arrays: SparseArrays,
              b: jnp.ndarray) -> jnp.ndarray:
    h, w = meta.block
    M, K = meta.shape
    out_dtype = jnp.dtype(cfg.out_dtype) if cfg.out_dtype else b.dtype
    bn = _clamp_bn(cfg.bn, b.shape[1])
    b_p, N = _pad_b(b, w, bn)
    bn = min(bn, b_p.shape[1])
    if cfg.backend == "pallas":
        out = pk.bcsr_spmm_nnz_stream(
            arrays.vals, arrays.row_ids, arrays.col_ids, b_p,
            meta.n_block_rows, bn=bn, out_dtype=out_dtype,
            interpret=cfg.interpret)
    elif cfg.backend == "row_loop":
        if meta.max_bpr <= 0:
            raise ValueError(
                "backend='row_loop' needs meta.max_bpr > 0 (metas built by "
                "prepare_sparse have it; hand-built specs metas do not)")
        flat_idx, flat_col, row_len = _row_loop_schedule(
            arrays.row_ids, arrays.col_ids, meta.n_block_rows, meta.max_bpr)
        out = pk.bcsr_spmm_row_loop(
            arrays.vals, flat_idx, flat_col, row_len, b_p,
            meta.n_block_rows, bn=bn, out_dtype=out_dtype,
            interpret=cfg.interpret)
    elif cfg.backend == "xla":
        out = ref.bcsr_spmm_ref(arrays.vals, arrays.row_ids, arrays.col_ids,
                                b_p, meta.n_block_rows, out_dtype=out_dtype)
    elif cfg.backend == "dense":
        dense = materialize_dense(arrays, meta)
        out = ref.spmm_dense_ref(dense, b_p[: dense.shape[1]],
                                 out_dtype=out_dtype)
    else:
        raise ValueError(f"unknown backend {cfg.backend!r}")
    out = out[:M, :N]
    if meta.reorder != "identity" and arrays.inv_perm is not None:
        # kernel computed C' = A' B in permuted row order; hand back
        # C = P^T C' so the permutation never leaks to callers
        out = jnp.take(out, arrays.inv_perm, axis=0)
    return out


def _dx_impl(cfg: SpmmConfig, meta: SparseMeta, arrays: SparseArrays,
             g: jnp.ndarray) -> jnp.ndarray:
    """dB = A^T @ dC via the transpose structure."""
    h, w = meta.block
    M, K = meta.shape
    sentinel = jnp.zeros((1,) + tuple(arrays.vals.shape[1:]),
                         dtype=arrays.vals.dtype)
    vals_ext = jnp.concatenate([arrays.vals, sentinel], axis=0)
    t_vals = jnp.transpose(vals_ext[arrays.t_perm], (0, 2, 1))  # [nnzb_t,w,h]
    bn = _clamp_bn(cfg.bn, g.shape[1])
    g_p, N = _pad_b(g, h, bn)
    bn = min(bn, g_p.shape[1])
    # row_loop is a forward-schedule choice; the backward always streams the
    # transpose structure (whose row skew differs from A's).
    if cfg.backend in ("pallas", "row_loop"):
        out = pk.bcsr_spmm_nnz_stream(
            t_vals, arrays.t_row_ids, arrays.t_col_ids, g_p,
            meta.n_block_cols, bn=bn, out_dtype=g.dtype,
            interpret=cfg.interpret)
    else:
        out = ref.bcsr_spmm_ref(t_vals, arrays.t_row_ids, arrays.t_col_ids,
                                g_p, meta.n_block_cols, out_dtype=g.dtype)
    return out[:K, :N]


def _sddmm_impl(cfg: SpmmConfig, meta: SparseMeta, arrays: SparseArrays,
                x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """vals[s] = X'[block row_ids[s]] @ Y[block col_ids[s]]^T — the dense
    pair sampled at the stored structure (X' = P X when the structure was
    prepared with a reorder; callers pass X in ORIGINAL row order).

    Backends mirror the SpMM family: ``pallas`` streams the nonzero-block
    list, ``row_loop`` runs the static (block-row x slot) schedule,
    ``xla`` is the gather/einsum oracle, ``dense`` materializes the full
    X @ Y^T and gathers blocks.  Padding entries (``real_mask`` False) are
    zeroed — they are structural, not values."""
    h, w = meta.block
    if meta.reorder != "identity" and arrays.row_perm is not None:
        x = jnp.take(x, arrays.row_perm, axis=0)
    out_dtype = jnp.dtype(cfg.out_dtype) if cfg.out_dtype else x.dtype
    bn = _clamp_bn(cfg.bn, max(x.shape[1], y.shape[1]))
    x_p, _ = _pad_b(x, h, bn)
    y_p, _ = _pad_b(y, w, bn)
    n_pad = max(x_p.shape[1], y_p.shape[1])
    x_p = jnp.pad(x_p, ((0, 0), (0, n_pad - x_p.shape[1])))
    y_p = jnp.pad(y_p, ((0, 0), (0, n_pad - y_p.shape[1])))
    bn = min(bn, n_pad)
    if cfg.backend == "pallas":
        vals = pk.bcsr_sddmm(x_p, y_p, arrays.row_ids, arrays.col_ids,
                             h, w, bn=bn, out_dtype=out_dtype,
                             interpret=cfg.interpret)
    elif cfg.backend == "row_loop":
        if meta.max_bpr <= 0:
            raise ValueError(
                "backend='row_loop' needs meta.max_bpr > 0 (metas built by "
                "prepare_sparse have it; hand-built specs metas do not)")
        flat_idx, flat_col = _sddmm_row_loop_schedule(
            arrays.row_ids, arrays.col_ids, meta.n_block_rows, meta.max_bpr)
        vals = pk.bcsr_sddmm_row_loop(
            x_p, y_p, flat_idx, flat_col, meta.n_block_rows, meta.nnzb,
            h, w, bn=bn, out_dtype=out_dtype, interpret=cfg.interpret)
    elif cfg.backend == "xla":
        vals = ref.bcsr_sddmm_ref(x_p, y_p, arrays.row_ids, arrays.col_ids,
                                  h, w, out_dtype=out_dtype)
    elif cfg.backend == "dense":
        vals = ref.bcsr_sddmm_dense_ref(x_p, y_p, arrays.row_ids,
                                        arrays.col_ids, h, w,
                                        out_dtype=out_dtype)
    else:
        raise ValueError(f"unknown backend {cfg.backend!r}")
    # padding entries are structural zeros — never values, never gradients
    return vals * arrays.real_mask[:, None, None].astype(vals.dtype)


def materialize_dense(arrays: SparseArrays, meta: SparseMeta) -> jnp.ndarray:
    """Scatter the blocks into the padded dense matrix (cuBLAS arm)."""
    h, w = meta.block
    nbr, nbc = meta.n_block_rows, meta.n_block_cols
    flat = jnp.zeros((nbr * nbc, h, w), dtype=arrays.vals.dtype)
    flat = flat.at[arrays.row_ids * nbc + arrays.col_ids].add(arrays.vals)
    dense = flat.reshape(nbr, nbc, h, w).transpose(0, 2, 1, 3)
    return dense.reshape(nbr * h, nbc * w)


# ----------------------------------------------------------------- custom vjp
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _spmm(cfg: SpmmConfig, meta: SparseMeta, vals: jnp.ndarray,
          b: jnp.ndarray, rest: tuple) -> jnp.ndarray:
    arrays = SparseArrays(vals, *rest)
    return _fwd_impl(cfg, meta, arrays, b)


def _spmm_fwd(cfg, meta, vals, b, rest):
    arrays = SparseArrays(vals, *rest)
    return _fwd_impl(cfg, meta, arrays, b), (vals, b, rest)


def _spmm_bwd(cfg, meta, res, g):
    vals, b, rest = res
    arrays = SparseArrays(vals, *rest)
    g2 = g.astype(b.dtype)
    if meta.reorder != "identity" and arrays.row_perm is not None:
        # cotangent arrives in ORIGINAL row order; the stored structure is
        # A' = P A, so dB = A'^T (P dC) needs the permuted cotangent
        # g' = P g (the SDDMM op permutes its X operand itself)
        g2 = jnp.take(g2, arrays.row_perm, axis=0)
    db = _dx_impl(cfg, meta, arrays, g2)[: b.shape[0], : b.shape[1]]
    # dvals through the SDDMM op — SpMM and SDDMM are mutual duals, so
    # higher-order AD recurses between the two custom VJPs
    cfg_d = dataclasses.replace(cfg, out_dtype=str(vals.dtype))
    dvals = _sddmm(cfg_d, meta, g.astype(b.dtype), b, rest)
    zeros_rest = jax.tree.map(
        lambda x: np.zeros(x.shape, jax.dtypes.float0), rest)
    return dvals, db.astype(b.dtype), zeros_rest


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sddmm(cfg: SpmmConfig, meta: SparseMeta, x: jnp.ndarray,
           y: jnp.ndarray, rest: tuple) -> jnp.ndarray:
    arrays = SparseArrays(x, *rest)   # vals slot unused by the sampling
    return _sddmm_impl(cfg, meta, arrays, x, y)


def _sddmm_fwd(cfg, meta, x, y, rest):
    arrays = SparseArrays(x, *rest)
    return _sddmm_impl(cfg, meta, arrays, x, y), (x, y, rest)


def _sddmm_bwd(cfg, meta, res, g):
    x, y, rest = res
    real_mask = rest[2]
    gm = g * real_mask[:, None, None].astype(g.dtype)
    cfg_b = dataclasses.replace(cfg, out_dtype=None)
    # dX = G @ Y — exactly the SpMM forward on the cotangent blocks (the
    # op un-permutes back to original row order itself); dY = G^T @ X'
    # via the stored transpose structure, with X' = P X matching the
    # permuted sampling of the forward
    dx = _spmm(cfg_b, meta, gm.astype(y.dtype), y, rest)
    garr = SparseArrays(gm.astype(y.dtype), *rest)
    xp = x
    if meta.reorder != "identity" and garr.row_perm is not None:
        xp = jnp.take(x, garr.row_perm, axis=0)
    dy = _dx_impl(cfg_b, meta, garr, xp)[: y.shape[0], : y.shape[1]]
    zeros_rest = jax.tree.map(
        lambda t: np.zeros(t.shape, jax.dtypes.float0), rest)
    return dx.astype(x.dtype), dy.astype(y.dtype), zeros_rest


_sddmm.defvjp(_sddmm_fwd, _sddmm_bwd)


# ------------------------------------------------------------------ public API
def resolve_backend(backend: str, bn: int, meta: SparseMeta,
                    n: int, op: str = "spmm") -> Tuple[str, int]:
    """Normalize aliases and resolve ``auto`` through the variant registry.

    ``auto`` needs only static info (meta + N), so this is safe at trace
    time; a cache miss falls back to the analytic perf-model pick (timed
    sweeps only happen via explicit ``autotune.Autotuner.tune`` calls).
    ``op`` selects the variant family (``"spmm"`` | ``"sddmm"``) — the two
    share backend strings but fingerprint separately (v6 ``op=`` field),
    so an SpMM pick can never alias an SDDMM one.
    """
    if backend == "auto":
        from repro.kernels import autotune  # local import: avoids cycle
        choice = autotune.get_autotuner().pick(meta, n, op=op)
        backend, bn = choice.backend, choice.bn
        if backend == "row_loop" and meta.max_bpr <= 0:
            backend = "pallas"  # stale cached pick for a specs meta
    backend = _BACKEND_ALIASES.get(backend, backend)
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS + ('auto', 'nnz_stream')}")
    if backend == "row_loop" and meta.max_bpr <= 0:
        # explicit request we cannot honor — raising beats silently timing
        # a different kernel than the caller asked for
        raise ValueError(
            "backend='row_loop' needs meta.max_bpr > 0 (metas built by "
            "prepare_sparse / prepare_sparse_meta have it; dims-only "
            "specs metas do not — pass sparse_linear_specs a seed, or "
            "use the model path's sparse_linear_meta)")
    if os.environ.get("REPRO_VERIFY_LAUNCH") == "1":
        # opt-in pre-launch contract check: meta invariants, schedule
        # capacity, and the VMEM budget, all symbolic (repro.analysis)
        from repro.analysis import verify_launch as _verify_launch
        _verify_launch.assert_launch_ok(meta, backend, n=n, bn=bn, op=op)
    # host-side dispatch record (static info only, so trace-time safe —
    # same argument as the `auto` resolution above)
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    obs_trace.event("ops.dispatch", op=op, backend=backend, bn=bn, n=n,
                    nnzb=meta.nnzb, max_bpr=meta.max_bpr)
    obs_metrics.counter("ops.dispatch", op=op, backend=backend).inc()
    return backend, bn


def spmm(arrays: SparseArrays, meta: SparseMeta, b: jnp.ndarray,
         *, backend: str = "pallas", bn: int = 512,
         interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """C = A @ B, differentiable w.r.t. ``arrays.vals`` and ``b``.

    A is the BCSR operand from ``prepare_sparse``; B is ``[K, N]`` dense.
    ``backend="auto"`` dispatches through the ``repro.kernels.autotune``
    registry using the matrix's stats fingerprint.  Outputs always come
    back in ORIGINAL row order, whatever ``reorder`` scheme prepared A.

    Example (sparse x dense against the dense oracle):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import ops
    >>> rng = np.random.default_rng(0)
    >>> dense = np.kron(rng.random((4, 4)) < 0.5,
    ...                 np.ones((8, 8))).astype(np.float32)
    >>> a = bcsr_lib.from_dense(dense, (8, 8))
    >>> arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    >>> b = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    >>> c = ops.spmm(arrays, meta, b, backend="xla")
    >>> c.shape
    (32, 16)
    >>> bool(jnp.allclose(c, dense @ np.asarray(b), atol=1e-5))
    True
    """
    backend, bn = resolve_backend(backend, bn, meta, int(b.shape[-1]))
    cfg = SpmmConfig(backend=backend, bn=bn, interpret=interpret,
                     out_dtype=str(jnp.dtype(out_dtype))
                     if out_dtype else None)
    rest = tuple(arrays[1:])
    return _spmm(cfg, meta, arrays.vals, b, rest)


def sddmm(arrays: SparseArrays, meta: SparseMeta, x: jnp.ndarray,
          y: jnp.ndarray, *, backend: str = "pallas", bn: int = 512,
          interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """Sampled dense-dense matmul: the blocks of ``X @ Y^T`` stored by the
    structure of ``(arrays, meta)`` — SpMM's dual, promoted from the SpMM
    VJP's private dW helper to a first-class op (the score kernel of
    block-sparse attention: ``Q K^T`` sampled on a BCSR mask).

    ``X`` is ``[M, N]`` (original row order — a reorder baked into the
    structure is applied internally, mirroring ``spmm``), ``Y`` is
    ``[K, N]``; the result is ``[nnzb, h, w]`` with padding entries
    (``real_mask`` False) zeroed.  Differentiable w.r.t. ``x`` and ``y``:
    dX runs as an SpMM of the cotangent blocks against ``Y``, dY as an
    SpMM through the stored transpose structure — the two ops are
    mutually recursive duals, so higher-order AD bounces between their
    custom VJPs (to any order on the pure-jnp ``xla`` backend; the
    Pallas leaf kernels have no JVP rule, capping the order there).
    ``backend="auto"`` resolves through the
    ``repro.kernels.autotune`` SDDMM variant family (v6 ``op=sddmm``
    fingerprints — never aliased with SpMM picks).

    Example (sampled product vs the dense masked oracle):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import ops
    >>> rng = np.random.default_rng(0)
    >>> dense = np.kron(rng.random((4, 4)) < 0.5,
    ...                 np.ones((8, 8))).astype(np.float32)
    >>> a = bcsr_lib.from_dense(dense, (8, 8))
    >>> arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    >>> x = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    >>> y = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    >>> vals = ops.sddmm(arrays, meta, x, y, backend="xla")
    >>> vals.shape == (meta.nnzb, 8, 8)
    True
    >>> full = np.asarray(x) @ np.asarray(y).T   # dense X Y^T, then sample
    >>> blk = full.reshape(4, 8, 4, 8).transpose(0, 2, 1, 3)[
    ...     np.asarray(arrays.row_ids), np.asarray(arrays.col_ids)]
    >>> blk *= np.asarray(arrays.real_mask)[:, None, None]  # padding -> 0
    >>> bool(jnp.allclose(vals, blk, atol=1e-4))
    True
    """
    backend, bn = resolve_backend(backend, bn, meta, int(x.shape[-1]),
                                  op="sddmm")
    cfg = SpmmConfig(backend=backend, bn=bn, interpret=interpret,
                     out_dtype=str(jnp.dtype(out_dtype))
                     if out_dtype else None)
    rest = tuple(arrays[1:])
    return _sddmm(cfg, meta, x, y, rest)


def make_row_loop_schedule(a: bcsr_lib.BCSR):
    """Host-side padded (flat_idx, flat_col, row_len, max_bpr) for the
    paper-faithful static kernel."""
    bpr = a.blocks_per_row()
    nbr = a.n_block_rows
    max_bpr = max(int(bpr.max()) if bpr.size else 1, 1)
    flat_idx = np.zeros(nbr * max_bpr, dtype=np.int32)
    flat_col = np.zeros(nbr * max_bpr, dtype=np.int32)
    for i in range(nbr):
        s0, s1 = int(a.rowptr[i]), int(a.rowptr[i + 1])
        flat_idx[i * max_bpr: i * max_bpr + (s1 - s0)] = np.arange(
            s0, s1, dtype=np.int32)
        flat_col[i * max_bpr: i * max_bpr + (s1 - s0)] = a.col_ids[s0:s1]
    return (jnp.asarray(flat_idx), jnp.asarray(flat_col),
            jnp.asarray(bpr.astype(np.int32)), max_bpr)
