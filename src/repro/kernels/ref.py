"""Pure-jnp oracles for the BCSR SpMM kernels.

These are the reference semantics every Pallas kernel is tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose), and
they double as the ``xla`` backend used by the 512-device dry-run (gather +
einsum + segment_sum lower to shardable XLA HLO on any backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bcsr_spmm_ref(vals: jnp.ndarray, row_ids: jnp.ndarray,
                  col_ids: jnp.ndarray, b: jnp.ndarray,
                  n_block_rows: int, out_dtype=None) -> jnp.ndarray:
    """C = A @ B with A in BCSR block form.

    vals     [nnzb, h, w]
    row_ids  [nnzb] block-row of each block
    col_ids  [nnzb] block-col of each block
    b        [K, N] dense (K must be a multiple of w)
    returns  [n_block_rows * h, N]
    """
    nnzb, h, w = vals.shape
    K, N = b.shape
    assert K % w == 0, (K, w)
    b_blocks = b.reshape(K // w, w, N)
    gathered = b_blocks[col_ids]  # [nnzb, w, N]
    prod = jnp.einsum(
        "shw,swn->shn",
        vals.astype(jnp.float32),
        gathered.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = jax.ops.segment_sum(prod, row_ids, num_segments=n_block_rows)
    out = out.reshape(n_block_rows * h, N)
    return out.astype(out_dtype or b.dtype)


def bcsr_sddmm_ref(dc: jnp.ndarray, b: jnp.ndarray, row_ids: jnp.ndarray,
                   col_ids: jnp.ndarray, h: int, w: int,
                   out_dtype=None) -> jnp.ndarray:
    """dVals = (dC @ B^T) sampled at the nonzero blocks (the weight gradient
    of the sparse operand).

    dc       [M, N]   upstream cotangent (M multiple of h)
    b        [K, N]   the dense forward operand (K multiple of w)
    returns  [nnzb, h, w]
    """
    M, N = dc.shape
    K, _ = b.shape
    dc_blocks = dc.reshape(M // h, h, N)[row_ids]   # [nnzb, h, N]
    b_blocks = b.reshape(K // w, w, N)[col_ids]     # [nnzb, w, N]
    dvals = jnp.einsum(
        "shn,swn->shw",
        dc_blocks.astype(jnp.float32),
        b_blocks.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return dvals.astype(out_dtype or dc.dtype)


def bcsr_sddmm_dense_ref(dc: jnp.ndarray, b: jnp.ndarray,
                         row_ids: jnp.ndarray, col_ids: jnp.ndarray,
                         h: int, w: int, out_dtype=None) -> jnp.ndarray:
    """The dense-masked arm of SDDMM: materialize the FULL ``dC @ B^T``
    product on the MXU, then gather the stored blocks.  Wins when the
    structure is near-dense (block coverage so high that skipping blocks
    saves less than the gather costs); the autotuner's ``sddmm_dense``
    variant lowers to this."""
    M, N = dc.shape
    K, _ = b.shape
    full = jnp.dot(dc.astype(jnp.float32), b.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)        # [M, K]
    blocks = full.reshape(M // h, h, K // w, w).transpose(0, 2, 1, 3)
    return blocks[row_ids, col_ids].astype(out_dtype or dc.dtype)


def spmm_dense_ref(a_dense: jnp.ndarray, b: jnp.ndarray,
                   out_dtype=None) -> jnp.ndarray:
    """The cuBLAS stand-in: multiply the (explicitly padded) dense matrix."""
    out = jnp.dot(a_dense.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out.astype(out_dtype or b.dtype)


def spmm_csr_ref(data: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray,
                 b: jnp.ndarray, m: int, out_dtype=None) -> jnp.ndarray:
    """The cuSPARSE stand-in: scalar COO/CSR SpMM via gather + segment_sum
    (one elementary op per nonzero — the paper's n_e upper-bound regime)."""
    prod = data.astype(jnp.float32)[:, None] * b[cols].astype(jnp.float32)
    out = jax.ops.segment_sum(prod, rows, num_segments=m)
    return out.astype(out_dtype or b.dtype)
