"""Mesh-aware activation sharding constraints.

``constrain(x, axis0, axis1, ...)`` applies ``with_sharding_constraint``
with the given logical axes when a mesh context is active (dry-run / real
launch); it is a no-op in mesh-less unit tests.  Axes missing from the
active mesh or not dividing the dimension are dropped.

``BATCH`` is the conventional hierarchical batch axis (pod+data).
"""
from __future__ import annotations

import numpy as np
import jax
from jax._src import mesh as _mesh_src
from jax.sharding import PartitionSpec as P

BATCH = ("pod", "data")
MODEL = "model"


def _active_mesh():
    env = _mesh_src.thread_resources.env
    m = env.physical_mesh
    return None if m.empty else m


def constrain(x, *axes):
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def fit(a, dim):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x_ for x_ in a if x_ in names)
            while kept and dim % int(np.prod([mesh.shape[k] for k in kept])):
                kept = kept[:-1]
            return kept or None
        if a not in names or dim % int(mesh.shape[a]):
            return None
        return a

    spec = [fit(a, d) for a, d in zip(axes, x.shape)]
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*spec))
