"""Sharded SpMM execution: row-partitioned BCSR over a device mesh.

SMaT's single-device wins only reach the serving north star if the SpMM
scales past one chip.  This module turns the reorder pipeline's dormant
``shard_balance`` scheme into a working scaling axis:

  * ``prepare_sharded`` partitions a host BCSR over block-rows (1D) using
    the capacitated LPT bin assignment from ``core.permute.shard_bins``:
    every shard owns exactly ``rows_per_shard`` block-row slots (trailing
    slots virtual/empty) and a fixed ``nnzb_per_shard`` entry budget, so
    the per-shard schedules are STATIC — scan/jit shapes never depend on
    which shard a block landed in.  Per-shard nonzero-block loads come out
    near-equal (the paper's mip1 observation, lifted from warps to
    devices; Acc-SpMM makes the same point for TC pipelines).
  * ``spmm_sharded`` executes the partition either as a ``shard_map`` over
    a dedicated mesh axis (real multi-device execution; the column split
    over B adds an optional 2D axis) or as an in-process "local" loop with
    identical math (the fallback when no compatible mesh exists — unit
    tests, single-chip serving).  Each shard resolves its OWN kernel
    variant through ``ops.resolve_backend``: per-shard metas carry
    ``n_shards`` into the v7 autotune fingerprint, and shards whose picks
    differ dispatch through a ``lax.switch`` on the mesh axis index.
  * ``spmm_sharded(n_chunks=K)`` pipelines the B operand movement against
    shard compute (Acc-SpMM's overlap, lifted to the collective level):
    the panel is cut into K ascending column chunks over the ``spmm_col``
    axis and the staging of chunk k+1 is ISSUED before the matmul over
    chunk k (``lax.optimization_barrier`` pins the issue order; XLA's
    async copy/collective engine runs the movement under the compute).
    Column panels of a matmul are independent, so the chunked result is
    BIT-IDENTICAL to the unchunked one — fixed ascending chunk order,
    same per-column accumulation tree, and kernel picks resolved at the
    full panel width (``tests/test_sharded_properties.py`` pins this).
  * Shard count is an AUTOTUNE AXIS: ``prepare_sharded(a, "auto")``
    resolves S through ``Autotuner.pick_shards`` (analytic pipeline
    model over {1,2,4,8}, cached under ``shards|max=<M>|<v7 nk= key>``),
    and ``tune_shard_count`` runs the timed S micro-sweep.
  * Extreme single-row skew is handled by ENTRY-GRANULAR SPLITS
    (``split_heavy_rows=True``): a block-row heavier than the balanced
    per-shard budget splits into contiguous entry fragments placed by the
    same LPT (``core.permute.split_heavy_rows``), and the row's partial
    sums recombine with a scatter-add at gather time.  Without splits, a
    structure whose derived budget would silently over-allocate (every
    shard padded to one dominant row's size) now raises instead.
  * Results gather back to ORIGINAL row order (``gather_rows`` composes
    the optional pre-reorder with the partition permutation), so the
    sharding — like the PR 2 reorder — never leaks to callers; gradients
    flow through the inner per-shard ``ops.spmm`` custom VJP, the
    ``shard_map`` transpose (partial dB psums across shards), and the
    outer gather's transpose (padding rows receive exact zeros).

Wired end-to-end via ``SparsitySpec(shards=...)`` (``shards="auto"``
resolves through the same pick) -> ``init_sparse_linear`` ->
``apply_sparse_linear`` (which reads the ambient mesh from
``use_spmm_mesh`` and the overlap depth from ``spec.shard_chunks``) ->
the serve engine's decode path; ``launch.dryrun`` reports the per-shard
nnzb balance, resolved S, and chunk schedule of sparse layers.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

try:  # moved to the public namespace on newer JAX
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover - newer JAX
    _shard_map = jax.shard_map

from repro.core import bcsr as bcsr_lib
from repro.core import permute as permute_lib
from repro.kernels import ops
from repro.launch import mesh as mesh_lib
from repro.obs import jaxmon
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

AXIS_ROW = "spmm"        # mesh axis the block-row partition maps onto
AXIS_COL = "spmm_col"    # optional 2D axis: column split over B


# ---------------------------------------------------------------------- types
class ShardedArrays(NamedTuple):
    """Device arrays of a row-partitioned BCSR operand (pytree leaves).

    ``vals`` stays the FLAT global entry list — the single trainable leaf,
    shaped exactly like the unsharded operand's so parameter trees,
    optimizers, and sharding rules are unchanged.  The per-shard leaves
    are index structure only (leading axis = shard):

      src_index  [S, nnzb_ps]    entry index into vals (nnzb = zero sentinel)
      row_ids    [S, nnzb_ps]    LOCAL block-row ids, sorted row-major
      col_ids    [S, nnzb_ps]    global block-col ids
      real_mask  [S, nnzb_ps]    False for sentinel/padding entries
      t_perm     [S, nnzb_t_ps]  local transpose gather (nnzb_ps = sentinel)
      t_row_ids  [S, nnzb_t_ps]  block-rows of the local A^T (= global bcols)
      t_col_ids  [S, nnzb_t_ps]  LOCAL block-rows of A
      gather_rows [M]            original row -> row of the stacked shard
                                 outputs (composes pre-reorder + partition)
      split_src   [n_extra]      stacked-output rows of NON-PRIMARY row
                                 fragments (entry-granular splits); empty
                                 (0,) when no block-row was split
      split_dst   [n_extra]      original rows those partial sums add into
                                 (``out.at[split_dst].add(out_pad[split_src])``)
    """
    vals: jnp.ndarray
    src_index: jnp.ndarray
    row_ids: jnp.ndarray
    col_ids: jnp.ndarray
    real_mask: jnp.ndarray
    t_perm: jnp.ndarray
    t_row_ids: jnp.ndarray
    t_col_ids: jnp.ndarray
    gather_rows: jnp.ndarray
    split_src: Optional[jnp.ndarray] = None
    split_dst: Optional[jnp.ndarray] = None


@dataclasses.dataclass(frozen=True)
class ShardedMeta:
    """Static (hashable) metadata of a sharded operand.

    ``shard_metas[s]`` is a full per-shard ``SparseMeta`` (shape
    ``(rows_per_shard*h, K)``, ``nnzb = nnzb_per_shard``, its own
    max_bpr/padding/skew stats, ``n_shards`` set) — the fingerprint the
    autotuner picks each shard's kernel variant from."""
    shape: Tuple[int, int]              # logical global (M, K)
    block: Tuple[int, int]
    n_shards: int
    col_shards: int
    rows_per_shard: int                 # block-row slots per shard
    nnzb: int                           # global flat entry count (vals leaf)
    nnzb_per_shard: int
    nnzb_t_per_shard: int
    shard_metas: Tuple[ops.SparseMeta, ...]
    reorder: str = "identity"           # pre-partition scheme (reporting)
    n_split_fragments: int = 0          # extra (non-primary) row fragments


# ------------------------------------------------------------- ambient mesh
_MESH_STACK: list = [None]


@contextlib.contextmanager
def use_spmm_mesh(mesh):
    """Route ``apply_sparse_linear``'s sharded path through ``mesh`` for the
    duration (trace-time setting: the mesh is baked into the jitted program
    traced inside).  ``mesh=None`` is a no-op passthrough."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_spmm_mesh():
    return _MESH_STACK[-1]


def make_spmm_mesh(n_shards: int, col_shards: int = 1):
    """Dedicated (n_shards,) or (n_shards, col_shards) mesh over the first
    local devices, axes ``(AXIS_ROW[, AXIS_COL])``."""
    need = n_shards * col_shards
    if jax.device_count() < need:
        raise ValueError(
            f"spmm mesh needs {need} devices, have {jax.device_count()} "
            "(CPU testing: XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if col_shards > 1:
        return mesh_lib.make_mesh((n_shards, col_shards), (AXIS_ROW, AXIS_COL))
    return mesh_lib.make_mesh((n_shards,), (AXIS_ROW,))


# ----------------------------------------------------------------- chunking
def chunk_schedule(n: int, n_chunks: int) -> Tuple[Tuple[int, int], ...]:
    """Ascending ``(start, stop)`` column chunks that partition ``[0, n)``.

    The schedule is the overlap pipeline's static contract: chunks are
    contiguous, strictly ascending, non-empty, and cover every column
    exactly once (``analysis.verify_launch.verify_chunk_schedule`` checks
    these invariants over the structure zoo).  ``n_chunks`` is clamped to
    ``n`` so tiny panels never produce empty chunks.

    >>> chunk_schedule(10, 4)
    ((0, 3), (3, 6), (6, 9), (9, 10))
    >>> chunk_schedule(8, 1)
    ((0, 8),)
    """
    if n < 1:
        raise ValueError(f"panel width must be >= 1, got {n}")
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    k = min(int(n_chunks), int(n))
    width = -(-n // k)
    bounds = []
    start = 0
    while start < n:
        stop = min(start + width, n)
        bounds.append((start, stop))
        start = stop
    return tuple(bounds)


def _barrier(x: jnp.ndarray) -> jnp.ndarray:
    try:
        return jax.lax.optimization_barrier(x)
    except AttributeError:      # pragma: no cover - very old JAX
        return x


@jax.custom_vjp
def _stage(x: jnp.ndarray) -> jnp.ndarray:
    """Pin the ISSUE point of a chunk's operand movement.

    ``optimization_barrier`` keeps XLA from sinking the staging of chunk
    k+1 below the matmul over chunk k, so the async copy/collective
    engine can run the movement under the compute.  Value-identity: the
    barrier never changes bits, only scheduling freedom — and the custom
    VJP passes the cotangent straight through (the barrier has no
    differentiation rule; the chunked forward's real backward runs the
    SINGLE-SHOT path anyway, see ``spmm_sharded``)."""
    return _barrier(x)


def _stage_fwd(x):
    return _barrier(x), None


def _stage_bwd(_, g):
    return (g,)


_stage.defvjp(_stage_fwd, _stage_bwd)


def _run_chunked(run_one, b: jnp.ndarray, n_chunks: int) -> jnp.ndarray:
    """Double-buffered chunk pipeline over the columns of ``b``.

    Issues the staging of chunk k+1 BEFORE the matmul over chunk k and
    concatenates the per-chunk panels in fixed ascending order.  Column
    panels of a matmul are independent — each output column sees the
    same accumulation tree as in the single-shot call — so the result is
    bit-identical to ``run_one(b)``."""
    n = int(b.shape[-1])
    bounds = chunk_schedule(n, n_chunks)
    if len(bounds) == 1:
        return run_one(b)
    lo0, hi0 = bounds[0]
    nxt = _stage(b[:, lo0:hi0])
    parts = []
    for i, _ in enumerate(bounds):
        cur = nxt
        if i + 1 < len(bounds):
            lo, hi = bounds[i + 1]
            nxt = _stage(b[:, lo:hi])
        parts.append(run_one(cur))
    return jnp.concatenate(parts, axis=1)


# ----------------------------------------------------------------- planning
def plan_shards(a_p: bcsr_lib.BCSR, n_shards: int, *,
                rows_per_shard: Optional[int] = None,
                nnzb_per_shard: Optional[int] = None):
    """Balanced block-row partition of a (row-padded) BCSR.

    Returns ``(assign, shard_rows, loads, rps)``: the LPT bin assignment
    (``core.permute.shard_bins``), per-shard sorted block-row lists, the
    per-shard nonzero-block loads, and the (resolved) row-slot count."""
    nbr = a_p.n_block_rows
    rps = rows_per_shard or -(-max(nbr, 1) // n_shards)
    bpr = np.diff(a_p.rowptr)
    max_load = nnzb_per_shard
    if max_load is not None:
        # every virtual (unassigned) row slot costs one sentinel entry on
        # whichever shard it lands; reserve the worst case up front so the
        # LPT never fills headroom the sentinels need — an assignment that
        # passes here is GUARANTEED to fit the real+virtual budget check
        v_max = min(max(n_shards * rps - nbr, 0), rps)
        max_load = max_load - v_max
    assign = permute_lib.shard_bins(
        bpr, n_shards, rows_per_shard=rps, max_load=max_load)
    shard_rows = [np.flatnonzero(assign == s) for s in range(n_shards)]
    loads = np.asarray([int(bpr[r].sum()) for r in shard_rows], np.int64)
    return assign, shard_rows, loads, rps


def _local_stats(rows: np.ndarray, vals_real: np.ndarray, rps: int,
                 nnzb_ps: int, block) -> Tuple[int, int, int]:
    """(max_bpr, pad_pct, cv_pct) of one shard's padded local structure."""
    h, w = block
    bpr = np.bincount(rows, minlength=rps).astype(np.float64)
    mean = float(bpr.mean()) if bpr.size else 0.0
    cv = float(bpr.std() / mean) if mean > 0 else 0.0
    nnz = int(np.count_nonzero(vals_real))
    pad = 1.0 - nnz / max(nnzb_ps * h * w, 1)
    return (int(bpr.max()) if bpr.size else 0, int(round(pad * 100)),
            int(round(cv * 100)))


@obs_trace.spanned("prepare.shard")
def _prepare_sharded_host(a: bcsr_lib.BCSR, n_shards, *,
                          col_shards: int = 1,
                          reorder: str = "identity", tau: float = 0.7,
                          max_candidates: Optional[int] = None,
                          rows_per_shard: Optional[int] = None,
                          nnzb_per_shard: Optional[int] = None,
                          split_heavy_rows: bool = False):
    """Host-side (numpy) portion of ``prepare_sharded``: pre-reorder,
    partition, per-shard index structure, and the static ``ShardedMeta``
    with its per-shard structure stats.  Returns ``(host_arrays_dict,
    meta)``; ``prepare_sharded`` converts to device arrays,
    ``prepare_sharded_meta`` keeps only the meta.

    ``n_shards="auto"`` resolves the shard count through
    :func:`resolve_n_shards`.  ``split_heavy_rows=True`` switches to
    ENTRY-GRANULAR planning: block-rows heavier than the balanced budget
    split into contiguous entry fragments (``core.permute
    .split_heavy_rows``) that the LPT places like rows; non-primary
    fragments are recombined by a scatter-add at gather time (their row
    indices land in ``split_src`` / ``split_dst``)."""
    if isinstance(n_shards, str):
        if n_shards != "auto":
            raise ValueError(f"n_shards must be an int or 'auto', "
                             f"got {n_shards!r}")
        n_shards = resolve_n_shards(a).n_shards
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    h, w = a.block
    M, K = a.shape
    pre_perm = np.arange(M, dtype=np.int64)
    if reorder not in ("identity", "shard_balance"):
        with obs_trace.span("prepare.shard.reorder", scheme=reorder):
            a, pre_perm = permute_lib.permute_bcsr(
                a, reorder, tau=tau, max_candidates=max_candidates,
                n_shards=n_shards, granularity="block_row")
    a_p, real_g = a.ensure_nonempty_rows(return_mask=True)
    nbr, nbc = a_p.n_block_rows, a_p.n_block_cols
    rowptr = a_p.rowptr
    bpr = np.diff(rowptr)
    nnzb_g = a_p.nnzb

    if split_heavy_rows:
        if nnzb_per_shard is not None:
            raise ValueError(
                "split_heavy_rows derives its own per-shard budget from "
                "the balanced load; pinning nnzb_per_shard alongside it "
                "is contradictory — drop one of the two")
        # fragment planning: heavy rows split into contiguous entry runs
        # no larger than the balanced per-shard load, then the SAME LPT
        # places fragments into row slots (a fragment is a local row)
        cap = max(-(-nnzb_g // n_shards), 1)
        frag_row, frag_start, frag_len = permute_lib.split_heavy_rows(
            bpr, cap)
        n_frags = int(frag_row.size)
        rps = rows_per_shard or -(-max(n_frags, 1) // n_shards)
        if rps * n_shards < n_frags:
            raise ValueError(
                f"rows_per_shard={rps} too small for {n_frags} row "
                f"fragments over {n_shards} shards")
        assign = permute_lib.shard_bins(frag_len, n_shards,
                                        rows_per_shard=rps)
        shard_units = [np.flatnonzero(assign == s) for s in range(n_shards)]
        shard_loads = np.asarray([int(frag_len[u].sum())
                                  for u in shard_units], np.int64)
        unit_row, unit_start, unit_len = frag_row, frag_start, frag_len
    else:
        assign, shard_units, shard_loads, rps = plan_shards(
            a_p, n_shards, rows_per_shard=rows_per_shard,
            nnzb_per_shard=nnzb_per_shard)
        if rps * n_shards < nbr:
            raise ValueError(f"rows_per_shard={rps} too small for {nbr} "
                             f"block-rows over {n_shards} shards")
        unit_row = np.arange(nbr, dtype=np.int64)
        unit_start = np.zeros(nbr, np.int64)
        unit_len = bpr.astype(np.int64)

    # per-shard balance record: the LPT's real loads, before padding
    # equalizes the static shapes (obs gauges feed the dryrun/bench views)
    mean_load = float(shard_loads.mean()) if shard_loads.size else 0.0
    imbalance = (round(float(shard_loads.max()) / mean_load, 3)
                 if mean_load > 0 else 1.0)
    obs_trace.event("dist.shard_balance", n_shards=n_shards,
                    loads=shard_loads, imbalance=imbalance,
                    split_heavy_rows=bool(split_heavy_rows))
    obs_metrics.gauge("dist.shard_imbalance", n_shards=n_shards).set(
        imbalance)

    # per-shard entry lists (entries stay in a_p's global order; local ids
    # relabel planning units — block-rows, or fragments of them — to each
    # shard's slot space)
    needed = []
    per_shard = []
    for s in range(n_shards):
        units_s = shard_units[s]
        ent = np.concatenate(
            [rowptr[unit_row[u]] + unit_start[u] +
             np.arange(unit_len[u]) for u in units_s]
        ).astype(np.int64) if units_s.size else np.zeros(0, np.int64)
        lrow = np.repeat(np.arange(units_s.size),
                         unit_len[units_s]) if units_s.size \
            else np.zeros(0, np.int64)
        n_virtual = rps - units_s.size
        needed.append(ent.size + n_virtual)
        per_shard.append((units_s, ent, lrow, n_virtual))
    nnzb_ps = nnzb_per_shard or max(needed)
    if (nnzb_per_shard is None and not split_heavy_rows and n_shards > 1):
        # the derived budget is only honest when the heaviest block-row
        # fits a balanced shard: one dominant row would silently inflate
        # EVERY shard's padded budget to its size (the latent shard_bins
        # edge) — refuse, and point at the split path that handles it
        bal = -(-nnzb_g // n_shards) + rps
        if nnzb_ps > 2 * bal and int(bpr.max(initial=0)) > bal:
            raise ValueError(
                f"heaviest block-row ({int(bpr.max())} blocks) exceeds "
                f"the balanced per-shard budget ({bal}); the derived "
                f"budget {nnzb_ps} would over-allocate every shard — "
                "pass split_heavy_rows=True (entry-granular splits) or "
                "pin nnzb_per_shard explicitly")
    too_big = [s for s in range(n_shards) if needed[s] > nnzb_ps]
    if too_big:
        raise ValueError(
            f"shard(s) {too_big} need {[needed[s] for s in too_big]} entry "
            f"slots but the per-shard budget is {nnzb_ps}; raise "
            f"nnzb_per_shard or lower n_shards")
    nnzb_t_ps = nnzb_ps + nbc
    nnzb_g = a_p.nnzb
    sentinel = nnzb_g            # extra zero row appended to vals at apply

    src = np.full((n_shards, nnzb_ps), sentinel, np.int32)
    rows = np.zeros((n_shards, nnzb_ps), np.int32)
    cols = np.zeros((n_shards, nnzb_ps), np.int32)
    mask = np.zeros((n_shards, nnzb_ps), bool)
    t_perm = np.zeros((n_shards, nnzb_t_ps), np.int32)
    t_rows = np.zeros((n_shards, nnzb_t_ps), np.int32)
    t_cols = np.zeros((n_shards, nnzb_t_ps), np.int32)
    metas = []
    for s, (units_s, ent, lrow, n_virtual) in enumerate(per_shard):
        n_real = ent.size
        # one sentinel per virtual row keeps the nnz-stream kernel's
        # every-block-row-nonempty invariant; leftover budget pads row 0
        vrows = np.arange(units_s.size, rps)
        l_rows = np.concatenate([
            lrow, vrows, np.zeros(nnzb_ps - n_real - n_virtual, np.int64)])
        l_cols = np.concatenate([
            a_p.col_ids[ent].astype(np.int64),
            np.zeros(nnzb_ps - n_real, np.int64)])
        l_src = np.concatenate([
            ent, np.full(nnzb_ps - n_real, sentinel, np.int64)])
        l_mask = np.concatenate([
            real_g[ent], np.zeros(nnzb_ps - n_real, bool)])
        order = np.lexsort((l_cols, l_rows))
        rows[s] = l_rows[order]
        cols[s] = l_cols[order]
        src[s] = l_src[order]
        mask[s] = l_mask[order]
        # transpose structure: every local slot (sentinels hold zero blocks,
        # harmless) + one t-sentinel per t-block-row for full coverage —
        # the count is nnzb_ps + nbc by construction, shape-deterministic
        tt_rows = np.concatenate([cols[s].astype(np.int64),
                                  np.arange(nbc, dtype=np.int64)])
        tt_cols = np.concatenate([rows[s].astype(np.int64),
                                  np.zeros(nbc, np.int64)])
        tt_perm = np.concatenate([np.arange(nnzb_ps, dtype=np.int64),
                                  np.full(nbc, nnzb_ps, np.int64)])
        t_order = np.lexsort((tt_cols, tt_rows))
        t_rows[s] = tt_rows[t_order]
        t_cols[s] = tt_cols[t_order]
        t_perm[s] = tt_perm[t_order]
        max_bpr, pad_pct, cv_pct = _local_stats(
            rows[s], a_p.vals[ent], rps, nnzb_ps, (h, w))
        metas.append(ops.SparseMeta(
            shape=(rps * h, K), block=(h, w), n_block_rows=rps,
            n_block_cols=nbc, nnzb=nnzb_ps, nnzb_t=nnzb_t_ps,
            max_bpr=max_bpr, padding_ratio_pct=pad_pct, bpr_cv_pct=cv_pct,
            reorder="identity", n_shards=n_shards))

    # original row -> stacked output row: pre-reorder, then partition slot.
    # Each planning unit occupies one slot; a split block-row's PRIMARY
    # fragment (entry offset 0) carries the row through the gather, the
    # extras recombine via the split_src/split_dst scatter-add.
    inv_pre = permute_lib.invert_perm(pre_perm)
    slot_of_unit = np.empty(max(unit_row.size, 1), np.int64)
    for s in range(n_shards):
        us = shard_units[s]
        slot_of_unit[us] = s * rps + np.arange(us.size)
    primary = unit_start == 0
    slot_of_br = np.empty(nbr, np.int64)
    slot_of_br[unit_row[primary]] = slot_of_unit[: unit_row.size][primary]
    perm_rows = inv_pre                       # position after pre-reorder
    gather = slot_of_br[perm_rows // h] * h + perm_rows % h

    extra = np.flatnonzero(~primary)
    ar = np.arange(h, dtype=np.int64)
    x_rows = (unit_row[extra][:, None] * h + ar).ravel()    # a_p row space
    s_rows = (slot_of_unit[extra][:, None] * h + ar).ravel()
    valid = x_rows < M          # last block-row's pad rows carry no data
    split_src = s_rows[valid].astype(np.int64)
    split_dst = pre_perm[x_rows[valid]].astype(np.int64)

    host = {
        "vals": a_p.vals,
        "src_index": src,
        "row_ids": rows,
        "col_ids": cols,
        "real_mask": mask,
        "t_perm": t_perm,
        "t_row_ids": t_rows,
        "t_col_ids": t_cols,
        "gather_rows": gather,
        "split_src": split_src,
        "split_dst": split_dst,
    }
    meta = ShardedMeta(shape=(M, K), block=(h, w), n_shards=n_shards,
                       col_shards=col_shards, rows_per_shard=rps,
                       nnzb=nnzb_g, nnzb_per_shard=nnzb_ps,
                       nnzb_t_per_shard=nnzb_t_ps, shard_metas=tuple(metas),
                       reorder=reorder,
                       n_split_fragments=int(extra.size))
    return host, meta


def resolve_n_shards(a: bcsr_lib.BCSR, *, n: int = 512, max_shards: int = 8,
                     n_chunks: int = 2, tuner=None):
    """Resolve ``n_shards="auto"`` for a host BCSR: the autotuner's
    shard-count pick (``Autotuner.pick_shards`` — cache hit, else the
    analytic pipeline model over {1, 2, 4, 8} capped at ``max_shards``)
    evaluated on the operand's unsharded static meta.  Deterministic for
    a fixed (structure, n, max_shards, n_chunks) and cached under the v7
    ``shards|max=<M>|...|nk=<K>`` key.  Returns the ``ShardChoice``."""
    from repro.kernels import autotune
    meta = ops.prepare_sparse_meta(a)
    t = tuner or autotune.get_autotuner()
    return t.pick_shards(meta, n, max_shards=max_shards, n_chunks=n_chunks)


def prepare_sharded(a: bcsr_lib.BCSR, n_shards, *,
                    col_shards: int = 1, dtype=jnp.bfloat16,
                    reorder: str = "identity", tau: float = 0.7,
                    max_candidates: Optional[int] = None,
                    rows_per_shard: Optional[int] = None,
                    nnzb_per_shard: Optional[int] = None,
                    split_heavy_rows: bool = False
                    ) -> Tuple[ShardedArrays, ShardedMeta]:
    """Host BCSR -> row-partitioned device arrays + static sharded meta.

    ``n_shards`` is an int, or ``"auto"`` to resolve the shard count
    through :func:`resolve_n_shards` (analytic pick, cache-backed).
    ``reorder`` optionally applies a block-row permutation scheme FIRST
    (``jaccard`` | ``rcm`` — densify, then balance); the partition itself
    is the ``shard_balance`` assignment, so passing ``"shard_balance"`` or
    ``"identity"`` skips the pre-permutation.  ``rows_per_shard`` /
    ``nnzb_per_shard`` pin the per-shard static shapes (the model-weight
    path derives them from dims so scan-stacked layers agree); omitted,
    they are derived from the structure (tight fit).  Raises when the
    structure cannot fit the pinned budget — static shapes are a contract,
    not a best effort.  ``split_heavy_rows=True`` splits block-rows
    heavier than the balanced budget into entry fragments (extreme
    single-row skew; see module docstring).

    Example (4-way partition of a 320x256 operand, local execution):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.launch import dist_spmm
    >>> a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), nnzb=80)
    >>> sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    >>> (smeta.n_shards, smeta.rows_per_shard, len(smeta.shard_metas))
    (4, 5, 4)
    >>> all(m.max_bpr > 0 for m in smeta.shard_metas)  # real structure stats
    True
    """
    host, meta = _prepare_sharded_host(
        a, n_shards, col_shards=col_shards, reorder=reorder, tau=tau,
        max_candidates=max_candidates, rows_per_shard=rows_per_shard,
        nnzb_per_shard=nnzb_per_shard, split_heavy_rows=split_heavy_rows)
    arrays = ShardedArrays(
        vals=jnp.asarray(host["vals"], dtype=dtype),
        src_index=jnp.asarray(host["src_index"], jnp.int32),
        row_ids=jnp.asarray(host["row_ids"], jnp.int32),
        col_ids=jnp.asarray(host["col_ids"], jnp.int32),
        real_mask=jnp.asarray(host["real_mask"]),
        t_perm=jnp.asarray(host["t_perm"], jnp.int32),
        t_row_ids=jnp.asarray(host["t_row_ids"], jnp.int32),
        t_col_ids=jnp.asarray(host["t_col_ids"], jnp.int32),
        gather_rows=jnp.asarray(host["gather_rows"], jnp.int32),
        split_src=jnp.asarray(host["split_src"], jnp.int32),
        split_dst=jnp.asarray(host["split_dst"], jnp.int32),
    )
    return arrays, meta


def prepare_sharded_meta(a: bcsr_lib.BCSR, n_shards, *,
                         col_shards: int = 1, reorder: str = "identity",
                         tau: float = 0.7,
                         max_candidates: Optional[int] = None,
                         rows_per_shard: Optional[int] = None,
                         nnzb_per_shard: Optional[int] = None,
                         split_heavy_rows: bool = False) -> ShardedMeta:
    """The static ``ShardedMeta`` that ``prepare_sharded`` would return,
    WITHOUT building device arrays — bit-identical by construction (same
    host pipeline, dtype only affects the arrays).

    The model path uses this (memoized, via
    ``core.sparse_linear.sparse_linear_meta``) to re-derive the true
    per-shard structure stats of a deterministic weight pattern at trace
    time, so ``apply_sparse_linear`` dispatches each shard on its real
    fingerprint — heterogeneous per-shard picks, not one collapsed
    streaming choice."""
    return _prepare_sharded_host(
        a, n_shards, col_shards=col_shards, reorder=reorder, tau=tau,
        max_candidates=max_candidates, rows_per_shard=rows_per_shard,
        nnzb_per_shard=nnzb_per_shard, split_heavy_rows=split_heavy_rows)[1]


def prepare(a: bcsr_lib.BCSR, n_shards, *, meta_only: bool = False,
            col_shards: int = 1, dtype=jnp.bfloat16,
            reorder: str = "identity", tau: float = 0.7,
            max_candidates: Optional[int] = None,
            rows_per_shard: Optional[int] = None,
            nnzb_per_shard: Optional[int] = None,
            split_heavy_rows: bool = False):
    """Unified entry point for the sharded prepare twins (PR 8).

    ``meta_only=False`` (default) delegates to :func:`prepare_sharded`
    and returns ``(ShardedArrays, ShardedMeta)``; ``meta_only=True``
    delegates to :func:`prepare_sharded_meta` and returns the
    ``ShardedMeta`` alone (``dtype`` is ignored — meta is dtype-free by
    construction).  The twins stay as documented aliases; this mirrors
    ``kernels.ops.prepare`` for the distributed op family.

    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.launch import dist_spmm
    >>> a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), nnzb=80)
    >>> _, smeta = dist_spmm.prepare(a, 4)
    >>> dist_spmm.prepare(a, 4, meta_only=True) == smeta
    True
    """
    kw = dict(col_shards=col_shards, reorder=reorder, tau=tau,
              max_candidates=max_candidates, rows_per_shard=rows_per_shard,
              nnzb_per_shard=nnzb_per_shard, split_heavy_rows=split_heavy_rows)
    if meta_only:
        return prepare_sharded_meta(a, n_shards, **kw)
    return prepare_sharded(a, n_shards, dtype=dtype, **kw)


# ---------------------------------------------------------------- execution
def _combine_splits(out: jnp.ndarray, out_pad: jnp.ndarray,
                    arrays: ShardedArrays) -> jnp.ndarray:
    """Add non-primary row-fragment partial sums back into their original
    rows (entry-granular splits).  No-op (same array) when the operand
    was prepared without splits — the default path stays byte-identical
    to the pre-split implementation."""
    src = arrays.split_src
    if src is None or int(src.shape[0]) == 0:
        return out
    return out.at[arrays.split_dst].add(jnp.take(out_pad, src, axis=0))


def _resolve_shard_choices(smeta: ShardedMeta, n_local: int, backend: str,
                           bn: int) -> Tuple[Tuple[str, int], ...]:
    """Per-shard (backend, bn): ``auto`` consults the v7 per-shard
    fingerprints, so a skewed shard can run ``row_loop`` while its uniform
    neighbors stream nonzeros — the per-structure choice the global
    dispatch could not make.  ``n_local`` is the panel width each shard
    ACTUALLY multiplies (full N in local mode; N / col_shards under the 2D
    shard_map) so cached picks come from the right N bucket."""
    return tuple(ops.resolve_backend(backend, bn, m, n_local)
                 for m in smeta.shard_metas)


def _branch_meta(smeta: ShardedMeta, members) -> ops.SparseMeta:
    """Representative meta for one switch branch: shapes are shared by
    construction; max_bpr takes the branch max so a row_loop schedule
    covers every member shard."""
    first = smeta.shard_metas[members[0]]
    return dataclasses.replace(
        first, max_bpr=max(smeta.shard_metas[i].max_bpr for i in members))


@jaxmon.monitor(name="launch.spmm_sharded")
def spmm_sharded(arrays: ShardedArrays, smeta: ShardedMeta, b: jnp.ndarray,
                 *, backend: str = "auto", bn: int = 512,
                 interpret: bool = False, mesh=None,
                 out_dtype=None, n_chunks: int = 1) -> jnp.ndarray:
    """C = A @ B over the row-partitioned operand, original row order.

    ``mesh=None`` runs the identical per-shard schedule in-process (the
    single-device fallback); a mesh with an ``AXIS_ROW`` axis of size
    ``n_shards`` (and ``AXIS_COL`` of size ``col_shards`` when 2D) runs it
    as a ``shard_map``.  Differentiable w.r.t. ``arrays.vals`` and ``b``
    through the per-shard custom VJPs; partial dB contributions psum
    across row shards via the shard_map transpose.

    ``backend="auto"`` resolves one (variant, bn) PER SHARD from the v7
    per-shard fingerprints; heterogeneous picks dispatch via ``lax.switch``
    on the mesh axis index.

    ``n_chunks > 1`` pipelines the panel in ascending column chunks —
    chunk k+1's operand staging is issued before chunk k's matmul
    (``_run_chunked``).  Kernel picks are resolved at the FULL panel
    width either way, so the chunked result is bit-identical to
    ``n_chunks=1`` (per-column accumulation trees are unchanged).  The
    backward pass runs the SINGLE-SHOT schedule regardless of
    ``n_chunks`` (a ``custom_vjp`` over the chunked forward): chunking
    the dvals contraction would split its column sum into a different
    accumulation tree, and since the chunked primal is value-identical
    to the unchunked one, the unchunked VJP is exactly its VJP — grads
    stay bitwise-stable across every chunk depth.

    Example (in-process fallback, checked against the unsharded oracle):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import ops
    >>> from repro.launch import dist_spmm
    >>> a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), nnzb=80)
    >>> sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    >>> b = jnp.asarray(np.random.default_rng(0).standard_normal(
    ...     (256, 32)).astype(np.float32))
    >>> c = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla")
    >>> arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    >>> bool(jnp.allclose(c, ops.spmm(arrays, meta, b, backend="xla"),
    ...                   atol=1e-4))
    True
    """
    if obs_trace.enabled():
        n = int(b.shape[-1])
        sched = chunk_schedule(n, n_chunks)
        obs_trace.event("dist.chunk_schedule", n=n, n_chunks=len(sched),
                        n_shards=smeta.n_shards, backend=backend,
                        schedule=sched)
    obs_metrics.gauge("dist.n_chunks").set(n_chunks)
    if n_chunks > 1:
        kw = dict(backend=backend, bn=bn, interpret=interpret, mesh=mesh,
                  out_dtype=out_dtype)

        @jax.custom_vjp
        def call(arrs, bb):
            return _spmm_sharded_exec(arrs, smeta, bb, n_chunks=n_chunks,
                                      **kw)

        def fwd(arrs, bb):
            return (_spmm_sharded_exec(arrs, smeta, bb, n_chunks=n_chunks,
                                       **kw), (arrs, bb))

        def bwd(res, g):
            arrs, bb = res
            _, vjp = jax.vjp(
                lambda a_, b_: _spmm_sharded_exec(a_, smeta, b_, n_chunks=1,
                                                  **kw), arrs, bb)
            return vjp(g)

        call.defvjp(fwd, bwd)
        return call(arrays, b)
    return _spmm_sharded_exec(arrays, smeta, b, backend=backend, bn=bn,
                              interpret=interpret, mesh=mesh,
                              out_dtype=out_dtype, n_chunks=n_chunks)


def _spmm_sharded_exec(arrays: ShardedArrays, smeta: ShardedMeta,
                       b: jnp.ndarray, *, backend: str, bn: int,
                       interpret: bool, mesh, out_dtype,
                       n_chunks: int) -> jnp.ndarray:
    M, K = smeta.shape
    N = int(b.shape[-1])
    S = smeta.n_shards

    zero = jnp.zeros((1,) + tuple(arrays.vals.shape[1:]), arrays.vals.dtype)
    vals_ext = jnp.concatenate([arrays.vals, zero], axis=0)

    if mesh is None:
        # local mode multiplies the FULL panel per shard — resolve picks
        # for N, not N / col_shards (and never for a chunk's width: the
        # pick must not depend on n_chunks or bitwise identity breaks)
        choices = _resolve_shard_choices(smeta, N, backend, bn)
        arrs = [ops.SparseArrays(
            jnp.take(vals_ext, arrays.src_index[s], axis=0),
            arrays.row_ids[s], arrays.col_ids[s],
            arrays.real_mask[s], arrays.t_perm[s], arrays.t_row_ids[s],
            arrays.t_col_ids[s]) for s in range(S)]

        def run_all(bc):
            outs = []
            for s in range(S):
                be, bn_s = choices[s]
                outs.append(ops.spmm(arrs[s], smeta.shard_metas[s], bc,
                                     backend=be, bn=bn_s,
                                     interpret=interpret,
                                     out_dtype=out_dtype))
            return jnp.concatenate(outs, axis=0)

        out_pad = _run_chunked(run_all, b, n_chunks)
        out = jnp.take(out_pad, arrays.gather_rows, axis=0)
        return _combine_splits(out, out_pad, arrays)

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis_sizes.get(AXIS_ROW) != S:
        raise ValueError(
            f"mesh axis {AXIS_ROW!r} must have size {S} "
            f"(got {axis_sizes.get(AXIS_ROW)}); build one with "
            "dist_spmm.make_spmm_mesh")
    C = smeta.col_shards
    if C > 1 and axis_sizes.get(AXIS_COL) != C:
        raise ValueError(
            f"mesh axis {AXIS_COL!r} must have size {C} "
            f"(got {axis_sizes.get(AXIS_COL)})")
    choices = _resolve_shard_choices(smeta, -(-N // C), backend, bn)

    n_pad = (-N) % C
    b_p = jnp.pad(b, ((0, 0), (0, n_pad))) if n_pad else b

    keys = list(dict.fromkeys(choices))
    branch_of = [keys.index(c) for c in choices]
    branch_metas = [
        _branch_meta(smeta, [i for i in range(S) if branch_of[i] == k])
        for k in range(len(keys))]

    def _branch(k):
        be, bn_k = keys[k]
        meta_k = branch_metas[k]

        def run(sv, ri, ci, rm, tp, tr, tc, bloc):
            arr = ops.SparseArrays(sv, ri, ci, rm, tp, tr, tc)

            def one(bc):
                return ops.spmm(arr, meta_k, bc, backend=be, bn=bn_k,
                                interpret=interpret, out_dtype=out_dtype)
            return _run_chunked(one, bloc, n_chunks)
        return run

    def body(ve, si, ri, ci, rm, tp, tr, tc, bloc):
        # the per-shard weight gather happens HERE, on the local slice of
        # src_index against the replicated flat vals — no device ever
        # materializes the full [S, nnzb_ps, h, w] stack
        sv = jnp.take(ve, si[0], axis=0)
        operands = (sv, ri[0], ci[0], rm[0], tp[0], tr[0], tc[0], bloc)
        if len(keys) == 1:
            return _branch(0)(*operands)
        idx = jax.lax.axis_index(AXIS_ROW)
        sel = jnp.asarray(branch_of, jnp.int32)[idx]
        return jax.lax.switch(sel, [_branch(k) for k in range(len(keys))],
                              *operands)

    shard_spec = P(AXIS_ROW)
    b_spec = P(None, AXIS_COL) if C > 1 else P()
    out_spec = P(AXIS_ROW, AXIS_COL) if C > 1 else P(AXIS_ROW)
    f = _shard_map(body, mesh=mesh,
                   in_specs=(P(),) + (shard_spec,) * 7 + (b_spec,),
                   out_specs=out_spec, check_rep=False)
    out_pad = f(vals_ext, arrays.src_index, arrays.row_ids, arrays.col_ids,
                arrays.real_mask, arrays.t_perm, arrays.t_row_ids,
                arrays.t_col_ids, b_p)
    # padding rows are dropped by the gather; its transpose scatters exact
    # zeros back into them, so grads match the unsharded path bit-for-bit
    # on the real support
    out = jnp.take(out_pad, arrays.gather_rows, axis=0)
    out = _combine_splits(out, out_pad, arrays)
    return out[:, :N]


# ------------------------------------------------------------------- tuning
def tune_shards(arrays: ShardedArrays, smeta: ShardedMeta, n: int, *,
                interpret: bool = True, warmup: int = 1, iters: int = 3,
                rng_seed: int = 0, tuner=None) -> dict:
    """Timed per-shard micro-sweep (the sharded analogue of
    ``Autotuner.tune``): times every registered candidate on each shard's
    LOCAL slice and caches the winner under the shard's v7 fingerprint,
    so later ``backend="auto"`` dispatch picks measured winners per shard.
    Shards whose fingerprints coincide (well-balanced partitions — the
    common case) are timed once.  Returns {fingerprint_key: choice}."""
    import time

    from repro.kernels import autotune
    tuner = tuner or autotune.get_autotuner()
    rng = np.random.default_rng(rng_seed)
    b = jnp.asarray(rng.standard_normal((smeta.shape[1], n)),
                    dtype=jnp.float32)
    zero = jnp.zeros((1,) + tuple(arrays.vals.shape[1:]), arrays.vals.dtype)
    vals_ext = jnp.concatenate([arrays.vals, zero], axis=0)

    tuned: dict = {}
    for s, meta_s in enumerate(smeta.shard_metas):
        fp = autotune.fingerprint(meta_s, n)
        if fp.key() in tuned:
            continue
        arr = ops.SparseArrays(
            jnp.take(vals_ext, arrays.src_index[s], axis=0),
            arrays.row_ids[s], arrays.col_ids[s], arrays.real_mask[s],
            arrays.t_perm[s], arrays.t_row_ids[s], arrays.t_col_ids[s])
        cand = {}
        for name in autotune.variant_names():
            v = autotune.get_variant(name)
            if not v.supported(meta_s):
                continue
            bns = {autotune.pick_bn(meta_s, n, v.bn_candidates)}
            bns.update(bn for bn in v.bn_candidates if bn <= max(n, 128))
            for bn in sorted(bns):
                cand[f"{name}/bn{bn}"] = (name, bn)
        cand.setdefault(
            f"{autotune.DEFAULT_VARIANT}/bn{autotune.DEFAULT_BN}",
            (autotune.DEFAULT_VARIANT, autotune.DEFAULT_BN))
        timings = {}
        for label, (name, bn) in cand.items():
            backend = autotune.get_variant(name).backend
            fn = jax.jit(lambda bb, _be=backend, _bn=bn: ops.spmm(
                arr, meta_s, bb, backend=_be, bn=_bn, interpret=interpret))
            try:
                jax.block_until_ready(fn(b))
                for _ in range(max(warmup - 1, 0)):
                    jax.block_until_ready(fn(b))
                ts = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(b))
                    ts.append(time.perf_counter() - t0)
                timings[label] = float(np.median(ts))
            except Exception:   # variant not runnable here — skip, not die
                continue
        default_label = f"{autotune.DEFAULT_VARIANT}/bn{autotune.DEFAULT_BN}"
        if not timings:
            choice = autotune.default_choice()
        else:
            best = min(timings, key=timings.get)
            if (default_label in timings and
                    timings[default_label] <= timings[best] * 1.02):
                best = default_label          # default wins ties (noise)
            name, bn = cand[best]
            choice = autotune.KernelChoice(name, bn, source="measured",
                                           predicted_us=timings[best] * 1e6)
        tuner.put(fp, choice, persist=True)
        tuned[fp.key()] = choice
    return tuned


def tune_shard_count(a: bcsr_lib.BCSR, n: int, *, max_shards: int = 8,
                     n_chunks: int = 1, backend: str = "auto", bn: int = 512,
                     interpret: bool = True, warmup: int = 1, iters: int = 3,
                     rng_seed: int = 0, tuner=None):
    """Timed shard-count micro-sweep: the measured counterpart of
    :func:`resolve_n_shards` (the optional half of the autotune axis —
    the analytic pick never blocks on it).  Prepares the operand at each
    candidate S, times the end-to-end local ``spmm_sharded`` with the
    requested chunk depth, and caches the winner in the autotuner's
    shard-entry section under the operand's v7 ``nk=`` fingerprint so
    later ``resolve_n_shards`` calls return the measured choice.  Smaller
    S wins ties (within 2% — partition overhead noise).  Returns the
    ``ShardChoice``."""
    import time

    from repro.kernels import autotune
    tuner = tuner or autotune.get_autotuner()
    meta = ops.prepare_sparse_meta(a)
    fp = autotune.fingerprint(meta, n, n_chunks=n_chunks)
    rng = np.random.default_rng(rng_seed)
    b = jnp.asarray(rng.standard_normal((a.shape[1], n)), jnp.float32)

    timings = {}
    for s in autotune.shard_candidates(max_shards, meta.n_block_rows):
        try:
            sharr, smeta = prepare_sharded(a, s, dtype=jnp.float32)
        except ValueError:      # unfittable at this S — not a candidate
            continue
        fn = jax.jit(lambda bb, _a=sharr, _m=smeta: spmm_sharded(
            _a, _m, bb, backend=backend, bn=bn, interpret=interpret,
            n_chunks=n_chunks))
        try:
            jax.block_until_ready(fn(b))
            for _ in range(max(warmup - 1, 0)):
                jax.block_until_ready(fn(b))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(b))
                ts.append(time.perf_counter() - t0)
            timings[s] = float(np.median(ts))
        except Exception:       # candidate not runnable here — skip
            continue
    if not timings:
        choice = autotune.analytic_shard_choice(
            meta, n, max_shards=max_shards, n_chunks=n_chunks)
    else:
        t_best = min(timings.values())
        best = next(s for s in sorted(timings)
                    if timings[s] <= t_best * 1.02)
        choice = autotune.ShardChoice(best, source="measured",
                                      predicted_us=timings[best] * 1e6)
    tuner.put_shards(fp, max_shards, choice, persist=True)
    return choice


# ---------------------------------------------------------------- reporting
def shard_balance_stats(a: bcsr_lib.BCSR, n_shards: int, *,
                        rows_per_shard: Optional[int] = None) -> dict:
    """Host-side per-shard nnzb balance report (dry-run / benchmarks).

    ``imbalance`` is max/mean per-shard load (1.0 = perfect);
    ``contig_imbalance`` is the same for a naive contiguous equal-row
    split — the balance the LPT assignment buys vs doing nothing."""
    a_p = a.ensure_nonempty_rows()
    _, _, loads, rps = plan_shards(a_p, n_shards,
                                   rows_per_shard=rows_per_shard)
    bpr = np.diff(a_p.rowptr)
    nbr = bpr.size
    contig = np.asarray(
        [int(bpr[s * rps: (s + 1) * rps].sum()) for s in range(n_shards)],
        np.int64)
    mean = float(loads.mean()) if n_shards else 0.0

    def imb(x):
        m = float(x.mean())
        return round(float(x.max()) / m, 4) if m > 0 else 1.0

    return {
        "n_shards": int(n_shards),
        "n_block_rows": int(nbr),
        "rows_per_shard": int(rps),
        "nnzb": int(a_p.nnzb),
        "loads": [int(x) for x in loads],
        "load_mean": round(mean, 2),
        "load_max": int(loads.max()) if n_shards else 0,
        "imbalance": imb(loads),
        "contig_imbalance": imb(contig),
        "load_cv_pct": int(round(100 * float(loads.std()) / mean))
        if mean > 0 else 0,
    }
