"""Sharded SpMM execution: row-partitioned BCSR over a device mesh.

SMaT's single-device wins only reach the serving north star if the SpMM
scales past one chip.  This module turns the reorder pipeline's dormant
``shard_balance`` scheme into a working scaling axis:

  * ``prepare_sharded`` partitions a host BCSR over block-rows (1D) using
    the capacitated LPT bin assignment from ``core.permute.shard_bins``:
    every shard owns exactly ``rows_per_shard`` block-row slots (trailing
    slots virtual/empty) and a fixed ``nnzb_per_shard`` entry budget, so
    the per-shard schedules are STATIC — scan/jit shapes never depend on
    which shard a block landed in.  Per-shard nonzero-block loads come out
    near-equal (the paper's mip1 observation, lifted from warps to
    devices; Acc-SpMM makes the same point for TC pipelines).
  * ``spmm_sharded`` executes the partition either as a ``shard_map`` over
    a dedicated mesh axis (real multi-device execution; the column split
    over B adds an optional 2D axis) or as an in-process "local" loop with
    identical math (the fallback when no compatible mesh exists — unit
    tests, single-chip serving).  Each shard resolves its OWN kernel
    variant through ``ops.resolve_backend``: per-shard metas carry
    ``n_shards`` into the v4 autotune fingerprint, and shards whose picks
    differ dispatch through a ``lax.switch`` on the mesh axis index.
  * Results gather back to ORIGINAL row order (``gather_rows`` composes
    the optional pre-reorder with the partition permutation), so the
    sharding — like the PR 2 reorder — never leaks to callers; gradients
    flow through the inner per-shard ``ops.spmm`` custom VJP, the
    ``shard_map`` transpose (partial dB psums across shards), and the
    outer gather's transpose (padding rows receive exact zeros).

Wired end-to-end via ``SparsitySpec(shards=...)`` -> ``init_sparse_linear``
-> ``apply_sparse_linear`` (which reads the ambient mesh from
``use_spmm_mesh``) -> the serve engine's decode path; ``launch.dryrun``
reports the per-shard nnzb balance of sparse layers.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

try:  # moved to the public namespace on newer JAX
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover - newer JAX
    _shard_map = jax.shard_map

from repro.core import bcsr as bcsr_lib
from repro.core import permute as permute_lib
from repro.kernels import ops
from repro.launch import mesh as mesh_lib

AXIS_ROW = "spmm"        # mesh axis the block-row partition maps onto
AXIS_COL = "spmm_col"    # optional 2D axis: column split over B


# ---------------------------------------------------------------------- types
class ShardedArrays(NamedTuple):
    """Device arrays of a row-partitioned BCSR operand (pytree leaves).

    ``vals`` stays the FLAT global entry list — the single trainable leaf,
    shaped exactly like the unsharded operand's so parameter trees,
    optimizers, and sharding rules are unchanged.  The per-shard leaves
    are index structure only (leading axis = shard):

      src_index  [S, nnzb_ps]    entry index into vals (nnzb = zero sentinel)
      row_ids    [S, nnzb_ps]    LOCAL block-row ids, sorted row-major
      col_ids    [S, nnzb_ps]    global block-col ids
      real_mask  [S, nnzb_ps]    False for sentinel/padding entries
      t_perm     [S, nnzb_t_ps]  local transpose gather (nnzb_ps = sentinel)
      t_row_ids  [S, nnzb_t_ps]  block-rows of the local A^T (= global bcols)
      t_col_ids  [S, nnzb_t_ps]  LOCAL block-rows of A
      gather_rows [M]            original row -> row of the stacked shard
                                 outputs (composes pre-reorder + partition)
    """
    vals: jnp.ndarray
    src_index: jnp.ndarray
    row_ids: jnp.ndarray
    col_ids: jnp.ndarray
    real_mask: jnp.ndarray
    t_perm: jnp.ndarray
    t_row_ids: jnp.ndarray
    t_col_ids: jnp.ndarray
    gather_rows: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ShardedMeta:
    """Static (hashable) metadata of a sharded operand.

    ``shard_metas[s]`` is a full per-shard ``SparseMeta`` (shape
    ``(rows_per_shard*h, K)``, ``nnzb = nnzb_per_shard``, its own
    max_bpr/padding/skew stats, ``n_shards`` set) — the fingerprint the
    autotuner picks each shard's kernel variant from."""
    shape: Tuple[int, int]              # logical global (M, K)
    block: Tuple[int, int]
    n_shards: int
    col_shards: int
    rows_per_shard: int                 # block-row slots per shard
    nnzb: int                           # global flat entry count (vals leaf)
    nnzb_per_shard: int
    nnzb_t_per_shard: int
    shard_metas: Tuple[ops.SparseMeta, ...]
    reorder: str = "identity"           # pre-partition scheme (reporting)


# ------------------------------------------------------------- ambient mesh
_MESH_STACK: list = [None]


@contextlib.contextmanager
def use_spmm_mesh(mesh):
    """Route ``apply_sparse_linear``'s sharded path through ``mesh`` for the
    duration (trace-time setting: the mesh is baked into the jitted program
    traced inside).  ``mesh=None`` is a no-op passthrough."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_spmm_mesh():
    return _MESH_STACK[-1]


def make_spmm_mesh(n_shards: int, col_shards: int = 1):
    """Dedicated (n_shards,) or (n_shards, col_shards) mesh over the first
    local devices, axes ``(AXIS_ROW[, AXIS_COL])``."""
    need = n_shards * col_shards
    if jax.device_count() < need:
        raise ValueError(
            f"spmm mesh needs {need} devices, have {jax.device_count()} "
            "(CPU testing: XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if col_shards > 1:
        return mesh_lib.make_mesh((n_shards, col_shards), (AXIS_ROW, AXIS_COL))
    return mesh_lib.make_mesh((n_shards,), (AXIS_ROW,))


# ----------------------------------------------------------------- planning
def plan_shards(a_p: bcsr_lib.BCSR, n_shards: int, *,
                rows_per_shard: Optional[int] = None,
                nnzb_per_shard: Optional[int] = None):
    """Balanced block-row partition of a (row-padded) BCSR.

    Returns ``(assign, shard_rows, loads, rps)``: the LPT bin assignment
    (``core.permute.shard_bins``), per-shard sorted block-row lists, the
    per-shard nonzero-block loads, and the (resolved) row-slot count."""
    nbr = a_p.n_block_rows
    rps = rows_per_shard or -(-max(nbr, 1) // n_shards)
    bpr = np.diff(a_p.rowptr)
    max_load = nnzb_per_shard
    if max_load is not None:
        # every virtual (unassigned) row slot costs one sentinel entry on
        # whichever shard it lands; reserve the worst case up front so the
        # LPT never fills headroom the sentinels need — an assignment that
        # passes here is GUARANTEED to fit the real+virtual budget check
        v_max = min(max(n_shards * rps - nbr, 0), rps)
        max_load = max_load - v_max
    assign = permute_lib.shard_bins(
        bpr, n_shards, rows_per_shard=rps, max_load=max_load)
    shard_rows = [np.flatnonzero(assign == s) for s in range(n_shards)]
    loads = np.asarray([int(bpr[r].sum()) for r in shard_rows], np.int64)
    return assign, shard_rows, loads, rps


def _local_stats(rows: np.ndarray, vals_real: np.ndarray, rps: int,
                 nnzb_ps: int, block) -> Tuple[int, int, int]:
    """(max_bpr, pad_pct, cv_pct) of one shard's padded local structure."""
    h, w = block
    bpr = np.bincount(rows, minlength=rps).astype(np.float64)
    mean = float(bpr.mean()) if bpr.size else 0.0
    cv = float(bpr.std() / mean) if mean > 0 else 0.0
    nnz = int(np.count_nonzero(vals_real))
    pad = 1.0 - nnz / max(nnzb_ps * h * w, 1)
    return (int(bpr.max()) if bpr.size else 0, int(round(pad * 100)),
            int(round(cv * 100)))


def _prepare_sharded_host(a: bcsr_lib.BCSR, n_shards: int, *,
                          col_shards: int = 1,
                          reorder: str = "identity", tau: float = 0.7,
                          max_candidates: Optional[int] = None,
                          rows_per_shard: Optional[int] = None,
                          nnzb_per_shard: Optional[int] = None):
    """Host-side (numpy) portion of ``prepare_sharded``: pre-reorder,
    partition, per-shard index structure, and the static ``ShardedMeta``
    with its per-shard structure stats.  Returns ``(host_arrays_dict,
    meta)``; ``prepare_sharded`` converts to device arrays,
    ``prepare_sharded_meta`` keeps only the meta."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    h, w = a.block
    M, K = a.shape
    pre_perm = np.arange(M, dtype=np.int64)
    if reorder not in ("identity", "shard_balance"):
        a, pre_perm = permute_lib.permute_bcsr(
            a, reorder, tau=tau, max_candidates=max_candidates,
            n_shards=n_shards, granularity="block_row")
    a_p, real_g = a.ensure_nonempty_rows(return_mask=True)
    nbr, nbc = a_p.n_block_rows, a_p.n_block_cols

    assign, shard_rows, loads, rps = plan_shards(
        a_p, n_shards, rows_per_shard=rows_per_shard,
        nnzb_per_shard=nnzb_per_shard)
    if rps * n_shards < nbr:
        raise ValueError(f"rows_per_shard={rps} too small for {nbr} "
                         f"block-rows over {n_shards} shards")

    # per-shard entry lists (entries stay in a_p's global order; local ids
    # relabel block-rows to each shard's slot space)
    rowptr = a_p.rowptr
    needed = []
    per_shard = []
    for s in range(n_shards):
        rows_s = shard_rows[s]
        ent = np.concatenate(
            [np.arange(rowptr[r], rowptr[r + 1]) for r in rows_s]
        ).astype(np.int64) if rows_s.size else np.zeros(0, np.int64)
        lrow = np.repeat(np.arange(rows_s.size),
                         np.diff(rowptr)[rows_s]) if rows_s.size \
            else np.zeros(0, np.int64)
        n_virtual = rps - rows_s.size
        needed.append(ent.size + n_virtual)
        per_shard.append((rows_s, ent, lrow, n_virtual))
    nnzb_ps = nnzb_per_shard or max(needed)
    too_big = [s for s in range(n_shards) if needed[s] > nnzb_ps]
    if too_big:
        raise ValueError(
            f"shard(s) {too_big} need {[needed[s] for s in too_big]} entry "
            f"slots but the per-shard budget is {nnzb_ps}; raise "
            f"nnzb_per_shard or lower n_shards")
    nnzb_t_ps = nnzb_ps + nbc
    nnzb_g = a_p.nnzb
    sentinel = nnzb_g            # extra zero row appended to vals at apply

    src = np.full((n_shards, nnzb_ps), sentinel, np.int32)
    rows = np.zeros((n_shards, nnzb_ps), np.int32)
    cols = np.zeros((n_shards, nnzb_ps), np.int32)
    mask = np.zeros((n_shards, nnzb_ps), bool)
    t_perm = np.zeros((n_shards, nnzb_t_ps), np.int32)
    t_rows = np.zeros((n_shards, nnzb_t_ps), np.int32)
    t_cols = np.zeros((n_shards, nnzb_t_ps), np.int32)
    metas = []
    for s, (rows_s, ent, lrow, n_virtual) in enumerate(per_shard):
        n_real = ent.size
        # one sentinel per virtual row keeps the nnz-stream kernel's
        # every-block-row-nonempty invariant; leftover budget pads row 0
        vrows = np.arange(rows_s.size, rps)
        l_rows = np.concatenate([
            lrow, vrows, np.zeros(nnzb_ps - n_real - n_virtual, np.int64)])
        l_cols = np.concatenate([
            a_p.col_ids[ent].astype(np.int64),
            np.zeros(nnzb_ps - n_real, np.int64)])
        l_src = np.concatenate([
            ent, np.full(nnzb_ps - n_real, sentinel, np.int64)])
        l_mask = np.concatenate([
            real_g[ent], np.zeros(nnzb_ps - n_real, bool)])
        order = np.lexsort((l_cols, l_rows))
        rows[s] = l_rows[order]
        cols[s] = l_cols[order]
        src[s] = l_src[order]
        mask[s] = l_mask[order]
        # transpose structure: every local slot (sentinels hold zero blocks,
        # harmless) + one t-sentinel per t-block-row for full coverage —
        # the count is nnzb_ps + nbc by construction, shape-deterministic
        tt_rows = np.concatenate([cols[s].astype(np.int64),
                                  np.arange(nbc, dtype=np.int64)])
        tt_cols = np.concatenate([rows[s].astype(np.int64),
                                  np.zeros(nbc, np.int64)])
        tt_perm = np.concatenate([np.arange(nnzb_ps, dtype=np.int64),
                                  np.full(nbc, nnzb_ps, np.int64)])
        t_order = np.lexsort((tt_cols, tt_rows))
        t_rows[s] = tt_rows[t_order]
        t_cols[s] = tt_cols[t_order]
        t_perm[s] = tt_perm[t_order]
        max_bpr, pad_pct, cv_pct = _local_stats(
            rows[s], a_p.vals[ent], rps, nnzb_ps, (h, w))
        metas.append(ops.SparseMeta(
            shape=(rps * h, K), block=(h, w), n_block_rows=rps,
            n_block_cols=nbc, nnzb=nnzb_ps, nnzb_t=nnzb_t_ps,
            max_bpr=max_bpr, padding_ratio_pct=pad_pct, bpr_cv_pct=cv_pct,
            reorder="identity", n_shards=n_shards))

    # original row -> stacked output row: pre-reorder, then partition slot
    inv_pre = permute_lib.invert_perm(pre_perm)
    slot_of_br = np.empty(nbr, np.int64)
    for s in range(n_shards):
        slot_of_br[shard_rows[s]] = s * rps + np.arange(shard_rows[s].size)
    perm_rows = inv_pre                       # position after pre-reorder
    gather = slot_of_br[perm_rows // h] * h + perm_rows % h

    host = {
        "vals": a_p.vals,
        "src_index": src,
        "row_ids": rows,
        "col_ids": cols,
        "real_mask": mask,
        "t_perm": t_perm,
        "t_row_ids": t_rows,
        "t_col_ids": t_cols,
        "gather_rows": gather,
    }
    meta = ShardedMeta(shape=(M, K), block=(h, w), n_shards=n_shards,
                       col_shards=col_shards, rows_per_shard=rps,
                       nnzb=nnzb_g, nnzb_per_shard=nnzb_ps,
                       nnzb_t_per_shard=nnzb_t_ps, shard_metas=tuple(metas),
                       reorder=reorder)
    return host, meta


def prepare_sharded(a: bcsr_lib.BCSR, n_shards: int, *,
                    col_shards: int = 1, dtype=jnp.bfloat16,
                    reorder: str = "identity", tau: float = 0.7,
                    max_candidates: Optional[int] = None,
                    rows_per_shard: Optional[int] = None,
                    nnzb_per_shard: Optional[int] = None
                    ) -> Tuple[ShardedArrays, ShardedMeta]:
    """Host BCSR -> row-partitioned device arrays + static sharded meta.

    ``reorder`` optionally applies a block-row permutation scheme FIRST
    (``jaccard`` | ``rcm`` — densify, then balance); the partition itself
    is the ``shard_balance`` assignment, so passing ``"shard_balance"`` or
    ``"identity"`` skips the pre-permutation.  ``rows_per_shard`` /
    ``nnzb_per_shard`` pin the per-shard static shapes (the model-weight
    path derives them from dims so scan-stacked layers agree); omitted,
    they are derived from the structure (tight fit).  Raises when the
    structure cannot fit the pinned budget — static shapes are a contract,
    not a best effort.

    Example (4-way partition of a 320x256 operand, local execution):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.launch import dist_spmm
    >>> a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), nnzb=80)
    >>> sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    >>> (smeta.n_shards, smeta.rows_per_shard, len(smeta.shard_metas))
    (4, 5, 4)
    >>> all(m.max_bpr > 0 for m in smeta.shard_metas)  # real structure stats
    True
    """
    host, meta = _prepare_sharded_host(
        a, n_shards, col_shards=col_shards, reorder=reorder, tau=tau,
        max_candidates=max_candidates, rows_per_shard=rows_per_shard,
        nnzb_per_shard=nnzb_per_shard)
    arrays = ShardedArrays(
        vals=jnp.asarray(host["vals"], dtype=dtype),
        src_index=jnp.asarray(host["src_index"], jnp.int32),
        row_ids=jnp.asarray(host["row_ids"], jnp.int32),
        col_ids=jnp.asarray(host["col_ids"], jnp.int32),
        real_mask=jnp.asarray(host["real_mask"]),
        t_perm=jnp.asarray(host["t_perm"], jnp.int32),
        t_row_ids=jnp.asarray(host["t_row_ids"], jnp.int32),
        t_col_ids=jnp.asarray(host["t_col_ids"], jnp.int32),
        gather_rows=jnp.asarray(host["gather_rows"], jnp.int32),
    )
    return arrays, meta


def prepare_sharded_meta(a: bcsr_lib.BCSR, n_shards: int, *,
                         col_shards: int = 1, reorder: str = "identity",
                         tau: float = 0.7,
                         max_candidates: Optional[int] = None,
                         rows_per_shard: Optional[int] = None,
                         nnzb_per_shard: Optional[int] = None) -> ShardedMeta:
    """The static ``ShardedMeta`` that ``prepare_sharded`` would return,
    WITHOUT building device arrays — bit-identical by construction (same
    host pipeline, dtype only affects the arrays).

    The model path uses this (memoized, via
    ``core.sparse_linear.sparse_linear_meta``) to re-derive the true
    per-shard structure stats of a deterministic weight pattern at trace
    time, so ``apply_sparse_linear`` dispatches each shard on its real
    fingerprint — heterogeneous per-shard picks, not one collapsed
    streaming choice."""
    return _prepare_sharded_host(
        a, n_shards, col_shards=col_shards, reorder=reorder, tau=tau,
        max_candidates=max_candidates, rows_per_shard=rows_per_shard,
        nnzb_per_shard=nnzb_per_shard)[1]


def prepare(a: bcsr_lib.BCSR, n_shards: int, *, meta_only: bool = False,
            col_shards: int = 1, dtype=jnp.bfloat16,
            reorder: str = "identity", tau: float = 0.7,
            max_candidates: Optional[int] = None,
            rows_per_shard: Optional[int] = None,
            nnzb_per_shard: Optional[int] = None):
    """Unified entry point for the sharded prepare twins (PR 8).

    ``meta_only=False`` (default) delegates to :func:`prepare_sharded`
    and returns ``(ShardedArrays, ShardedMeta)``; ``meta_only=True``
    delegates to :func:`prepare_sharded_meta` and returns the
    ``ShardedMeta`` alone (``dtype`` is ignored — meta is dtype-free by
    construction).  The twins stay as documented aliases; this mirrors
    ``kernels.ops.prepare`` for the distributed op family.

    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.launch import dist_spmm
    >>> a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), nnzb=80)
    >>> _, smeta = dist_spmm.prepare(a, 4)
    >>> dist_spmm.prepare(a, 4, meta_only=True) == smeta
    True
    """
    kw = dict(col_shards=col_shards, reorder=reorder, tau=tau,
              max_candidates=max_candidates, rows_per_shard=rows_per_shard,
              nnzb_per_shard=nnzb_per_shard)
    if meta_only:
        return prepare_sharded_meta(a, n_shards, **kw)
    return prepare_sharded(a, n_shards, dtype=dtype, **kw)


# ---------------------------------------------------------------- execution
def _resolve_shard_choices(smeta: ShardedMeta, n_local: int, backend: str,
                           bn: int) -> Tuple[Tuple[str, int], ...]:
    """Per-shard (backend, bn): ``auto`` consults the v4 per-shard
    fingerprints, so a skewed shard can run ``row_loop`` while its uniform
    neighbors stream nonzeros — the per-structure choice the global
    dispatch could not make.  ``n_local`` is the panel width each shard
    ACTUALLY multiplies (full N in local mode; N / col_shards under the 2D
    shard_map) so cached picks come from the right N bucket."""
    return tuple(ops.resolve_backend(backend, bn, m, n_local)
                 for m in smeta.shard_metas)


def _branch_meta(smeta: ShardedMeta, members) -> ops.SparseMeta:
    """Representative meta for one switch branch: shapes are shared by
    construction; max_bpr takes the branch max so a row_loop schedule
    covers every member shard."""
    first = smeta.shard_metas[members[0]]
    return dataclasses.replace(
        first, max_bpr=max(smeta.shard_metas[i].max_bpr for i in members))


def spmm_sharded(arrays: ShardedArrays, smeta: ShardedMeta, b: jnp.ndarray,
                 *, backend: str = "auto", bn: int = 512,
                 interpret: bool = False, mesh=None,
                 out_dtype=None) -> jnp.ndarray:
    """C = A @ B over the row-partitioned operand, original row order.

    ``mesh=None`` runs the identical per-shard schedule in-process (the
    single-device fallback); a mesh with an ``AXIS_ROW`` axis of size
    ``n_shards`` (and ``AXIS_COL`` of size ``col_shards`` when 2D) runs it
    as a ``shard_map``.  Differentiable w.r.t. ``arrays.vals`` and ``b``
    through the per-shard custom VJPs; partial dB contributions psum
    across row shards via the shard_map transpose.

    ``backend="auto"`` resolves one (variant, bn) PER SHARD from the v4
    per-shard fingerprints; heterogeneous picks dispatch via ``lax.switch``
    on the mesh axis index.

    Example (in-process fallback, checked against the unsharded oracle):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import bcsr as bcsr_lib
    >>> from repro.kernels import ops
    >>> from repro.launch import dist_spmm
    >>> a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), nnzb=80)
    >>> sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    >>> b = jnp.asarray(np.random.default_rng(0).standard_normal(
    ...     (256, 32)).astype(np.float32))
    >>> c = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla")
    >>> arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    >>> bool(jnp.allclose(c, ops.spmm(arrays, meta, b, backend="xla"),
    ...                   atol=1e-4))
    True
    """
    M, K = smeta.shape
    N = int(b.shape[-1])
    S = smeta.n_shards

    zero = jnp.zeros((1,) + tuple(arrays.vals.shape[1:]), arrays.vals.dtype)
    vals_ext = jnp.concatenate([arrays.vals, zero], axis=0)

    if mesh is None:
        # local mode multiplies the FULL panel per shard — resolve picks
        # for N, not N / col_shards
        choices = _resolve_shard_choices(smeta, N, backend, bn)
        outs = []
        for s in range(S):
            arr = ops.SparseArrays(
                jnp.take(vals_ext, arrays.src_index[s], axis=0),
                arrays.row_ids[s], arrays.col_ids[s],
                arrays.real_mask[s], arrays.t_perm[s], arrays.t_row_ids[s],
                arrays.t_col_ids[s])
            be, bn_s = choices[s]
            outs.append(ops.spmm(arr, smeta.shard_metas[s], b, backend=be,
                                 bn=bn_s, interpret=interpret,
                                 out_dtype=out_dtype))
        out_pad = jnp.concatenate(outs, axis=0)
        return jnp.take(out_pad, arrays.gather_rows, axis=0)

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis_sizes.get(AXIS_ROW) != S:
        raise ValueError(
            f"mesh axis {AXIS_ROW!r} must have size {S} "
            f"(got {axis_sizes.get(AXIS_ROW)}); build one with "
            "dist_spmm.make_spmm_mesh")
    C = smeta.col_shards
    if C > 1 and axis_sizes.get(AXIS_COL) != C:
        raise ValueError(
            f"mesh axis {AXIS_COL!r} must have size {C} "
            f"(got {axis_sizes.get(AXIS_COL)})")
    choices = _resolve_shard_choices(smeta, -(-N // C), backend, bn)

    n_pad = (-N) % C
    b_p = jnp.pad(b, ((0, 0), (0, n_pad))) if n_pad else b

    keys = list(dict.fromkeys(choices))
    branch_of = [keys.index(c) for c in choices]
    branch_metas = [
        _branch_meta(smeta, [i for i in range(S) if branch_of[i] == k])
        for k in range(len(keys))]

    def _branch(k):
        be, bn_k = keys[k]
        meta_k = branch_metas[k]

        def run(sv, ri, ci, rm, tp, tr, tc, bloc):
            arr = ops.SparseArrays(sv, ri, ci, rm, tp, tr, tc)
            return ops.spmm(arr, meta_k, bloc, backend=be, bn=bn_k,
                            interpret=interpret, out_dtype=out_dtype)
        return run

    def body(ve, si, ri, ci, rm, tp, tr, tc, bloc):
        # the per-shard weight gather happens HERE, on the local slice of
        # src_index against the replicated flat vals — no device ever
        # materializes the full [S, nnzb_ps, h, w] stack
        sv = jnp.take(ve, si[0], axis=0)
        operands = (sv, ri[0], ci[0], rm[0], tp[0], tr[0], tc[0], bloc)
        if len(keys) == 1:
            return _branch(0)(*operands)
        idx = jax.lax.axis_index(AXIS_ROW)
        sel = jnp.asarray(branch_of, jnp.int32)[idx]
        return jax.lax.switch(sel, [_branch(k) for k in range(len(keys))],
                              *operands)

    shard_spec = P(AXIS_ROW)
    b_spec = P(None, AXIS_COL) if C > 1 else P()
    out_spec = P(AXIS_ROW, AXIS_COL) if C > 1 else P(AXIS_ROW)
    f = _shard_map(body, mesh=mesh,
                   in_specs=(P(),) + (shard_spec,) * 7 + (b_spec,),
                   out_specs=out_spec, check_rep=False)
    out_pad = f(vals_ext, arrays.src_index, arrays.row_ids, arrays.col_ids,
                arrays.real_mask, arrays.t_perm, arrays.t_row_ids,
                arrays.t_col_ids, b_p)
    # padding rows are dropped by the gather; its transpose scatters exact
    # zeros back into them, so grads match the unsharded path bit-for-bit
    # on the real support
    return jnp.take(out_pad, arrays.gather_rows, axis=0)[:, :N]


# ------------------------------------------------------------------- tuning
def tune_shards(arrays: ShardedArrays, smeta: ShardedMeta, n: int, *,
                interpret: bool = True, warmup: int = 1, iters: int = 3,
                rng_seed: int = 0, tuner=None) -> dict:
    """Timed per-shard micro-sweep (the sharded analogue of
    ``Autotuner.tune``): times every registered candidate on each shard's
    LOCAL slice and caches the winner under the shard's v4 fingerprint,
    so later ``backend="auto"`` dispatch picks measured winners per shard.
    Shards whose fingerprints coincide (well-balanced partitions — the
    common case) are timed once.  Returns {fingerprint_key: choice}."""
    import time

    from repro.kernels import autotune
    tuner = tuner or autotune.get_autotuner()
    rng = np.random.default_rng(rng_seed)
    b = jnp.asarray(rng.standard_normal((smeta.shape[1], n)),
                    dtype=jnp.float32)
    zero = jnp.zeros((1,) + tuple(arrays.vals.shape[1:]), arrays.vals.dtype)
    vals_ext = jnp.concatenate([arrays.vals, zero], axis=0)

    tuned: dict = {}
    for s, meta_s in enumerate(smeta.shard_metas):
        fp = autotune.fingerprint(meta_s, n)
        if fp.key() in tuned:
            continue
        arr = ops.SparseArrays(
            jnp.take(vals_ext, arrays.src_index[s], axis=0),
            arrays.row_ids[s], arrays.col_ids[s], arrays.real_mask[s],
            arrays.t_perm[s], arrays.t_row_ids[s], arrays.t_col_ids[s])
        cand = {}
        for name in autotune.variant_names():
            v = autotune.get_variant(name)
            if not v.supported(meta_s):
                continue
            bns = {autotune.pick_bn(meta_s, n, v.bn_candidates)}
            bns.update(bn for bn in v.bn_candidates if bn <= max(n, 128))
            for bn in sorted(bns):
                cand[f"{name}/bn{bn}"] = (name, bn)
        cand.setdefault(
            f"{autotune.DEFAULT_VARIANT}/bn{autotune.DEFAULT_BN}",
            (autotune.DEFAULT_VARIANT, autotune.DEFAULT_BN))
        timings = {}
        for label, (name, bn) in cand.items():
            backend = autotune.get_variant(name).backend
            fn = jax.jit(lambda bb, _be=backend, _bn=bn: ops.spmm(
                arr, meta_s, bb, backend=_be, bn=_bn, interpret=interpret))
            try:
                jax.block_until_ready(fn(b))
                for _ in range(max(warmup - 1, 0)):
                    jax.block_until_ready(fn(b))
                ts = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(b))
                    ts.append(time.perf_counter() - t0)
                timings[label] = float(np.median(ts))
            except Exception:   # variant not runnable here — skip, not die
                continue
        default_label = f"{autotune.DEFAULT_VARIANT}/bn{autotune.DEFAULT_BN}"
        if not timings:
            choice = autotune.default_choice()
        else:
            best = min(timings, key=timings.get)
            if (default_label in timings and
                    timings[default_label] <= timings[best] * 1.02):
                best = default_label          # default wins ties (noise)
            name, bn = cand[best]
            choice = autotune.KernelChoice(name, bn, source="measured",
                                           predicted_us=timings[best] * 1e6)
        tuner.put(fp, choice, persist=True)
        tuned[fp.key()] = choice
    return tuned


# ---------------------------------------------------------------- reporting
def shard_balance_stats(a: bcsr_lib.BCSR, n_shards: int, *,
                        rows_per_shard: Optional[int] = None) -> dict:
    """Host-side per-shard nnzb balance report (dry-run / benchmarks).

    ``imbalance`` is max/mean per-shard load (1.0 = perfect);
    ``contig_imbalance`` is the same for a naive contiguous equal-row
    split — the balance the LPT assignment buys vs doing nothing."""
    a_p = a.ensure_nonempty_rows()
    _, _, loads, rps = plan_shards(a_p, n_shards,
                                   rows_per_shard=rows_per_shard)
    bpr = np.diff(a_p.rowptr)
    nbr = bpr.size
    contig = np.asarray(
        [int(bpr[s * rps: (s + 1) * rps].sum()) for s in range(n_shards)],
        np.int64)
    mean = float(loads.mean()) if n_shards else 0.0

    def imb(x):
        m = float(x.mean())
        return round(float(x.max()) / m, 4) if m > 0 else 1.0

    return {
        "n_shards": int(n_shards),
        "n_block_rows": int(nbr),
        "rows_per_shard": int(rps),
        "nnzb": int(a_p.nnzb),
        "loads": [int(x) for x in loads],
        "load_mean": round(mean, 2),
        "load_max": int(loads.max()) if n_shards else 0,
        "imbalance": imb(loads),
        "contig_imbalance": imb(contig),
        "load_cv_pct": int(round(100 * float(loads.std()) / mean))
        if mean > 0 else 0,
    }
