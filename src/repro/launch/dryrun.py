import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation) and extract memory / cost / roofline.

The two lines above MUST stay first — jax locks the device count on first
init.  Everything below imports jax.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Small-mesh testing (CI):
  DRYRUN_XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.dryrun --arch h2o-danube-1.8b:smoke \
      --shape train_4k --mesh-shape 2,4 --batch 8 --seq 128
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_applicable, get_config, list_archs
from repro.configs.base import ShapeCell
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rl
from repro.launch import sharding as sh
from repro.launch import steps as st
from repro.models import transformer as T
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.optim import adamw


def _lower_and_compile(cfg, shape: ShapeCell, mesh, remat: str,
                       seq_shard_long: bool, donate: bool):
    t0 = time.time()
    params_specs = T.param_specs(cfg)
    # inference cells use serve-mode weight shardings (TP only, no FSDP —
    # §Perf cell A).  Replication only amortizes over batch: single-request
    # long-context keeps the sharded (train) weight layout.
    p_mode = "serve" if (shape.kind != "train" and
                         shape.global_batch >= 8) else "train"
    p_shard = sh.param_shardings(mesh, params_specs, mode=p_mode)

    with mesh:
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            o_specs = jax.eval_shape(adamw.init, params_specs)
            o_shard = sh.opt_state_shardings(mesh, o_specs, p_shard)
            b_specs = st.input_specs(cfg, shape)
            b_shard = sh.batch_shardings(mesh, b_specs)
            fn = st.make_train_step(cfg, opt_cfg, remat=remat)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_specs, o_specs, b_specs)
        elif shape.kind == "prefill":
            b_specs = st.input_specs(cfg, shape)
            b_shard = sh.batch_shardings(mesh, b_specs)
            c_specs = T.cache_specs(cfg, shape.global_batch, shape.seq_len)
            c_shard = sh.cache_shardings(mesh, c_specs, cfg)
            fn = st.make_prefill_step(cfg, shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(params_specs, b_specs)
        else:  # decode
            seq_shard = seq_shard_long and shape.global_batch < 8
            b_specs = st.input_specs(cfg, shape)
            tok_shard = sh.batch_shardings(mesh, b_specs)["tokens"] \
                if shape.global_batch >= 8 else None
            c_specs = T.cache_specs(cfg, shape.global_batch, shape.seq_len)
            c_shard = sh.cache_shardings(mesh, c_specs, cfg,
                                         seq_shard=seq_shard)
            fn = st.make_decode_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, c_shard, tok_shard, None),
                out_shardings=(None, c_shard),
                donate_argnums=(1,) if donate else ())
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(params_specs, c_specs,
                                   b_specs["tokens"], pos_spec)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _cell_costs(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = rl.parse_collectives(compiled.as_text())
    return flops, bytes_acc, coll


def _repeat_knobs(cfg) -> dict:
    """Layer-stack repeat counts (the affine variables of the cost model)."""
    if cfg.layout == "zamba":
        return {"hybrid_n_units": cfg.hybrid_n_units,
                "hybrid_tail": cfg.hybrid_tail}
    if cfg.layout == "gemma_pair":
        return {"n_layers": cfg.n_layers // 2}   # repeats = pairs
    return {"n_layers": cfg.n_layers}


def _with_repeats(cfg, reps: dict):
    import dataclasses as dc
    kw = dict(reps)
    if cfg.layout == "gemma_pair" and "n_layers" in kw:
        kw["n_layers"] = kw["n_layers"] * 2
    return dc.replace(cfg, **kw)


def extrapolated_costs(cfg, shape: ShapeCell, mesh, remat: str,
                       seq_shard_long: bool, verbose: bool = True):
    """XLA counts while-loop bodies once, so scanned stacks undercount
    FLOPs/bytes/collectives.  Compile small UNROLLED variants (1 and 2
    repeats per scan knob) and extrapolate affinely to the real depth.
    Returns (flops, bytes, wire_bytes, collective_dict) per device."""
    from repro.models import unroll as U
    knobs = _repeat_knobs(cfg)
    names = list(knobs)

    def measure(reps):
        small = _with_repeats(cfg, reps)
        with U.unroll_scans():
            compiled, _, _ = _lower_and_compile(
                small, shape, mesh, remat, seq_shard_long, donate=False)
        return _cell_costs(compiled)

    base_reps = {k: 1 for k in names}
    f0, b0, c0 = measure(base_reps)
    flops, bytes_acc, wire = f0, b0, c0.wire_bytes
    coll_counts = dict(c0.counts)
    for k in names:
        reps2 = dict(base_reps)
        reps2[k] = 2
        f1, b1, c1 = measure(reps2)
        extra = knobs[k] - 1
        flops += (f1 - f0) * extra
        bytes_acc += (b1 - b0) * extra
        wire += (c1.wire_bytes - c0.wire_bytes) * extra
        for kind, n in c1.counts.items():
            coll_counts[kind] = coll_counts.get(kind, 0) + \
                (n - c0.counts.get(kind, 0)) * extra
    coll = {"counts": coll_counts, "wire_bytes": wire,
            "mode": "extrapolated-unroll"}
    if verbose:
        print(f"[dryrun]   cost-extrapolation {cfg.name} x {shape.name}: "
              f"flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
              f"wire/dev={wire:.3e}")
        sys.stdout.flush()
    return flops, bytes_acc, wire, coll


def run_cell(cfg, shape: ShapeCell, mesh, *, remat: str = "full",
             seq_shard_long: bool = True, donate: bool = True,
             extrapolate: bool = True, verbose: bool = True) -> dict:
    """Lower + compile one cell; returns the record for EXPERIMENTS.md."""
    n_dev = mesh.devices.size
    compiled, t_lower, t_compile = _lower_and_compile(
        cfg, shape, mesh, remat, seq_shard_long, donate)

    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll_raw = _cell_costs(compiled)
    if extrapolate:
        flops, bytes_acc, wire, coll_d = extrapolated_costs(
            cfg, shape, mesh, remat, seq_shard_long, verbose=verbose)
    else:
        flops, bytes_acc, wire = flops_raw, bytes_raw, coll_raw.wire_bytes
        coll_d = coll_raw.to_dict()
    model_flops = rl.model_flops_for(cfg, shape)
    roof = rl.compute_roofline(flops, bytes_acc, wire, n_dev,
                               model_flops, collectives=coll_d)

    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "status": "ok",
        "remat": remat,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes +
                                         mem.temp_size_in_bytes),
        },
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(f"[dryrun] {cfg.name} x {shape.name} @ {rec['mesh']}: "
              f"compile={t_compile:.0f}s "
              f"mem/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
              f"Tc={roof.t_compute*1e3:.2f}ms Tm={roof.t_memory*1e3:.2f}ms "
              f"Tx={roof.t_collective*1e3:.2f}ms -> {roof.bottleneck}")
        sys.stdout.flush()
    return rec


def sparse_shard_report(cfg, n_tokens: int = 512) -> dict:
    """Per-shard nnzb balance AND autotune kernel picks of the arch's
    partitioned sparse FFN (``SparsitySpec(shards=...)``) — empty when the
    arch has none.  Printed per arch so the LPT partition quality and the
    per-shard variant choices are visible before any launch.

    The picks come from the SAME static metas the model path dispatches
    on (``models.layers.mlp_sparse_metas`` — true per-shard structure
    stats merged over the layer stack), resolved as ``backend="auto"``
    for an ``n_tokens``-wide activation panel.  ``shards="auto"`` specs
    additionally report the RESOLVED shard count per weight (the
    autotuner's shard-count pick) and every report carries the overlap
    chunk schedule the apply will pipeline the token panel with."""
    spec = cfg.ffn_sparsity
    from repro.core import sparse_linear as sl
    if spec is None or not sl.is_sharded(spec):
        return {}
    from repro.kernels import ops as kops
    from repro.launch import dist_spmm
    from repro.models import layers as L
    from repro.models.transformer import _mlp_seed_hints
    # balance and picks must describe the SAME structures: use the real
    # pattern seeds of the first layer's gate / down weights (mlp_seed),
    # not shard_balance_report's default probe seed
    seed0 = L.mlp_seed(_mlp_seed_hints(cfg)[0])
    rep = {
        "gate_up": sl.shard_balance_report(cfg.d_model, cfg.d_ff, spec,
                                           seed=seed0),
        "down": sl.shard_balance_report(cfg.d_ff, cfg.d_model, spec,
                                        seed=seed0 + 2),
    }
    n_chunks = max(spec.shard_chunks, 1)
    for lname, (od, idim) in (("gate_up", (cfg.d_ff, cfg.d_model)),
                              ("down", (cfg.d_model, cfg.d_ff))):
        rep[lname]["resolved_shards"] = sl.resolved_shards(spec, od, idim)
        rep[lname]["shards_auto"] = spec.shards == "auto"
        rep[lname]["n_chunks"] = n_chunks
        rep[lname]["chunk_schedule"] = [
            list(c) for c in dist_spmm.chunk_schedule(n_tokens, n_chunks)]
    meta_in, meta_out = L.mlp_sparse_metas(
        spec, cfg.d_model, cfg.d_ff, _mlp_seed_hints(cfg))
    from repro.analysis import verify_launch as vl
    for lname, m in (("gate_up", meta_in), ("down", meta_out)):
        rep[lname]["auto_picks"] = [
            "{}/bn{}".format(*kops.resolve_backend("auto", spec.bn, sm,
                                                   n_tokens))
            for sm in m.shard_metas]
        # static contract re-proof: the same checks REPRO_VERIFY_LAUNCH=1
        # would run at dispatch, surfaced in the pre-launch report
        rep[lname]["verify"] = vl.verify_summary(m, n_tokens)
    return rep


def sparse_attention_report(cfg, seq_len: int = 512) -> dict:
    """Mask structure + autotune picks of the arch's block-sparse attention
    (``ModelConfig.attn_sparsity``) — empty when the arch has none.

    Reports the mask nnzb / block density vs dense-causal, the
    attention-level fused-vs-composed resolution (v6 ``op=attn`` family —
    the PR-6 one-kernel path), and the composed ``op=sddmm`` (score) +
    ``op=spmm`` (context) picks the spec's backend resolves for a
    ``seq_len`` sequence at the arch's REAL head dim (the contraction
    width the runtime ops fingerprint with) — the attention twin of
    ``sparse_shard_report``, derived entirely from static metas (the
    PR-4/PR-5 pipeline: no params, no arrays)."""
    spec = getattr(cfg, "attn_sparsity", None)
    if spec is None:
        return {}
    from repro.analysis import verify_launch as vl
    from repro.analysis import workspace
    from repro.models import attention as A
    seq = max(seq_len, spec.block[0] * 2)   # at least two block-rows
    rep = A.attention_mask_report(spec, seq, head_dim=cfg.head_dim)
    meta = A.attention_mask_meta(spec.mask, seq, spec.block)
    # shared estimator (repro.analysis.workspace — same numbers the
    # attention benchmark gates on) + the static contract re-proof
    rep["composed_workspace_bytes"] = \
        workspace.attn_composed_workspace_bytes(meta)
    rep["fused_state_bytes"] = \
        workspace.attn_fused_state_bytes(spec.block, cfg.head_dim)
    rep["verify"] = vl.verify_summary(meta, cfg.head_dim, op="attn")
    return rep


def paged_kv_report(cfg, cache_len: int = 512, n_slots: int = 4) -> dict:
    """Paged block-sparse KV accounting for serving (PR 8) — empty when
    the arch has no ``attn_sparsity`` or no k/v attention rings.

    Per layer group: page count and bytes, pages touched per decode step
    (the mask meta's ``max_bpr`` — the page table IS the mask BCSR),
    device-resident vs host-offloaded bytes under the analytic placement
    policy, and the cost-model step-read estimates.  Derived entirely
    from static metas, like the other sparse reports; also round-trips
    the materialized page tables through ``sharding.cache_shardings`` so
    the page-table leaf rules stay exercised."""
    spec = getattr(cfg, "attn_sparsity", None)
    if spec is None or cfg.layout not in ("attn_mlp", "gemma_pair"):
        return {}
    from repro.serve.paged_kv import PagedKVCache  # local: layering
    paged = PagedKVCache(cfg, cache_len, n_slots)
    rep = paged.report()
    leaves = paged.table_leaves()
    if leaves:
        mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
        shardings = sh.cache_shardings(mesh, leaves, cfg)
        rep["table_leaf_specs"] = {
            g: {k: str(s.spec) for k, s in d.items()}
            for g, d in shardings.items()}
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override, e.g. 2,4 (axes data,model) or 2,2,2")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots", "names"])
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-seq-shard-long", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the unrolled cost-extrapolation compiles "
                         "(multi-pod pass = sharding/memory proof only)")
    args = ap.parse_args(argv)

    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("pod", "data", "model")[-len(shape):] if len(shape) == 3 \
            else ("data", "model")
        mesh = mesh_lib.make_mesh(shape, axes)
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    records = []
    for a in archs:
        cfg = get_config(a)
        with obs_trace.span("dryrun.shard_report", arch=cfg.name):
            shard_rep = sparse_shard_report(cfg)
        if shard_rep:
            for lname, r in shard_rep.items():
                print(f"[dryrun] {cfg.name} sparse shard balance [{lname}]: "
                      f"{r['n_shards']} shards, nnzb loads {r['loads']} "
                      f"(imbalance {r['imbalance']}x vs contiguous "
                      f"{r['contig_imbalance']}x), "
                      f"auto picks {r['auto_picks']}")
            records.append({"arch": cfg.name, "status": "sparse_shards",
                            "sparse_shards": shard_rep})
        with obs_trace.span("dryrun.attention_report", arch=cfg.name):
            attn_rep = sparse_attention_report(cfg)
        if attn_rep:
            print(f"[dryrun] {cfg.name} sparse attention mask: "
                  f"{attn_rep['mask']['kind']} nnzb={attn_rep['nnzb']} "
                  f"({attn_rep['block_density_vs_causal']}x of dense-causal "
                  f"blocks at seq {attn_rep['seq_len']}), "
                  f"impl={attn_rep['attn_impl']} "
                  f"(attn={attn_rep['attn_pick']}), picks "
                  f"sddmm={attn_rep['sddmm_pick']} "
                  f"spmm={attn_rep['spmm_pick']}")
            records.append({"arch": cfg.name, "status": "sparse_attention",
                            "sparse_attention": attn_rep})
        with obs_trace.span("dryrun.paged_kv_report", arch=cfg.name):
            kv_rep = paged_kv_report(cfg)
        if kv_rep:
            for g in kv_rep["groups"]:
                extra = ("" if not g.get("paged") else
                         f", {g['pages_touched_per_step']}/{g['n_pages']} "
                         "pages/step")
                print(f"[dryrun] {cfg.name} paged KV [{g['group']}]: "
                      f"{g.get('n_pages', 0)} pages x "
                      f"{g.get('page_bytes', 0)} B, resident "
                      f"{g.get('resident_bytes', 0)} B over "
                      f"{g['n_layers']} layers (paged={g['paged']}{extra})")
            records.append({"arch": cfg.name, "status": "paged_kv",
                            "paged_kv": kv_rep})
        for s in shapes:
            cell = SHAPES[s]
            if args.batch or args.seq:
                import dataclasses as dc
                cell = dc.replace(cell,
                                  global_batch=args.batch or cell.global_batch,
                                  seq_len=args.seq or cell.seq_len)
            ok, why = cell_applicable(cfg, cell)
            if not ok:
                records.append({"arch": cfg.name, "shape": cell.name,
                                "mesh": "x".join(
                                    str(x) for x in mesh.devices.shape),
                                "status": "skip", "reason": why})
                print(f"[dryrun] SKIP {cfg.name} x {cell.name}: {why}")
                continue
            try:
                with obs_trace.span("dryrun.cell", arch=cfg.name,
                                    shape=cell.name):
                    records.append(run_cell(
                        cfg, cell, mesh, remat=args.remat,
                        seq_shard_long=not args.no_seq_shard_long,
                        extrapolate=not args.no_extrapolate))
            except Exception as e:  # noqa
                traceback.print_exc()
                records.append({"arch": cfg.name, "shape": cell.name,
                                "status": "error", "error": repr(e)})

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {len(records)} records -> {args.out}")
    if obs_trace.enabled():
        print("[dryrun] trace summary:")
        print(obs_export.summary_tree(obs_trace.get_events()))
    n_err = sum(r["status"] == "error" for r in records)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
