"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — `pod` is the
outer data-parallel axis (gradient reduction across pods rides the DCI).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """The (possibly hierarchical) batch axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
