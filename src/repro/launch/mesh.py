"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — `pod` is the
outer data-parallel axis (gradient reduction across pods rides the DCI).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.

Version compat: ``jax.sharding.AxisType`` / the ``axis_types=`` kwarg only
exist on newer JAX; on 0.4.x we fall back to ``jax.make_mesh`` without axis
types, and on anything older still to a hand-built ``jax.sharding.Mesh``.
"""
from __future__ import annotations

import numpy as np

import jax

try:  # JAX >= 0.5-era explicit axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - exercised on JAX 0.4.x
    _AxisType = None


def _build_mesh(shape, axes):
    """jax.make_mesh with the newest supported signature."""
    shape, axes = tuple(shape), tuple(axes)
    if hasattr(jax, "make_mesh"):
        if _AxisType is not None:
            try:
                return jax.make_mesh(shape, axes,
                                     axis_types=(_AxisType.Auto,) * len(axes))
            except TypeError:  # make_mesh predates axis_types kwarg
                pass
        return jax.make_mesh(shape, axes)
    # oldest fallback: arrange the flat device list ourselves
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _build_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return _build_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """AbstractMesh across JAX versions: new API takes (shape, axis_names),
    0.4.x takes a single ((name, size), ...) tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # 0.4.x signature
        return AbstractMesh(tuple(zip(tuple(axes), tuple(shape))))


def data_axes(mesh) -> tuple:
    """The (possibly hierarchical) batch axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
