"""Roofline-term extraction from a compiled (dry-run) executable.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = sum over collective ops of bytes_wire / ICI_BW
               (all-reduce counted at the ring 2(n-1)/n factor, all-gather /
               reduce-scatter at (n-1)/n, all-to-all at (n-1)/n of the
               per-device payload; `n` = devices on the reduced axes)

``cost_analysis()`` yields flops+bytes of the per-device SPMD module;
collective bytes are NOT included there, so we parse the optimized HLO text.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.perf_model import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")

# "bf16[4096,512]{1,0}" or "f32[]" or tuple "(f32[8,16], f32[8,16])"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M)
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_REPLICA_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        total += numel * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _REPLICA_GROUPS_V2_RE.search(line)
    if m:                                   # [num_groups, group_size]
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_total: Dict[str, int]      # output-shape bytes per kind
    wire_bytes: float                # per-device bytes actually crossing links

    def to_dict(self):
        return {"counts": self.counts, "bytes": self.bytes_total,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    btot: Dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        type_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue                        # counted at -start
        nbytes = _shape_bytes(type_str)
        n = max(_group_size(line), 1)
        counts[kind] = counts.get(kind, 0) + 1
        btot[kind] = btot.get(kind, 0) + nbytes
        if kind == "collective-permute":     # point-to-point: full payload
            wire += nbytes
            continue
        if n <= 1:
            continue
        if kind == "all-reduce":
            wire += 2.0 * (n - 1) / n * nbytes
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire += (n - 1) / n * nbytes
    return CollectiveStats(counts, btot, wire)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float               # 6*N*D useful flops (global)
    useful_ratio: float              # model_flops / (flops_per_device*chips)
    peak_fraction: float             # compute term / max(all terms)
    collectives: Dict

    def to_dict(self):
        return dataclasses.asdict(self)


def compute_roofline(flops: float, bytes_acc: float, wire_bytes: float,
                     n_devices: int, model_flops: float,
                     peak=PEAK_FLOPS_BF16, hbm=HBM_BW, ici=ICI_BW,
                     collectives: Optional[Dict] = None) -> Roofline:
    t_c = flops / peak
    t_m = bytes_acc / hbm
    t_x = wire_bytes / ici
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    total_hw_flops = flops * n_devices
    useful = model_flops / total_hw_flops if total_hw_flops else 0.0
    t_max = max(t_c, t_m, t_x) or 1.0
    return Roofline(
        flops_per_device=flops, bytes_per_device=bytes_acc,
        wire_bytes_per_device=wire_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=useful, peak_fraction=t_c / t_max,
        collectives=collectives or {})


def model_flops_for(cfg, shape, n_tokens: Optional[int] = None) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D per generated
    token for decode/prefill forward-only."""
    n_active = cfg.active_param_count()
    if n_tokens is None:
        n_tokens = shape.global_batch * shape.seq_len if \
            shape.kind in ("train", "prefill") else shape.global_batch
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * n_tokens
