"""Serving launcher CLI: loads a (smoke-scale) model and runs continuous
batched decode over a synthetic request stream, reporting tokens/s.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium:smoke \
      --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    params = T.init_params(cfg, seed=0)
    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         cache_len=args.cache_len)

    rng = np.random.default_rng(0)
    requests = []
    for rid in range(args.requests):
        if cfg.input_mode == "codebooks":
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=(args.prompt_len, cfg.n_codebooks),
                                  dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len,
                                  dtype=np.int32)
        requests.append(Request(rid=rid, prompt=prompt,
                                max_new_tokens=args.new_tokens,
                                temperature=args.temperature))

    t0 = time.time()
    streamed = {}
    for rid, token in engine.generate(requests):
        streamed.setdefault(rid, []).append(token)
    dt = time.time() - t0
    total_new = sum(len(toks) for toks in streamed.values())
    print(f"[serve] {len(streamed)}/{args.requests} requests, "
          f"{total_new} new tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    print(f"[serve] scheduler: {engine.scheduler.step_idx} engine steps, "
          f"{engine.scheduler.prefix_hits} prefix-cache hits "
          f"({engine.scheduler.prefix_tokens_reused} tokens reused)")
    if engine.paged_kv is not None:
        rep = engine.paged_kv.report()
        print(f"[serve] paged KV: resident "
              f"{rep['resident_bytes_total']} B, offloaded "
              f"{rep['offload_bytes_total']} B over "
              f"{len(rep['groups'])} layer group(s)")
    for rid in sorted(streamed)[:3]:
        print(f"  rid={rid} first-tokens={streamed[rid][:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
