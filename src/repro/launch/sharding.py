"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Strategy (MaxText-style 2D: TP x FSDP):
  * `model` axis: tensor parallelism — attention heads, FFN hidden, MoE
    experts (EP), vocab, MLA per-head up-projections, BCSR nnz blocks.
  * `data` (+ `pod`) axes: batch parallelism; additionally FSDP-shards every
    weight's non-TP major dim (ZeRO-3-lite — GSPMD inserts the all-gathers).
  * decode caches: batch over data axes, kv-heads over model; when kv-heads
    don't divide the model axis (GQA kv=8 on a 16-wide axis) the cache
    SEQUENCE is sharded over `model` instead; the 500k single-request cell
    shards the sequence over the data axes too (sequence-parallel decode).

All rules are validated against tensor shapes: any mesh axis that does not
divide its dimension is dropped (jit in_shardings require divisibility).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes


# -------------------------------------------------------------- param rules
# spec given for the TRAILING dims; leading stack dims padded with None.
_PARAM_RULES = {
    # embeddings / head
    "embed": P("model", "data"),          # [V, D]
    "lm_head": P("data", "model"),        # [D, V]
    # attention
    "wq": P("data", "model"), "wk": P("data", "model"),
    "wv": P("data", "model"), "wo": P("model", "data"),
    "bq": P("model"), "bk": P("model"), "bv": P("model"),
    # MLA
    "wq_a": P("data", None), "wq_b": P(None, "model"),
    "wkv_a": P("data", None), "wkv_b": P(None, "model"),
    # dense / shared-expert MLP
    "w_gate": P("data", "model"), "w_up": P("data", "model"),
    "w_down": P("model", "data"),
    # MoE (experts on model = EP); router replicated on model
    "router": P("data", None),
    # SSD: FSDP on d_model; inner dims replicated (see DESIGN §5)
    "w_in": P("data", None), "w_out": P(None, "data"),
    "conv_w": P(None, None), "conv_b": P(None),
    "A_log": P(None), "D": P(None), "dt_bias": P(None),
    # norms
    "norm": P(None), "ln1": P(None), "ln2": P(None),
    "ln1_post": P(None), "ln2_post": P(None), "final_norm": P(None),
    "q_norm": P(None), "kv_norm": P(None),
    # BCSR sparse layer: REPLICATED.  nnz-sharding over `model` makes every
    # sparse matmul reduce partial output rows across shards (all-reduce of
    # [M, tokens] activations, ~1 GB/layer measured — §Perf C baseline);
    # the block-sparse weights themselves are tiny (90% of the dense FFN
    # removed), so replication costs MBs and kills the collective entirely.
    "vals": P(None, None, None),
    "row_ids": P(None), "col_ids": P(None), "real_mask": P(None),
    "t_perm": P(None), "t_row_ids": P(None), "t_col_ids": P(None),
    # reorder permutation leaves (core.permute): replicated like the other
    # index arrays — every chip un-permutes its own token panel's output
    "row_perm": P(None), "inv_perm": P(None),
    # partitioned-execution leaves (launch.dist_spmm, SparsitySpec.shards):
    # replicated index structure — the row-shard axis lives in the
    # dedicated spmm mesh consumed by shard_map (use_spmm_mesh), not in
    # the training mesh, and the shapes are tiny (int32 index lists)
    "shard_src": P(None, None), "shard_row_ids": P(None, None),
    "shard_col_ids": P(None, None), "shard_mask": P(None, None),
    "shard_t_perm": P(None, None), "shard_t_row_ids": P(None, None),
    "shard_t_col_ids": P(None, None), "gather_rows": P(None),
}

_MOE_EXPERT_LEAVES = {"w_gate", "w_up", "w_down"}  # [E, D, F] under "moe"


def _axis_size(mesh, a) -> int:
    if a is None:
        return 1
    if isinstance(a, tuple):
        return int(np.prod([mesh.shape[x] for x in a]))
    return int(mesh.shape[a])


def _sanitize(mesh, a):
    """Drop axes not present in this mesh (small test meshes)."""
    if a is None:
        return None
    if isinstance(a, tuple):
        kept = tuple(x for x in a if x in mesh.axis_names)
        return kept if kept else None
    return a if a in mesh.axis_names else None


def fit_spec(mesh, spec: P, shape) -> P:
    """Sanitize + enforce divisibility (jit in_shardings requirement)."""
    out = []
    for dim, a in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        a = _sanitize(mesh, a)
        if a is not None and dim % _axis_size(mesh, a) != 0:
            if isinstance(a, tuple):          # try a shrinking prefix
                while a and dim % _axis_size(mesh, a) != 0:
                    a = a[:-1]
                a = a or None
            else:
                a = None
        out.append(a)
    return P(*out)


def _rule_for(path, leaf) -> P:
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    name = keys[-1]
    ndim = leaf.ndim

    if name in _MOE_EXPERT_LEAVES and "moe" in keys and "shared" not in keys:
        base = {"w_gate": P("model", "data", None),
                "w_up": P("model", "data", None),
                "w_down": P("model", None, "data")}[name]
    elif name == "embed" and ndim >= 3:
        base = P(None, "model", "data")       # codebooks [ncb, V, D]
    elif name == "lm_head" and ndim >= 3:
        base = P(None, "data", "model")
    elif name in _PARAM_RULES:
        base = _PARAM_RULES[name]
    else:
        base = P()

    pad = ndim - len(base)
    if pad < 0:
        return P()
    return P(*([None] * pad + list(base)))


def _batch_axes(mesh):
    da = data_axes(mesh)
    return da if len(da) > 1 else (da[0] if da else None)


def spmm_shard_count(mesh=None) -> int:
    """Number of shards a sparse layer's work is split across — the bin
    count ``SparsitySpec(reorder="shard_balance")`` balances nonzero-block
    loads over (``core.permute.shard_balance_rows``).  BCSR weights are
    replicated under the rules above while the token panel is sharded over
    ALL mesh axes (see ``apply_sparse_linear``), so the balance target is
    the full mesh size; with no mesh yet (init before launch) it falls
    back to the process's device count."""
    if mesh is None:
        return max(jax.device_count(), 1)
    return max(int(np.prod([mesh.shape[a] for a in mesh.axis_names])), 1)


def _strip_data_axes(spec: P) -> P:
    """Serve-mode: weights are NOT FSDP-sharded (no per-token all-gathers);
    TP over `model` only, replicas across data axes — standard inference
    sharding."""
    def strip(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x for x in a if x not in ("data", "pod"))
            return kept or None
        return None if a in ("data", "pod") else a
    return P(*[strip(a) for a in spec])


# serve-mode overrides: decode is WEIGHT-traffic bound, so layers whose
# train rule is FSDP-only get explicit inference TP (§Perf cell A2/A3).
# SSD w_in is ROW-parallel (its fused z|xBC|dt output dim is misaligned with
# shard boundaries — column-parallel forced per-layer state resharding,
# measured 2.4x worse in §Perf A2); the psum'd projection is only ~2 MB.
_SERVE_RULES = {
    "w_in": P("model", None),
    "w_out": P(None, "model"),
    "wq_a": P(None, "model"), "wkv_a": P(None, None),
    "router": P(None, None),
}


def param_shardings(mesh, params_or_specs, mode: str = "train") -> Any:
    def assign(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        rule = _rule_for(path, leaf)
        if mode == "serve":
            name = keys[-1]
            is_expert = name in _MOE_EXPERT_LEAVES and "moe" in keys and \
                "shared" not in keys
            if is_expert:
                pass      # MoE expert banks stay FSDP-sharded: replicating
                          # 60x7.5 GB of experts cannot fit HBM (§Perf A/B)
            elif name in _SERVE_RULES:
                base = _SERVE_RULES[name]
                rule = P(*([None] * (leaf.ndim - len(base)) + list(base)))
            else:
                rule = _strip_data_axes(rule)
        spec = fit_spec(mesh, rule, leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, params_or_specs)


def opt_state_shardings(mesh, opt_specs, params_shardings=None) -> Any:
    """m/v mirror the param shardings; scalar leaves replicated."""
    def assign(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # strip the leading "m"/"v" container key and reuse the param rule
        spec = fit_spec(mesh, _rule_for(path[1:], leaf), leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, opt_specs)


# ------------------------------------------------------------ batch / cache
def batch_shardings(mesh, batch_specs) -> Any:
    bd = _batch_axes(mesh)

    def assign(path, leaf):
        spec = fit_spec(mesh, P(*([bd] + [None] * (leaf.ndim - 1))),
                        leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, batch_specs)


def cache_shardings(mesh, cache_specs_tree, cfg: ModelConfig,
                    seq_shard: bool = False) -> Any:
    """Decode caches.  Layout conventions (after layer stacking):
       attn k/v:   [..., B, S, KV, dh]
       mla:        ckv [..., B, S, r] / krope [..., B, S, rope]
       ssd:        conv [..., B, cw-1, d_xbc]; state [..., B, H, P, N]
    seq_shard=True (single-request long-context): S takes the data axes."""
    bd = _batch_axes(mesh)
    model_ok = "model" in mesh.axis_names

    def assign(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = keys[-1]
        nd = leaf.ndim
        shape = leaf.shape
        if name in ("k", "v"):
            B, S, KV, dh = shape[-4:]
            kv_axis = "model" if model_ok and KV % mesh.shape["model"] == 0 \
                else None
            s_axes = []
            if seq_shard and bd is not None:
                s_axes += list(bd) if isinstance(bd, tuple) else [bd]
            if kv_axis is None and model_ok:
                s_axes.append("model")
            spec = [None] * (nd - 4) + [
                None if seq_shard else bd,
                tuple(s_axes) if s_axes else None,
                kv_axis, None]
        elif name in ("ckv", "krope"):
            s_axes = []
            if seq_shard and bd is not None:
                s_axes += list(bd) if isinstance(bd, tuple) else [bd]
            spec = [None] * (nd - 3) + [
                None if seq_shard else bd,
                tuple(s_axes) if s_axes else None, None]
        elif name == "conv":
            spec = [None] * (nd - 3) + [None if seq_shard else bd,
                                        None, None]
        elif name == "state":
            spec = [None] * (nd - 4) + [None if seq_shard else bd,
                                        None, None, None]
        elif name in ("pages", "page_live"):
            # paged-KV page tables (serve.paged_kv.PagedKVCache
            # .table_leaves): [nbr, max_bpr] index/liveness constants of
            # the mask BCSR.  Every device gathers through the WHOLE
            # table (the decode row index is traced), and the tables are
            # a few KiB — replicate, never shard.
            spec = [None] * nd
        else:
            spec = [None] * nd
        return NamedSharding(mesh, fit_spec(mesh, P(*spec), shape))
    return jax.tree_util.tree_map_with_path(assign, cache_specs_tree)


def replicated(mesh, specs) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), specs)
