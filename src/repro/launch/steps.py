"""Jit-able step functions (train / prefill / decode) + input specs.

These are the exact functions the dry-run lowers at 256/512 devices and the
train/serve loops execute for real; one definition, both uses.

Sparse-FFN archs need no special handling here: the structure metadata the
SpMM dispatch keys on is STATIC aux data re-derived inside ``mlp()`` from
the arch config (``models.layers.mlp_sparse_metas``), so every step traced
from these functions — train, prefill, decode — resolves the same real
per-shard kernel picks as the raw ``dist_spmm`` API, with no extra
arguments threaded through params or inputs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import transformer as T
from repro.optim import adamw


# --------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    sds = jax.ShapeDtypeStruct
    B, L = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "codebooks":
            batch = {"tokens": sds((B, L, cfg.n_codebooks), jnp.int32),
                     "labels": sds((B, L, cfg.n_codebooks), jnp.int32)}
        elif cfg.input_mode == "tokens+patches":
            lt = L - cfg.patch_tokens
            batch = {"tokens": sds((B, lt), jnp.int32),
                     "patch_embeds": sds((B, cfg.patch_tokens, cfg.d_model),
                                         jnp.bfloat16),
                     "labels": sds((B, lt), jnp.int32)}
        else:
            batch = {"tokens": sds((B, L), jnp.int32),
                     "labels": sds((B, L), jnp.int32)}
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a cache of length L
    if cfg.input_mode == "codebooks":
        return {"tokens": sds((B, cfg.n_codebooks), jnp.int32)}
    return {"tokens": sds((B,), jnp.int32)}


# ------------------------------------------------------------------- steps
def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    remat: str = "full"):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, parts = T.train_loss(cfg, p, batch, remat=remat)
            return loss, parts
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True, allow_int=True)(params)
        params2, opt_state2, om = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **parts, **om}
        return params2, opt_state2, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        logits, cache = T.prefill(cfg, params, batch, cache_len)
        # return just the last-position logits (what serving samples from)
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return T.decode_step(cfg, params, cache, tokens, pos)
    return serve_step


def opt_specs(cfg: ModelConfig, params_specs):
    return jax.eval_shape(adamw.init, params_specs)
