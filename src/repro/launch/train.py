"""Training launcher CLI.

Examples:
  # end-to-end ~100M-param sparse-FFN LM for a few hundred steps on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch smat-ffn-1.3b:smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

  # failure injection + automatic restart from the latest checkpoint:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b:smoke \
      --steps 60 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt2 \
      --inject-failure 30
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.launch import mesh as mesh_lib
from repro.optim import adamw
from repro.train.loop import train_with_restarts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots"])
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 1,1 (default: all local devices on data)")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.INFO,
        format="%(asctime)s %(name)s %(levelname)s %(message)s")

    cfg = get_config(args.arch)
    shape = ShapeCell("cli", "train", args.seq, args.batch)

    from repro.core.sparse_linear import is_sharded
    if cfg.ffn_sparsity is not None and is_sharded(cfg.ffn_sparsity):
        # partitioned sparse FFN: surface the per-shard balance and the
        # autotune picks the model path will dispatch with (the static
        # metas mlp() derives — the same ones the train step traces against)
        from repro.launch.dryrun import sparse_shard_report
        rep = sparse_shard_report(cfg, n_tokens=args.batch * args.seq)
        for lname, r in rep.items():
            logging.getLogger("train").info(
                "sparse FFN [%s]: %d shards, nnzb loads %s, auto picks %s",
                lname, r["n_shards"], r["loads"], r["auto_picks"])

    def mesh_factory(restart_idx: int):
        if args.mesh_shape:
            dims = tuple(int(x) for x in args.mesh_shape.split(","))
        else:
            n = len(jax.devices())
            dims = (n, 1)
        return mesh_lib.make_mesh(dims, ("data", "model"))

    res = train_with_restarts(
        cfg, shape, mesh_factory,
        total_steps=args.steps,
        opt_cfg=adamw.AdamWConfig(lr=args.lr, total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at_step=args.inject_failure,
        max_restarts=args.max_restarts, remat=args.remat)
    print(f"[train] done: {res.final_step} steps, "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
          f"restarts={res.restarts_used}, stragglers={res.straggler_steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
