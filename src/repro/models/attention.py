"""Block-sparse attention — the SDDMM/SpMM pair as a sequence-mixing layer.

The paper's ops power two workloads: sparse *weights* (the FFN path that
has been in the repo since PR 1) and sparse *interactions* — attention
whose score matrix is only evaluated on a static block mask.  This module
builds the second one from the public kernel ops:

    scores = ops.sddmm(mask, Q, K)        # Q K^T sampled at stored blocks
    probs  = masked block softmax         # per query row, stored keys only
    ctx    = ops.spmm(mask<-probs, V)     # probs @ V over the same structure

Gradients need no extra code: SpMM and SDDMM are mutual duals (each op's
custom VJP calls the other), so d(ctx)/d{Q,K,V} bounces between the two
Pallas kernels exactly like the dense math would between its two GEMMs.

Since PR 6 the three dispatches also exist as ONE kernel:
``kernels.bcsr_attn.bcsr_attn_fused`` recomputes the score blocks inside
a single Pallas launch and folds them into per-query-block running
(max, sum, accumulator) state — O(L * d) memory, no materialized scores
or probs.  ``backend="auto"`` arbitrates fused vs composed through the
``op="attn"`` autotune family (v6 fingerprints — fused and composed
picks never alias); ``backend="fused"`` forces it.  The fused forward is
bit-for-bit equal to the composed path in f32, which lets the backward
stay on the composed dual-VJP route (no fused backward).

Masks are STATIC (a pure function of ``(mask_spec, seq_len, block)``), so
the whole PR-4 static-metadata pipeline applies: ``attention_mask_meta``
memoizes the true structure meta — nnzb, ``max_bpr``, skew — without
building arrays, ``backend="auto"`` resolves the SDDMM and SpMM variants
per layer from the v6 fingerprints, and scanned layer stacks merge their
per-layer metas with ``core.sparse_linear.merge_sparse_metas``.  The index
arrays themselves are trace-time constants, never params — a mask has no
gradient.

Wired end-to-end: ``ModelConfig.attn_sparsity`` switches
``models.layers.attention``'s train/prefill path onto this module (decode
applies the same mask spec as a positional bias, so serving stays
consistent with training); ``launch.dryrun`` prints the mask nnzb and the
auto picks; ``AttnSparsitySpec(shards=S)`` row-shards the score structure
through ``launch.dist_spmm`` (shard_map under a compatible mesh, identical
in-process math otherwise).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcsr as bcsr_lib
# the spec/builder leaf lives in core (configs imports it too — the layer
# map stays one-directional); this module is the user-facing namespace,
# so re-export the whole surface:
from repro.core.attention_mask import (NEG_INF, AttnMaskSpec,  # noqa: F401
                                       AttnSparsitySpec, banded,
                                       blockwise_causal, local_global,
                                       mask_allowed)
from repro.core.sparse_linear import merge_sparse_metas
from repro.kernels import bcsr_attn, ops


def decode_mask_bias(spec: AttnMaskSpec, q_pos: jnp.ndarray,
                     k_pos: jnp.ndarray) -> jnp.ndarray:
    """Additive decode-step bias ``[..., Lq, Sk]`` applying the SAME mask
    the block-sparse train/prefill path realizes — what keeps a served
    model consistent with how it was trained."""
    return jnp.where(mask_allowed(spec, q_pos, k_pos), 0.0, NEG_INF)


@functools.lru_cache(maxsize=None)
def decode_page_table(spec: AttnMaskSpec, seq_len: int,
                      block: Tuple[int, int]):
    """Serving page table of the mask — literally the mask BCSR reshaped
    to a ``[n_block_rows, max_bpr]`` slot grid (the page-table-as-BCSR
    contract of ``serve.paged_kv``): row ``i`` lists, in ascending key
    order, the ids of every KV page (block-column of width ``block[1]``)
    that queries in block-row ``i`` can ever touch under ``spec``;
    ``live`` marks real slots (rows with fewer than ``max_bpr`` mask
    blocks pad with dead slots that gather page 0 and are masked out).

    Host numpy constants, memoized like the other mask pipelines —
    trace-safe to close over in a jitted decode step.  Returns
    ``(pages, live, meta)``.

    >>> from repro.models import attention as A
    >>> pages, live, meta = A.decode_page_table(A.banded(32), 64, (16, 16))
    >>> pages.shape == live.shape == (4, meta.max_bpr)
    True
    >>> pages[3][live[3]].tolist()      # block-row 3 touches pages 1..3
    [1, 2, 3]
    """
    a = attention_mask_bcsr(spec, seq_len, block)
    meta = attention_mask_meta(spec, seq_len, block)
    nbr = meta.n_block_rows
    slots = max(meta.max_bpr, 1)
    pages = np.zeros((nbr, slots), np.int32)
    live = np.zeros((nbr, slots), bool)
    counts = np.bincount(a.row_ids, minlength=nbr)
    rowptr = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(a.row_ids.shape[0]) - rowptr[a.row_ids]
    pages[a.row_ids, slot] = a.col_ids          # ascending within each row
    live[a.row_ids, slot] = True
    pages.setflags(write=False)
    live.setflags(write=False)
    return pages, live, meta


# ======================================================== mask BCSR pipeline
@functools.lru_cache(maxsize=None)
def attention_mask_bcsr(spec: AttnMaskSpec, seq_len: int,
                        block: Tuple[int, int]) -> bcsr_lib.BCSR:
    """Host BCSR of the element mask (vals are 0/1 f32; blocks with any
    allowed element are stored).  Memoized: the mask is a pure function of
    ``(spec, seq_len, block)`` — the attention analogue of the weight
    pipeline's deterministic ``(seed, dims, spec)`` patterns.

    Built one block-row strip at a time (peak O(h * L) host memory) — a
    dense [L, L] mask would be multi-GiB at the 32k prefill cell — with
    output identical to ``from_dense(mask_allowed(...))`` on the full
    dense mask (entries row-major by (block-row, block-col))."""
    h, w = block
    nbr = -(-seq_len // h)
    nbc = -(-seq_len // w)
    k_pos = np.arange(nbc * w)
    k_valid = k_pos < seq_len
    rows, cols, vals = [], [], []
    for i in range(nbr):
        q_pos = np.arange(i * h, (i + 1) * h)
        strip = mask_allowed(spec, q_pos, k_pos)
        strip &= k_valid[None, :] & (q_pos < seq_len)[:, None]
        blocks = strip.reshape(h, nbc, w).transpose(1, 0, 2)  # [nbc, h, w]
        nz = np.flatnonzero(blocks.any(axis=(1, 2)))
        rows.append(np.full(nz.size, i, np.int32))
        cols.append(nz.astype(np.int32))
        vals.append(blocks[nz].astype(np.float32))
    row_ids = np.concatenate(rows)
    col_ids = np.concatenate(cols)
    vals = np.concatenate(vals) if row_ids.size else \
        np.zeros((0, h, w), np.float32)
    return bcsr_lib.BCSR(vals, col_ids, row_ids,
                         bcsr_lib.rowptr_from_rows(row_ids, nbr),
                         (seq_len, seq_len), (h, w))


@functools.lru_cache(maxsize=None)
def attention_mask_meta(spec: AttnMaskSpec, seq_len: int,
                        block: Tuple[int, int]) -> ops.SparseMeta:
    """TRUE structure meta of the mask — ``prepare_sparse_meta`` on the
    deterministic mask BCSR, memoized.  This is what ``backend="auto"``
    fingerprints (v6: the ``op=attn`` fused-vs-composed pick plus the
    composed path's ``op=sddmm`` / ``op=spmm`` picks) and what
    ``launch.dryrun`` reports, with no arrays built."""
    return ops.prepare_sparse_meta(attention_mask_bcsr(spec, seq_len, block))


@functools.lru_cache(maxsize=None)
def attention_mask_arrays(spec: AttnMaskSpec, seq_len: int,
                          block: Tuple[int, int]
                          ) -> Tuple[ops.SparseArrays, ops.SparseMeta]:
    """Arrays + meta of the mask structure.  The arrays are HOST (numpy)
    constants — index structure and 0/1 element weights.  They are not
    params, carry no gradient, and embed as trace-time constants in
    whatever jit/scan body touches them; keeping them numpy (instead of
    device arrays) makes the memoized value safe to build lazily inside a
    trace and to share across traces."""
    host, meta = ops._prepare_sparse_host(
        attention_mask_bcsr(spec, seq_len, block), reorder="identity",
        reorder_granularity="element", tau=0.7, max_candidates=None,
        n_shards=1)
    assert meta == attention_mask_meta(spec, seq_len, block)
    arrays = ops.SparseArrays(
        vals=host["vals"].astype(np.float32),
        row_ids=host["row_ids"].astype(np.int32),
        col_ids=host["col_ids"].astype(np.int32),
        real_mask=host["real_mask"],
        t_perm=host["t_perm"].astype(np.int32),
        t_row_ids=host["t_row_ids"].astype(np.int32),
        t_col_ids=host["t_col_ids"].astype(np.int32),
        row_perm=host["row_perm"].astype(np.int32),
        inv_perm=host["inv_perm"].astype(np.int32))
    return arrays, meta


def merged_attention_meta(specs, seq_len: int,
                          block: Tuple[int, int]) -> ops.SparseMeta:
    """One static meta covering every layer of a scanned stack — the
    attention twin of ``models.layers.mlp_sparse_metas``: per-spec metas
    merge conservatively (``merge_sparse_metas``: stats take the stack
    max), so a single traced body dispatches correctly for all layers."""
    return merge_sparse_metas(
        [attention_mask_meta(s, seq_len, block) for s in specs])


@functools.lru_cache(maxsize=None)
def _mask_sharded(spec: AttnMaskSpec, seq_len: int, block: Tuple[int, int],
                  n_shards: int):
    """Row-partitioned view of the mask structure (``launch.dist_spmm``):
    the context SpMM's score operand split over block-rows with the LPT
    balancer.  The flat probs computed by the SDDMM drop into the
    partition's ``vals`` slot untouched — both sides are built from the
    same padded host BCSR, so the global entry order is shared."""
    from repro.launch import dist_spmm  # local: layering
    a = attention_mask_bcsr(spec, seq_len, block)
    host, smeta = dist_spmm._prepare_sharded_host(a, n_shards)
    _, meta = attention_mask_arrays(spec, seq_len, block)
    if smeta.nnzb != meta.nnzb:   # same padded entry list by construction
        raise AssertionError(
            f"sharded/unsharded mask entry counts diverged: "
            f"{smeta.nnzb} vs {meta.nnzb}")
    # host (numpy) constants, like attention_mask_arrays — trace-safe
    sharr = dist_spmm.ShardedArrays(
        vals=host["vals"].astype(np.float32),
        src_index=host["src_index"].astype(np.int32),
        row_ids=host["row_ids"].astype(np.int32),
        col_ids=host["col_ids"].astype(np.int32),
        real_mask=host["real_mask"],
        t_perm=host["t_perm"].astype(np.int32),
        t_row_ids=host["t_row_ids"].astype(np.int32),
        t_col_ids=host["t_col_ids"].astype(np.int32),
        gather_rows=host["gather_rows"].astype(np.int32))
    return sharr, smeta


# ============================================================= sparse layer
def block_softmax(scores: jnp.ndarray, elem_mask: jnp.ndarray,
                  row_ids: jnp.ndarray, n_block_rows: int,
                  cap: Optional[float] = None) -> jnp.ndarray:
    """Masked softmax over a BCSR score matrix, per GLOBAL query row.

    scores     [nnzb, h, w] f32 logits (already scaled)
    elem_mask  [nnzb, h, w] bool — valid (stored AND allowed) elements
    row_ids    [nnzb] block-row of each block
    returns    [nnzb, h, w] probabilities; masked elements are exactly 0,
               each valid query row sums to 1 across its stored blocks.
    """
    if cap is not None:
        scores = cap * jnp.tanh(scores / cap)
    logits = jnp.where(elem_mask, scores, NEG_INF)
    blk_max = jnp.max(logits, axis=2)                       # [nnzb, h]
    row_max = jax.ops.segment_max(blk_max, row_ids,
                                  num_segments=n_block_rows)  # [nbr, h]
    row_max = jnp.maximum(row_max, -1e30)   # rows with no valid element
    z = jnp.exp(logits - row_max[row_ids][:, :, None])
    z = jnp.where(elem_mask, z, 0.0)
    denom = jax.ops.segment_sum(z.sum(axis=2), row_ids,
                                num_segments=n_block_rows)    # [nbr, h]
    denom = jnp.maximum(denom, 1e-30)
    return z / denom[row_ids][:, :, None]


def _context_spmm(probs: jnp.ndarray, arrays: ops.SparseArrays,
                  meta: ops.SparseMeta, v: jnp.ndarray,
                  spec: AttnSparsitySpec) -> jnp.ndarray:
    """ctx = probs @ V over the mask structure — unsharded, or through the
    ``dist_spmm`` row partition when ``spec.shards > 0``."""
    if spec.shards > 0:
        from repro.launch import dist_spmm  # local: layering
        sharr, smeta = _mask_sharded(spec.mask, meta.shape[0], meta.block,
                                     spec.shards)
        mesh = dist_spmm.current_spmm_mesh()
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            if sizes.get(dist_spmm.AXIS_ROW) != spec.shards:
                mesh = None     # incompatible ambient mesh: local fallback
        return dist_spmm.spmm_sharded(
            sharr._replace(vals=probs), smeta, v, backend=spec.backend,
            bn=spec.bn, interpret=spec.interpret, mesh=mesh)
    return ops.spmm(arrays._replace(vals=probs), meta, v,
                    backend=spec.backend, bn=spec.bn,
                    interpret=spec.interpret)


def _composed_spec(spec: AttnSparsitySpec) -> AttnSparsitySpec:
    """The spec the composed three-dispatch path runs under:
    ``backend="fused"`` is an attention-level choice the SDDMM/SpMM ops
    don't know — normalize it to ``"auto"`` for them."""
    if spec.backend == "fused":
        return dataclasses.replace(spec, backend="auto")
    return spec


def _composed_heads(qf: jnp.ndarray, kf: jnp.ndarray, vf: jnp.ndarray,
                    spec: AttnSparsitySpec, scale: float,
                    cap: Optional[float]) -> jnp.ndarray:
    """SDDMM -> block softmax -> SpMM over folded ``[G, L, d]`` heads —
    the three-dispatch reference path (and the backward route of the
    fused forward)."""
    L = qf.shape[1]
    spec = _composed_spec(spec)
    arrays, meta = attention_mask_arrays(spec.mask, L, spec.block)
    # host constants: valid = stored-and-allowed AND not a padding entry
    elem_mask = (arrays.vals > 0.5) & arrays.real_mask[:, None, None]

    def one_head(qi, ki, vi):
        scores = ops.sddmm(arrays, meta, qi, ki, backend=spec.backend,
                           bn=spec.bn, interpret=spec.interpret,
                           out_dtype=jnp.float32)
        probs = block_softmax(scores * scale, elem_mask, arrays.row_ids,
                              meta.n_block_rows, cap=cap)
        return _context_spmm(probs, arrays, meta, vi, spec)

    return jax.vmap(one_head)(qf, kf, vf)


@functools.lru_cache(maxsize=None)
def _fused_inputs(spec: AttnMaskSpec, seq_len: int, block: Tuple[int, int]):
    """Host constants for the fused kernel: the 0/1 element-mask blocks
    and the static (block-row x slot) schedule (padding slots -> the
    sentinel index ``nnzb`` — the host twin of
    ``ops._sddmm_row_loop_schedule``).  Memoized like the other mask
    pipelines; numpy, so trace-safe as closed-over constants."""
    arrays, meta = attention_mask_arrays(spec, seq_len, block)
    emask = ((arrays.vals > 0.5) &
             arrays.real_mask[:, None, None]).astype(np.float32)
    nnzb = arrays.row_ids.shape[0]
    counts = np.bincount(arrays.row_ids, minlength=meta.n_block_rows)
    rowptr = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(nnzb) - rowptr[arrays.row_ids]
    pos = arrays.row_ids * meta.max_bpr + slot
    flat_idx = np.full(meta.n_block_rows * meta.max_bpr, nnzb, np.int32)
    flat_col = np.zeros(meta.n_block_rows * meta.max_bpr, np.int32)
    flat_idx[pos] = np.arange(nnzb, dtype=np.int32)
    flat_col[pos] = arrays.col_ids
    return emask, flat_idx, flat_col, meta


def _fused_heads(qf: jnp.ndarray, kf: jnp.ndarray, vf: jnp.ndarray,
                 spec: AttnSparsitySpec, scale: float,
                 cap: Optional[float]) -> jnp.ndarray:
    emask, flat_idx, flat_col, meta = _fused_inputs(
        spec.mask, qf.shape[1], spec.block)
    return bcsr_attn.bcsr_attn_fused(
        qf, kf, vf, emask, flat_idx, flat_col,
        n_block_rows=meta.n_block_rows, n_block_cols=meta.n_block_cols,
        block=meta.block, scale=scale, cap=cap, bn=spec.bn,
        out_dtype=jnp.float32, interpret=spec.interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _attn_fused(spec: AttnSparsitySpec, scale: float, cap: Optional[float],
                qf: jnp.ndarray, kf: jnp.ndarray, vf: jnp.ndarray):
    """Fused forward, composed backward.  The statics (spec, scale, cap)
    are hashable nondiff args; the bit-for-bit forward pin is what makes
    differentiating THROUGH the composed path consistent with the fused
    primal."""
    return _fused_heads(qf, kf, vf, spec, scale, cap)


def _attn_fused_fwd(spec, scale, cap, qf, kf, vf):
    return _fused_heads(qf, kf, vf, spec, scale, cap), (qf, kf, vf)


def _attn_fused_bwd(spec, scale, cap, res, g):
    qf, kf, vf = res
    _, vjp = jax.vjp(
        lambda a, b, c: _composed_heads(a, b, c, spec, scale, cap),
        qf, kf, vf)
    return vjp(g)


_attn_fused.defvjp(_attn_fused_fwd, _attn_fused_bwd)


def resolve_attn_impl(spec: AttnSparsitySpec, seq_len: int,
                      head_dim: int) -> str:
    """``"fused"`` | ``"composed"`` — the attention-level dispatch.

    Explicit kernel backends (``xla``/``pallas``/...) and sharded score
    paths stay composed; ``backend="fused"`` forces the fused kernel;
    ``backend="auto"`` consults the ``op="attn"`` autotune family (v6
    fingerprints, disjoint from the sddmm/spmm key spaces).  Static info
    only — trace-safe."""
    if spec.shards > 0 or spec.backend not in ("auto", "fused"):
        return "composed"
    meta = attention_mask_meta(spec.mask, seq_len, spec.block)
    if meta.max_bpr <= 0:
        return "composed"   # no static schedule bound -> no fused walk
    if spec.backend == "fused":
        return "fused"
    from repro.kernels import autotune  # local import: layering
    choice = autotune.get_autotuner().pick(meta, head_dim, op="attn")
    return "fused" if choice.variant == "attn_fused" else "composed"


def block_sparse_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           spec: AttnSparsitySpec, *,
                           scale: Optional[float] = None,
                           cap: Optional[float] = None) -> jnp.ndarray:
    """Attention with scores evaluated only on the stored mask blocks.

    q, k, v  [B, L, H, d]  (GQA callers repeat KV heads first)
    returns  [B, L, H, d] in f32 (callers cast), matching the dense-masked
             reference on the mask support.

    The per-(batch, head) instance is SDDMM -> block softmax -> SpMM —
    either as three dispatches (``vmap`` over the two custom-VJP ops with
    the mask structure closed over as constants), or, when
    ``resolve_attn_impl`` picks the fused path (``backend="auto"`` via
    the ``op="attn"`` v6 autotune family, or ``backend="fused"``), as ONE
    Pallas launch (``kernels.bcsr_attn.bcsr_attn_fused``) whose forward
    is bit-for-bit equal in f32 and whose backward reuses the composed
    dual-VJP route.

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.models import attention as A
    >>> rng = np.random.default_rng(0)
    >>> q = jnp.asarray(rng.standard_normal((2, 64, 2, 8)), jnp.float32)
    >>> k = jnp.asarray(rng.standard_normal((2, 64, 2, 8)), jnp.float32)
    >>> v = jnp.asarray(rng.standard_normal((2, 64, 2, 8)), jnp.float32)
    >>> spec = A.AttnSparsitySpec(mask=A.banded(32), block=(8, 8),
    ...                           backend="xla")
    >>> out = A.block_sparse_attention(q, k, v, spec)
    >>> out.shape
    (2, 64, 2, 8)
    >>> bool(jnp.all(jnp.isfinite(out)))
    True
    """
    B, L, H, d = q.shape
    # normalize to plain python floats so both paths scale/cap with the
    # SAME weak-typed constants (bit-for-bit pin) and the fused op's
    # nondiff args stay hashable
    scale = float(d ** -0.5 if scale is None else scale)
    cap = None if cap is None else float(cap)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, L, d).astype(jnp.float32)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, L, d).astype(jnp.float32)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, L, d).astype(jnp.float32)
    if resolve_attn_impl(spec, L, d) == "fused":
        ctx = _attn_fused(spec, scale, cap, qf, kf, vf)    # [B*H, L, d]
    else:
        ctx = _composed_heads(qf, kf, vf, spec, scale, cap)
    return ctx.reshape(B, H, L, d).transpose(0, 2, 1, 3)


# ================================================================ reporting
def attention_mask_report(spec: AttnSparsitySpec, seq_len: int,
                          head_dim: int = 0) -> dict:
    """Mask structure + kernel picks for the dry-run: nnzb, block density
    vs dense causal, the attention-level fused-vs-composed resolution,
    and the v6 ``op=attn`` / ``op=sddmm`` / ``op=spmm`` picks the spec's
    backend resolves at this sequence length.

    ``head_dim`` is the contraction width the runtime ops actually
    fingerprint with (both the SDDMM's N axis and the context SpMM's
    panel are head-dim wide per vmapped instance) — pass the model's real
    head dim or the printed picks can come from the wrong N bucket."""
    meta = attention_mask_meta(spec.mask, seq_len, spec.block)
    nbr = meta.n_block_rows
    causal_blocks = nbr * (nbr + 1) // 2
    head_n = head_dim or meta.block[1]
    cspec = _composed_spec(spec)
    sddmm_be = ops.resolve_backend(cspec.backend, cspec.bn, meta, head_n,
                                   op="sddmm")
    spmm_be = ops.resolve_backend(cspec.backend, cspec.bn, meta, head_n,
                                  op="spmm")
    from repro.kernels import autotune  # local import: layering
    attn_choice = autotune.get_autotuner().pick(meta, head_n, op="attn")
    return {
        "mask": dataclasses.asdict(spec.mask),
        "block": list(meta.block),
        "seq_len": seq_len,
        "nnzb": meta.nnzb,
        "max_bpr": meta.max_bpr,
        "block_density_vs_causal": round(meta.nnzb / max(causal_blocks, 1),
                                         4),
        "attn_impl": resolve_attn_impl(spec, seq_len, head_n),
        "attn_pick": attn_choice.variant,
        "sddmm_pick": "{}/bn{}".format(*sddmm_be),
        "spmm_pick": "{}/bn{}".format(*spmm_be),
        "shards": spec.shards,
    }
