"""Transformer layer components: norms, RoPE, GQA attention (sliding window,
logit softcap, QKV bias, or block-sparse scores on a static BCSR mask —
``cfg.attn_sparsity``), MLA (DeepSeek), gated MLP (dense or block-sparse —
the paper's technique as a drop-in FFN).

Sparse-FFN layers inherit the full ``SparsitySpec`` surface through
``apply_sparse_linear`` — including ``shards="auto"`` (shard count
resolved per layer from dims alone, so scan-stacked layers keep shared
leaf shapes) and ``shard_chunks`` (the communication-overlap pipeline
depth; chunked dispatch is bit-identical to unchunked, so it is safe by
default).

Conventions:
  * params are nested dicts of jnp arrays; init fns take (cfg, key).
  * activations are [B, L, D]; caches are dicts of ring buffers written at
    ``pos % cache_len`` (works for both full and sliding-window caches).
  * attention math accumulates in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.core.sparse_linear import (apply_sparse_linear,
                                      init_sparse_linear,
                                      merge_sparse_metas,
                                      sparse_linear_meta)
from repro.models import unroll as U
from repro.obs import jaxmon

# chunk size for q-blocked (flash-style, O(L*chunk) memory) attention
Q_CHUNK = 1024
NEG_INF = -2.0e38


# ------------------------------------------------------------------ basics
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x [..., L, H, dh]; positions [..., L] int32 (broadcastable)."""
    dh = x.shape[-1]
    freqs = _rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., L, dh/2]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ================================================================= attention
def init_attention(cfg, key, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": _init(ks[0], (d, h * hd), s, dtype),
        "wk": _init(ks[1], (d, kv * hd), s, dtype),
        "wv": _init(ks[2], (d, kv * hd), s, dtype),
        "wo": _init(ks[3], (h * hd, d), (h * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _mask_bias(q_pos, k_pos, window):
    """[..., Lq, Sk] additive mask: causal + optional sliding window +
    validity (k_pos >= 0)."""
    ok = (k_pos[..., None, :] <= q_pos[..., :, None]) & \
         (k_pos[..., None, :] >= 0)
    if window is not None:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q, k, v, bias, cap, scale):
    """q [B,Lq,H,dh] k [B,S,KV,dh] v [B,S,KV,dv] bias [B,Lq,S]
    -> [B,Lq,H,dv] (dv may differ from dh, e.g. MLA)."""
    B, Lq, H, dh = q.shape
    KV = k.shape[2]
    dv = v.shape[-1]
    rep = H // KV
    qg = q.reshape(B, Lq, KV, rep, dh)
    scores = jnp.einsum("blgrd,bsgd->bgrls", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cap)
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bgrls,bsgd->blgrd", probs, v.astype(jnp.float32))
    return ctx.reshape(B, Lq, H, dv)


def _sparse_mask(cfg, window):
    """Effective mask spec of the block-sparse attention path: the config's
    static pattern, intersected with the layer's sliding window when one is
    set (gemma-style local halves keep their window under sparse scores)."""
    mask = cfg.attn_sparsity.mask
    if window is not None:
        mask = dataclasses.replace(mask, window_cap=int(window))
    return mask


def _decode_pages(cfg, window, cache_len):
    """Static paged-decode resolution for one attention layer: the mask
    page table (host constants) when the paged KV path applies, else None
    (dense-bias decode).  ``AttnSparsitySpec.paged_decode`` gates it:
    "auto" requires a strict page saving (``max_bpr < n_pages``), "force"
    only structural feasibility, "off" disables.  Trace-safe — depends on
    static config only."""
    sparse = getattr(cfg, "attn_sparsity", None)
    if sparse is None:
        return None
    mode = getattr(sparse, "paged_decode", "auto")
    if mode == "off":
        return None
    w = sparse.block[1]
    if cache_len % w != 0:          # pages must tile the KV ring exactly
        return None
    from repro.models import attention as A
    pages, live, meta = A.decode_page_table(
        _sparse_mask(cfg, window), cache_len, sparse.block)
    if meta.max_bpr <= 0:
        return None
    if mode != "force" and meta.max_bpr >= cache_len // w:
        return None                 # no page saving: keep the dense bias
    return pages, live


@jaxmon.monitor(name="models.paged_decode")
def _paged_decode(cfg, q, kc, vc, pos, window, cap, scale, *,
                  pages, live):
    """One-token decode attention reading KV through the mask page table
    (``attention.decode_page_table``) instead of biasing the dense cache.

    Only the ``max_bpr`` pages of block-row ``pos // block_h`` are
    gathered; softmax combines them as a SEQUENTIAL per-page fold in
    ascending key order (exact running max, then denominator and context
    accumulated page by page).  A page absent from the table contributes
    exactly 0.0 to the denominator and context and never attains the max,
    and inserting exact zeros into a sequential add chain is a bitwise
    no-op — so this path is bit-for-bit equal in f32 to the same fold
    over the FULL page table (the dense-bias reference arm pinned per
    mask family in ``tests/test_serving.py``).

    Positions are taken as ``page * w + offset``: the paged path assumes
    the ring has not wrapped (``pos < cache_len``), which the serving
    scheduler enforces at admission (``len(prompt) + max_new_tokens <=
    cache_len``)."""
    from repro.models import attention as A
    spec = _sparse_mask(cfg, window)
    B, _, H, dh = q.shape
    Sc, KV = kc.shape[1], kc.shape[2]
    h, w = cfg.attn_sparsity.block
    n_pages = Sc // w
    nbr = pages.shape[0]
    row = jnp.clip(pos // h, 0, nbr - 1)
    cols = jnp.asarray(pages)[row]                        # [P]
    alive = jnp.asarray(live)[row]                        # [P]
    P = int(cols.shape[0])
    kp = kc.reshape(B, n_pages, w, KV, dh)[:, cols]       # [B,P,w,KV,dh]
    vp = vc.reshape(B, n_pages, w, KV, dh)[:, cols]
    k_pos = (cols[:, None] * w +
             jnp.arange(w, dtype=jnp.int32)[None]).reshape(-1)   # [P*w]
    qpos = jnp.reshape(pos, (1,))
    bias = (_mask_bias(qpos, k_pos, window) +
            A.decode_mask_bias(spec, qpos, k_pos))[0].reshape(P, w)
    bias = jnp.where(alive[:, None], bias, NEG_INF)
    rep = H // KV
    qg = q.reshape(B, KV, rep, dh).astype(jnp.float32)    # L == 1 squeezed
    scores = jnp.einsum("bgrd,bpwgd->bgrpw", qg,
                        kp.astype(jnp.float32)) * scale
    scores = softcap(scores, cap)
    biased = scores + bias[None, None, None]              # [B,g,r,P,w]
    m = jnp.max(biased, axis=(3, 4))                      # exact any order
    z = jnp.exp(biased - m[..., None, None])
    page_sum = z.sum(axis=4)                              # [B,g,r,P]
    partial = jnp.einsum("bgrpw,bpwgd->bgrpd", z,
                         vp.astype(jnp.float32))          # [B,g,r,P,dh]
    denom = jnp.zeros(m.shape, jnp.float32)
    ctx = jnp.zeros(m.shape + (dh,), jnp.float32)
    for p in range(P):      # static P: unrolled sequential add chains
        denom = denom + page_sum[..., p]
        ctx = ctx + partial[..., p, :]
    ctx = ctx / denom[..., None]
    return ctx.reshape(B, 1, H, dh)


def _sparse_attention(cfg, q, k, v, window, cap, scale):
    """Full-sequence attention through ``models.attention``: SDDMM scores
    on the static BCSR mask, masked block softmax, SpMM context.  Replaces
    ``_causal_attention`` when ``cfg.attn_sparsity`` is set."""
    from repro.models import attention as A
    spec = dataclasses.replace(cfg.attn_sparsity,
                               mask=_sparse_mask(cfg, window))
    rep = q.shape[2] // k.shape[2]
    if rep > 1:                     # GQA: expand KV heads for per-head ops
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return A.block_sparse_attention(q, k, v, spec, scale=scale, cap=cap)


def attention(cfg, p, x, *, window=None, cache=None, pos=None,
              rope_theta=None):
    """Returns (y, new_cache).  Modes:
      train:    cache None, pos None — full causal self-attention.
      prefill:  cache dict (zeroed, len >= L), pos = 0 — causal + cache write.
      decode:   cache dict, L == 1, pos = current position (int32 scalar).

    With ``cfg.attn_sparsity`` set, train/prefill score the static BCSR
    mask through the SDDMM/SpMM pair (``models.attention``) and decode
    applies the SAME mask spec as a positional bias — served tokens stay
    consistent with how the model trains.
    """
    B, L, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    theta = rope_theta or cfg.rope_theta
    q = _dense(x, p["wq"], p.get("bq")).reshape(B, L, h, dh)
    k = _dense(x, p["wk"], p.get("bk")).reshape(B, L, kv, dh)
    v = _dense(x, p["wv"], p.get("bv")).reshape(B, L, kv, dh)

    if cache is None or pos is None:        # training: positions 0..L-1
        positions = jnp.arange(L, dtype=jnp.int32)[None, :]
    else:
        positions = (pos + jnp.arange(L, dtype=jnp.int32))[None, :]
    q = apply_rope(q, jnp.broadcast_to(positions, (B, L)), theta)
    k = apply_rope(k, jnp.broadcast_to(positions, (B, L)), theta)

    scale = dh ** -0.5
    cap = cfg.attn_logit_softcap

    sparse = getattr(cfg, "attn_sparsity", None)
    if cache is None:
        if sparse is not None:
            ctx = _sparse_attention(cfg, q, k, v, window, cap, scale)
        else:
            ctx = _causal_attention(q, k, v, window, cap, scale)
        new_cache = None
    elif L > 1:                              # prefill into empty cache
        Sc = cache["k"].shape[1]
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k[:, -Sc:].astype(cache["k"].dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v[:, -Sc:].astype(cache["v"].dtype), (0, 0, 0, 0))
        new_cache = {"k": kc, "v": vc}
        if sparse is not None:
            ctx = _sparse_attention(cfg, q, k, v, window, cap, scale)
        else:
            ctx = _causal_attention(q, k, v, window, cap, scale)
    else:                                    # decode one token
        Sc = cache["k"].shape[1]
        slot = pos % Sc
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": kc, "v": vc}
        table = _decode_pages(cfg, window, Sc)
        if table is not None:
            # paged KV: gather only the mask row's pages (serve.paged_kv)
            ctx = _paged_decode(cfg, q, kc, vc, pos, window, cap, scale,
                                pages=table[0], live=table[1])
        else:
            j = jnp.arange(Sc, dtype=jnp.int32)
            k_pos = pos - ((pos - j) % Sc)   # ring-buffer slot positions
            bias = _mask_bias(jnp.reshape(pos, (1,)), k_pos,
                              window)        # [1, Sc]
            if sparse is not None:
                # the decode twin of the block-sparse score mask
                from repro.models import attention as A
                bias = bias + A.decode_mask_bias(
                    _sparse_mask(cfg, window), jnp.reshape(pos, (1,)),
                    k_pos)
            bias = jnp.broadcast_to(bias[None], (B, 1, Sc))
            ctx = _sdpa(q, kc, vc, bias, cap, scale)

    y = _dense(ctx.reshape(B, L, h * dh).astype(x.dtype), p["wo"])
    return y, new_cache


def _causal_attention(q, k, v, window, cap, scale):
    """Full causal attention, q-chunked above Q_CHUNK (O(L*chunk) scores
    memory — the flash-attention analogue for the 32k prefill cells)."""
    B, L, H, dh = q.shape
    pos = jnp.arange(L, dtype=jnp.int32)
    if L <= Q_CHUNK:
        bias = _mask_bias(pos, pos, window)[None]
        return _sdpa(q, k, v, jnp.broadcast_to(bias, (B, L, L)), cap, scale)

    n_chunks = L // Q_CHUNK
    assert L % Q_CHUNK == 0, (L, Q_CHUNK)

    def chunk_fn(carry, qi):
        q_chunk, q_pos = qi                     # [B, C, H, dh], [C]
        bias = _mask_bias(q_pos, pos, window)[None]
        ctx = _sdpa(q_chunk, k, v, jnp.broadcast_to(bias, (B, Q_CHUNK, L)),
                    cap, scale)
        return carry, ctx

    q_chunks = q.reshape(B, n_chunks, Q_CHUNK, H, dh).transpose(1, 0, 2, 3, 4)
    pos_chunks = pos.reshape(n_chunks, Q_CHUNK)
    _, ctxs = U.scan(chunk_fn, None, (q_chunks, pos_chunks))
    dv = ctxs.shape[-1]                      # may differ from dh (MLA)
    return ctxs.transpose(1, 0, 2, 3, 4).reshape(B, L, H, dv)


def init_attn_cache(cfg, batch, cache_len, dtype, window=None):
    Sc = min(cache_len, window) if window else cache_len
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, Sc, kv, dh), dtype),
            "v": jnp.zeros((batch, Sc, kv, dh), dtype)}


# ======================================================================= MLA
def init_mla(cfg, key, dtype):
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vd, r = (cfg.nope_head_dim, cfg.rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    p = {}
    q_dim = h * (nope + rope)
    if cfg.q_lora_rank:
        p["wq_a"] = _init(ks[0], (d, cfg.q_lora_rank), s, dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), jnp.float32)
        p["wq_b"] = _init(ks[1], (cfg.q_lora_rank, q_dim),
                          cfg.q_lora_rank ** -0.5, dtype)
    else:
        p["wq"] = _init(ks[0], (d, q_dim), s, dtype)
    p["wkv_a"] = _init(ks[2], (d, r + rope), s, dtype)
    p["kv_norm"] = jnp.zeros((r,), jnp.float32)
    p["wkv_b"] = _init(ks[3], (r, h * (nope + vd)), r ** -0.5, dtype)
    p["wo"] = _init(ks[4], (h * vd, d), (h * vd) ** -0.5, dtype)
    return p


def mla_attention(cfg, p, x, *, cache=None, pos=None):
    """Multi-head Latent Attention.  Cache holds the compressed latent
    (c_kv, k_rope) only — decode uses the absorbed-matrix form."""
    B, L, D = x.shape
    h = cfg.n_heads
    nope, rope, vd, r = (cfg.nope_head_dim, cfg.rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
    theta = cfg.rope_theta

    if cfg.q_lora_rank:
        q = _dense(rms_norm(_dense(x, p["wq_a"]), p["q_norm"]), p["wq_b"])
    else:
        q = _dense(x, p["wq"])
    q = q.reshape(B, L, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kv_a = _dense(x, p["wkv_a"])                         # [B, L, r + rope]
    c_kv = rms_norm(kv_a[..., :r], p["kv_norm"])
    k_rope = kv_a[..., r:].reshape(B, L, 1, rope)

    if cache is None or pos is None:
        positions = jnp.arange(L, dtype=jnp.int32)[None, :]
    else:
        positions = (pos + jnp.arange(L, dtype=jnp.int32))[None, :]
    positions = jnp.broadcast_to(positions, (B, L))
    q_rope = apply_rope(q_rope, positions, theta)
    k_rope = apply_rope(k_rope, positions, theta)

    scale = (nope + rope) ** -0.5
    w_kv_b = p["wkv_b"].reshape(r, h, nope + vd)
    w_uk, w_uv = w_kv_b[..., :nope], w_kv_b[..., nope:]

    if cache is not None and L == 1:
        # ---- absorbed decode: score against the latent cache directly
        Sc = cache["ckv"].shape[1]
        slot = pos % Sc
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, slot, 0))
        krope_c = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope[:, :, 0].astype(cache["krope"].dtype),
            (0, slot, 0))
        new_cache = {"ckv": ckv_c, "krope": krope_c}
        q_lat = jnp.einsum("blhn,rhn->blhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))       # [B,1,h,r]
        s_lat = jnp.einsum("blhr,bsr->bhls", q_lat,
                           ckv_c.astype(jnp.float32))
        s_rope = jnp.einsum("blhe,bse->bhls", q_rope.astype(jnp.float32),
                            krope_c.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        j = jnp.arange(Sc, dtype=jnp.int32)
        k_pos = pos - ((pos - j) % Sc)
        bias = _mask_bias(jnp.reshape(pos, (1,)), k_pos, None)   # [1, Sc]
        probs = jax.nn.softmax(scores + bias[None, None], axis=-1)
        ctx_lat = jnp.einsum("bhls,bsr->blhr", probs,
                             ckv_c.astype(jnp.float32))
        ctx = jnp.einsum("blhr,rhv->blhv", ctx_lat, w_uv.astype(jnp.float32))
    else:
        # ---- train/prefill: materialize per-head K, V — constrained to
        # heads-over-model so sequence gathers move the 576-dim latent and
        # 1/16 head slices, not the full 128-head expansion (§Perf B2)
        from repro.launch.constrain import BATCH, MODEL, constrain
        k_nope = jnp.einsum("blr,rhn->blhn", c_kv, w_uk.astype(c_kv.dtype))
        v = jnp.einsum("blr,rhv->blhv", c_kv, w_uv.astype(c_kv.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, L, h, rope))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = constrain(qq, BATCH, None, MODEL)
        k = constrain(k, BATCH, None, MODEL)
        v = constrain(v, BATCH, None, MODEL)
        ctx = _causal_attention(qq, k, v, None, None, scale)  # [B,L,h,vd]
        ctx = _checkpoint_name(ctx, "attn_ctx")
        if cache is not None:               # prefill: write latent cache
            Sc = cache["ckv"].shape[1]
            ckv_c = jax.lax.dynamic_update_slice(
                cache["ckv"], c_kv[:, -Sc:].astype(cache["ckv"].dtype),
                (0, 0, 0))
            krope_c = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope[:, -Sc:, 0].astype(
                    cache["krope"].dtype), (0, 0, 0))
            new_cache = {"ckv": ckv_c, "krope": krope_c}
        else:
            new_cache = None

    y = _dense(ctx.reshape(B, L, h * vd).astype(x.dtype), p["wo"])
    return y, new_cache


def init_mla_cache(cfg, batch, cache_len, dtype):
    return {"ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, cache_len, cfg.rope_head_dim), dtype)}


# ======================================================================= MLP
# python-int seed of the structural sparse pattern for one init_mlp call —
# THE single derivation site: init_mlp (builds params) and mlp_sparse_metas
# (re-derives static metas at apply time) must agree or the apply path
# would dispatch on stats of a structure the params don't have.
MLP_SEED_BASE = 7919


def mlp_seed(seed_hint: int) -> int:
    """Pattern seed of ``init_mlp(..., seed_hint=...)``'s gate weight (up
    uses ``+1``, down ``+2``)."""
    return MLP_SEED_BASE * (seed_hint + 1)


def init_mlp(cfg, key, dtype, d_ff=None, seed_hint: int = 0):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.ffn_sparsity is not None:
        # sparse patterns are STRUCTURAL (host-side numpy): seeded by a
        # python int per layer, not the traced jax key — this keeps
        # init_params eval_shape-able for the dry-run.  The spec's
        # ``reorder`` scheme is applied here too (block-row granularity,
        # so every layer keeps the same nnzb and the stack still scans);
        # apply_sparse_linear sees it via the row_perm/inv_perm leaves and
        # the static metas mlp() re-derives, and un-permutes outputs
        # transparently.
        seed = mlp_seed(seed_hint)
        gate, _ = init_sparse_linear(seed, d, f, cfg.ffn_sparsity, dtype)
        up, _ = init_sparse_linear(seed + 1, d, f, cfg.ffn_sparsity, dtype)
        down, _ = init_sparse_linear(seed + 2, f, d, cfg.ffn_sparsity, dtype)
        return {"gate": gate, "up": up, "down": down}
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d, f), d ** -0.5, dtype),
        "w_up": _init(ks[1], (d, f), d ** -0.5, dtype),
        "w_down": _init(ks[2], (f, d), f ** -0.5, dtype),
    }


@functools.lru_cache(maxsize=None)
def mlp_sparse_metas(spec, d: int, f: int, seed_hints: tuple):
    """TRUE structure metas of a (possibly scan-stacked) sparse MLP.

    ``seed_hints`` are the ``init_mlp`` seed hints of every layer sharing
    the traced body (one hint for an unstacked block, ``range(n_layers)``
    for the transformer's scanned stack).  Per-layer metas are re-derived
    from the deterministic pattern seeds (``sparse_linear_meta`` — real
    ``max_bpr``/padding/skew, per-shard ``ShardedMeta`` stats) and merged
    conservatively (``merge_sparse_metas``: stats take the stack max, so
    one static meta is correct for every layer the scan applies).  Gate
    and up share dims ``d -> f`` and both fold into ``meta_in``; down is
    ``f -> d`` (``meta_out``).  Returns ``(meta_in, meta_out)`` —
    hashable STATIC aux data, never pytree leaves."""
    metas_in, metas_out = [], []
    for hint in seed_hints:
        seed = mlp_seed(hint)
        metas_in.append(sparse_linear_meta(seed, d, f, spec))        # gate
        metas_in.append(sparse_linear_meta(seed + 1, d, f, spec))    # up
        metas_out.append(sparse_linear_meta(seed + 2, f, d, spec))   # down
    return merge_sparse_metas(metas_in), merge_sparse_metas(metas_out)


def mlp(cfg, p, x, d_ff=None, seed_hints=(0,)):
    """Gated MLP (dense, or block-sparse when ``cfg.ffn_sparsity`` is set
    AND ``p`` holds sparse params).

    The sparse path dispatches on the static metas of the structures
    ``init_mlp`` actually built: pass the same ``seed_hints`` the params
    were initialized with (every hint sharing this traced body — the
    layer-scan callers in ``models.transformer`` pass the whole stack's
    hints).  That is what gives the model path heterogeneous per-shard
    autotune picks and real ``row_loop`` schedule bounds instead of the
    dims-only collapse."""
    act = jax.nn.silu if cfg.mlp_act == "silu" else \
        functools.partial(jax.nn.gelu, approximate=True)
    if cfg.ffn_sparsity is not None and "gate" in p:
        d, f = cfg.d_model, d_ff or cfg.d_ff
        meta_in, meta_out = mlp_sparse_metas(cfg.ffn_sparsity, d, f,
                                             tuple(seed_hints))
        g = apply_sparse_linear(p["gate"], meta_in, x, cfg.ffn_sparsity)
        u = apply_sparse_linear(p["up"], meta_in, x, cfg.ffn_sparsity)
        return apply_sparse_linear(p["down"], meta_out, act(g) * u,
                                   cfg.ffn_sparsity)
    return _dense(act(_dense(x, p["w_gate"])) * _dense(x, p["w_up"]),
                  p["w_down"])
