"""Mixture-of-Experts FFN (DeepSeek-V2 style: shared + routed top-k).

Two dispatch implementations:

  * ``gather`` (default) — sort/scatter-based capacity dispatch computed PER
    BATCH ROW (capacity C = cf * L * k / E per row).  Token movement is
    gathers/scatters (zero matmul FLOPs); expert FFN is the only dense
    compute.  Shards cleanly: rows over `data`, experts over `model` (EP) —
    the cross-shard token exchange lowers to the all-to-all-class collective
    a real EP implementation performs.

  * ``einsum``  — the classic GShard one-hot dispatch-einsum formulation.
    Kept as a benchmark arm: its dispatch tensors/FLOPs are the well-known
    scaling trap (see EXPERIMENTS.md §Perf for the measured difference).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.launch.constrain import BATCH, MODEL, constrain
from repro.models.layers import _init, mlp


def init_moe(cfg, key, dtype):
    d, f = cfg.d_model, cfg.expert_d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": _init(ks[0], (d, e), s, jnp.float32),
        "w_gate": _init(ks[1], (e, d, f), s, dtype),
        "w_up": _init(ks[2], (e, d, f), s, dtype),
        "w_down": _init(ks[3], (e, f, d), f ** -0.5, dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init(kss[0], (d, fs), s, dtype),
            "w_up": _init(kss[1], (d, fs), s, dtype),
            "w_down": _init(kss[2], (fs, d), fs ** -0.5, dtype),
        }
    return p


def _route(cfg, p, xt):
    """xt [..., T, D] -> (gate_vals, gate_idx, aux)."""
    e, k = cfg.n_experts, cfg.moe_top_k
    logits = jnp.einsum("...td,de->...te", xt.astype(jnp.float32),
                        p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    # Switch-style load-balance loss
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    onehot_mean = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / gate_idx.size)
    aux = e * jnp.sum(me * onehot_mean)
    return gate_vals, gate_idx, aux


def _expert_mlp(cfg, p, xin):
    """xin [..., E, C, D] -> [..., E, C, D]"""
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("...ecd,edf->...ecf", xin, p["w_gate"])) * \
        jnp.einsum("...ecd,edf->...ecf", xin, p["w_up"])
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])


# ------------------------------------------------------------ gather dispatch
def _dispatch_row(e_flat):
    """Per-row slot assignment.  e_flat [Lk] = expert of each (token,slot);
    returns pos [Lk]: position within that expert's queue (stable order)."""
    Lk = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    r = jnp.arange(Lk, dtype=jnp.int32)
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = (r - first).astype(jnp.int32)
    return jnp.zeros((Lk,), jnp.int32).at[order].set(pos_sorted)


def _moe_gather(cfg, p, x):
    """x [B, L, D]; per-row capacity; gather/scatter token movement."""
    B, L, D = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    C = int(cfg.capacity_factor * L * k / e) + 1

    gate_vals, gate_idx, aux = _route(cfg, p, x)          # [B,L,k]
    e_flat = gate_idx.reshape(B, L * k)
    pos = jax.vmap(_dispatch_row)(e_flat)                 # [B, Lk]
    keep = pos < C
    tok = jnp.tile(jnp.arange(L, dtype=jnp.int32)[:, None],
                   (1, k)).reshape(L * k)

    # scatter token index / gate weight into the [E, C] slot tables; dropped
    # entries get an out-of-bounds expert id, discarded by mode="drop"
    gates_flat = gate_vals.reshape(B, L * k)

    def scatter_row(ef, ps, kp, gv):
        ii = (jnp.where(kp, ef, e), jnp.where(kp, ps, 0))
        st = jnp.full((e, C), L, jnp.int32).at[ii].set(tok, mode="drop")
        sw = jnp.zeros((e, C), jnp.float32).at[ii].set(gv, mode="drop")
        return st, sw
    slot_tok, slot_w = jax.vmap(scatter_row)(e_flat, pos, keep, gates_flat)

    x_pad = jnp.concatenate(
        [x, jnp.zeros((B, 1, D), x.dtype)], axis=1)       # pad row L -> zeros
    xin = jax.vmap(lambda xp, st: xp[st])(x_pad, slot_tok)  # [B, E, C, D]
    xin = constrain(xin, BATCH, MODEL)                     # rows x EP

    eout = _expert_mlp(cfg, p, xin)                        # [B, E, C, D]
    eout = constrain(eout, BATCH, MODEL)
    eout = _checkpoint_name(eout, "moe_eout")

    # combine: scatter-add each slot's gate-weighted output back to its
    # token (expert-sharded partial sums -> one psum of [B, L, D] — §Perf B1;
    # the gather-based combine all-gathered the full [B, E, C, D] instead)
    contrib = eout * slot_w[..., None].astype(eout.dtype)  # [B, E, C, D]

    def combine_row(st, cb):
        y = jnp.zeros((L + 1, D), cb.dtype)
        return y.at[st.reshape(e * C)].add(cb.reshape(e * C, D))
    y = jax.vmap(combine_row)(slot_tok, contrib)[:, :L]
    y = constrain(y, BATCH)
    return y.astype(x.dtype), aux


# ------------------------------------------------------------ einsum dispatch
def _moe_einsum(cfg, p, x):
    """GShard one-hot dispatch (benchmark arm)."""
    B, L, D = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    C = int(cfg.capacity_factor * L * k / e) + 1
    gate_vals, gate_idx, aux = _route(cfg, p, x)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # [B,L,k,E]
    flat = onehot.reshape(B, L * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(B, L, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                      # [B,L,k]
    keep = pos < C
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * \
        keep[..., None].astype(jnp.float32)
    dispatch = jnp.einsum("blke,blkc->blec", onehot, pos_oh)
    combine = jnp.einsum("blke,blkc,blk->blec", onehot, pos_oh,
                         gate_vals.astype(jnp.float32))
    xin = jnp.einsum("blec,bld->becd", dispatch.astype(x.dtype), x)
    eout = _expert_mlp(cfg, p, xin)
    y = jnp.einsum("blec,becd->bld", combine.astype(x.dtype), eout)
    return y, aux


def moe_ffn(cfg, p, x, dispatch: str = "gather"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, L, D] -> (y [B, L, D], aux_loss scalar)."""
    if dispatch == "gather":
        y, aux = _moe_gather(cfg, p, x)
    else:
        y, aux = _moe_einsum(cfg, p, x)
    if cfg.n_shared_experts:
        Bt, L, D = x.shape
        fs = cfg.expert_d_ff * cfg.n_shared_experts
        # shared experts are initialized DENSE (init_moe above) regardless
        # of cfg.ffn_sparsity; mlp() dispatches on the params' structure,
        # so this stays the dense einsum path even for sparse-FFN archs
        y = y + mlp(cfg, p["shared"], x, d_ff=fs)
    return y, aux
