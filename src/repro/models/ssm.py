"""Mamba2 block — SSD (state-space duality) algorithm, arXiv:2405.21060.

Chunked linear-time training/prefill path (quadratic only within a chunk)
and O(1)-state decode path.  Layout per block:

  in_proj: x -> [z (d_inner), xBC (d_inner + 2*G*N), dt (H)]
  depthwise causal conv (width 4) over xBC, silu
  split xBC -> x_ssm [H, P], B [G, N], C [G, N]
  SSD recurrence with per-head decay a = exp(dt * A)  (A < 0)
  y = gated_rms_norm(y, z) -> out_proj
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense, _init, rms_norm
from repro.models import unroll as U


def init_ssd(cfg, key, dtype):
    d, di = cfg.d_model, cfg.d_inner
    g, n, hh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv_width
    d_xbc = di + 2 * g * n
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "w_in": _init(ks[0], (d, di + d_xbc + hh), s, dtype),
        "conv_w": _init(ks[1], (cw, d_xbc), cw ** -0.5, dtype),
        "conv_b": jnp.zeros((d_xbc,), dtype),
        "A_log": jnp.zeros((hh,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((hh,), jnp.float32),
        "dt_bias": jnp.zeros((hh,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "w_out": _init(ks[2], (di, d), di ** -0.5, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B, L, C], w [cw, C] -> [B, L, C]."""
    cw = w.shape[0]
    x_pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(x_pad[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return out + b


def _segsum(l):
    """log-decay cumulative segment sums: l [..., T] ->
    S[..., i, j] = sum_{k=j+1..i} l_k (i >= j), -inf above diagonal."""
    T = l.shape[-1]
    cs = jnp.cumsum(l, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j+1..i}
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD: x [b,L,H,P], dt [b,L,H] (post-softplus), A [H] (negative),
    B,C [b,L,G,N], D [H].  Returns (y [b,L,H,P], final_state [b,H,P,N])."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    # broadcast groups to heads
    Bh = jnp.repeat(B, rep, axis=2)                      # [b,L,H,N]
    Ch = jnp.repeat(C, rep, axis=2)

    xr = x.reshape(b, nc, chunk, H, P)
    dtr = dt.reshape(b, nc, chunk, H)
    Br = Bh.reshape(b, nc, chunk, H, N)
    Cr = Ch.reshape(b, nc, chunk, H, N)

    l = dtr * A                                           # [b,nc,c,H] log-decay
    l_t = l.transpose(0, 1, 3, 2)                         # [b,nc,H,c]
    seg = jnp.exp(_segsum(l_t))                           # [b,nc,H,c,c]

    xdt = xr * dtr[..., None]                             # weight inputs by dt

    # ---- intra-chunk (quadratic within chunk)
    scores = jnp.einsum("bzihn,bzjhn->bzhij", Cr.astype(jnp.float32),
                        Br.astype(jnp.float32))
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", scores * seg,
                        xdt.astype(jnp.float32))

    # ---- chunk-final states
    cum = jnp.cumsum(l_t, axis=-1)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)           # [b,nc,H,c]
    states = jnp.einsum("bzjhn,bzhj,bzjhp->bzhpn", Br.astype(jnp.float32),
                        decay_to_end, xdt.astype(jnp.float32))

    # ---- inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(cum[..., -1])                   # [b,nc,H]

    def step(h_prev, inp):
        st, dec = inp                                     # [b,H,P,N], [b,H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, H, P, N), jnp.float32)
    h_final, h_prevs = U.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)            # [b,nc,H,P,N]

    # ---- inter-chunk contribution
    decay_from_start = jnp.exp(cum)                       # [b,nc,H,c]
    y_off = jnp.einsum("bzihn,bzhi,bzhpn->bzihp", Cr.astype(jnp.float32),
                       decay_from_start, h_prevs)

    y = (y_diag + y_off).reshape(b, L, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, h_final


def ssd_decode_step(x, dt, A, B, C, D, state):
    """One-token recurrence: x [b,H,P], dt [b,H], B,C [b,G,N],
    state [b,H,P,N] -> (y [b,H,P], new_state)."""
    b, H, P = x.shape
    G, N = B.shape[1], B.shape[2]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)   # [b,H,N]
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A)                               # [b,H]
    upd = jnp.einsum("bhp,bhn->bhpn", (x * dt[..., None]).astype(jnp.float32),
                     Bh)
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y, new_state


def ssd_block(cfg, p, x, *, cache=None, pos=None):
    """Full Mamba2 mixer.  cache = {"conv": [B, cw-1, d_xbc],
    "state": [B, H, P, N]} for decode; None for train; for prefill the
    returned cache holds the final state."""
    Bt, L, D = x.shape
    di = cfg.d_inner
    g, n, hh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    cw = cfg.ssm_conv_width
    d_xbc = di + 2 * g * n

    zxbcdt = _dense(x, p["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + d_xbc]
    dt_raw = zxbcdt[..., di + d_xbc:]
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cache is not None and L == 1:
        # ---- decode: roll the conv window, single recurrence step
        conv_st = cache["conv"]                           # [B, cw-1, d_xbc]
        window = jnp.concatenate([conv_st, xbc], axis=1)  # [B, cw, d_xbc]
        conv_out = jnp.einsum("btc,tc->bc", window, p["conv_w"]) + p["conv_b"]
        xbc_c = jax.nn.silu(conv_out)[:, None]            # [B, 1, d_xbc]
        new_conv = window[:, 1:]
        x_ssm = xbc_c[..., :di].reshape(Bt, hh, P)
        Bm = xbc_c[..., di:di + g * n].reshape(Bt, g, n)
        Cm = xbc_c[..., di + g * n:].reshape(Bt, g, n)
        y, new_state = ssd_decode_step(x_ssm, dt[:, 0], A, Bm, Cm,
                                       p["D"], cache["state"])
        y = y.reshape(Bt, 1, di)
        new_cache = {"conv": new_conv, "state": new_state}
    else:
        xbc_c = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
        x_ssm = xbc_c[..., :di].reshape(Bt, L, hh, P)
        Bm = xbc_c[..., di:di + g * n].reshape(Bt, L, g, n)
        Cm = xbc_c[..., di + g * n:].reshape(Bt, L, g, n)
        y, final_state = ssd_scan(x_ssm, dt, A, Bm, Cm, p["D"],
                                  min(cfg.ssm_chunk, L))
        y = y.reshape(Bt, L, di)
        if cache is not None:                             # prefill
            new_conv = jnp.swapaxes(
                jax.lax.dynamic_slice_in_dim(
                    jnp.swapaxes(xbc, 1, 2), L - (cw - 1), cw - 1, axis=2),
                1, 2)
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                         "state": final_state}
        else:
            new_cache = None

    # gated RMS norm then out-projection
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"])
    return _dense(y, p["w_out"]), new_cache


def init_ssd_cache(cfg, batch, dtype):
    g, n = cfg.ssm_groups, cfg.ssm_state
    d_xbc = cfg.d_inner + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_xbc), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                           jnp.float32),
    }
