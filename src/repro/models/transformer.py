"""Model assembly: embeddings, scanned layer stacks (homogeneous segments
keep the HLO small at 512 devices), caches, and the train/prefill/decode
entry points.

Layouts:
  attn_mlp    — standard decoder (dense archs, pixtral/musicgen backbones,
                smat_ffn with block-sparse FFN)
  gemma_pair  — (local SWA + global) pair scanned n_layers/2 times, softcaps
  mla_moe     — DeepSeek MLA attention + shared/routed MoE FFN
  ssd         — Mamba2 (attention-free)
  zamba       — units of (unit_len x mamba2) + ONE shared attention block
                (params reused across units) + mamba tail
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import unroll as U


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ================================================================ block defs
def _init_block(cfg: ModelConfig, key, dtype, seed_hint: int = 0):
    """One repeating unit of the layer stack."""
    if cfg.layout == "attn_mlp":
        k1, k2 = jax.random.split(key)
        return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": L.init_attention(cfg, k1, dtype),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "mlp": L.init_mlp(cfg, k2, dtype,
                                  seed_hint=seed_hint)}
    if cfg.layout == "gemma_pair":
        ks = jax.random.split(key, 4)
        def half(ka, kb):
            return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                    "ln1_post": jnp.zeros((cfg.d_model,), jnp.float32),
                    "attn": L.init_attention(cfg, ka, dtype),
                    "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                    "ln2_post": jnp.zeros((cfg.d_model,), jnp.float32),
                    "mlp": L.init_mlp(cfg, kb, dtype)}
        return {"local": half(ks[0], ks[1]), "global": half(ks[2], ks[3])}
    if cfg.layout == "mla_moe":
        k1, k2 = jax.random.split(key)
        return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "mla": L.init_mla(cfg, k1, dtype),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "moe": M.init_moe(cfg, k2, dtype)}
    if cfg.layout == "ssd":
        return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "ssd": S.init_ssd(cfg, key, dtype)}
    raise ValueError(cfg.layout)


def _mlp_seed_hints(cfg: ModelConfig):
    """``init_mlp`` seed hints of every sparse-FFN layer sharing the scanned
    block body — the static aux data ``L.mlp`` re-derives its structure
    metas from.  ``attn_mlp`` stacks init with ``seed_hint=i`` (see
    ``init_params``); every other layout inits its mlps with the default
    hint 0."""
    if cfg.layout == "attn_mlp":
        return tuple(range(_n_repeats(cfg)))
    return (0,)


def _apply_block(cfg: ModelConfig, p, x, cache, pos):
    """Returns (x, new_cache, aux)."""
    from repro.launch.constrain import BATCH, MODEL, constrain
    if x.shape[1] > 1:
        # sequence-parallel carry (Megatron-SP): norms/FFN run L-sharded;
        # GSPMD gathers L only where attention needs the full sequence.
        x = constrain(x, BATCH, MODEL)
    aux = jnp.zeros((), jnp.float32)
    if cfg.layout == "attn_mlp":
        a, c = L.attention(cfg, p["attn"], L.rms_norm(x, p["ln1"]),
                           window=cfg.sliding_window, cache=cache, pos=pos)
        x = x + a
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"]),
                      seed_hints=_mlp_seed_hints(cfg))
        return x, c, aux
    if cfg.layout == "gemma_pair":
        caches = cache or {"local": None, "global": None}
        new_c = {}
        for kind, window in (("local", cfg.sliding_window), ("global", None)):
            h = p[kind]
            a, c = L.attention(cfg, h["attn"], L.rms_norm(x, h["ln1"]),
                               window=window, cache=caches[kind], pos=pos)
            x = x + L.rms_norm(a, h["ln1_post"])
            m = L.mlp(cfg, h["mlp"], L.rms_norm(x, h["ln2"]),
                      seed_hints=_mlp_seed_hints(cfg))
            x = x + L.rms_norm(m, h["ln2_post"])
            new_c[kind] = c
        return x, (new_c if cache is not None else None), aux
    if cfg.layout == "mla_moe":
        a, c = L.mla_attention(cfg, p["mla"], L.rms_norm(x, p["ln1"]),
                               cache=cache, pos=pos)
        x = x + a
        y, aux = M.moe_ffn(cfg, p["moe"], L.rms_norm(x, p["ln2"]),
                           dispatch=cfg.moe_dispatch)
        x = x + y
        return x, c, aux
    if cfg.layout == "ssd":
        y, c = S.ssd_block(cfg, p["ssd"], L.rms_norm(x, p["ln"]),
                           cache=cache, pos=pos)
        return x + y, c, aux
    raise ValueError(cfg.layout)


def _block_cache(cfg: ModelConfig, batch, cache_len, dtype):
    if cfg.layout == "attn_mlp":
        return L.init_attn_cache(cfg, batch, cache_len, dtype,
                                 window=cfg.sliding_window)
    if cfg.layout == "gemma_pair":
        return {"local": L.init_attn_cache(cfg, batch, cache_len, dtype,
                                           window=cfg.sliding_window),
                "global": L.init_attn_cache(cfg, batch, cache_len, dtype)}
    if cfg.layout == "mla_moe":
        return L.init_mla_cache(cfg, batch, cache_len, dtype)
    if cfg.layout == "ssd":
        return S.init_ssd_cache(cfg, batch, dtype)
    raise ValueError(cfg.layout)


def _n_repeats(cfg: ModelConfig) -> int:
    if cfg.layout == "gemma_pair":
        return cfg.n_layers // 2
    return cfg.n_layers


# ============================================================= params (full)
def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    key = jax.random.PRNGKey(seed)
    d = cfg.d_model
    k_embed, k_head, k_blocks, k_shared = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if cfg.input_mode == "codebooks":
        params["embed"] = (jax.random.normal(
            k_embed, (cfg.n_codebooks, cfg.vocab_size, d)) * 0.02
        ).astype(dtype)
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.n_codebooks, d, cfg.vocab_size)) * d ** -0.5
        ).astype(dtype)
    else:
        params["embed"] = (jax.random.normal(
            k_embed, (cfg.vocab_size, d)) * 0.02).astype(dtype)
        params["lm_head"] = (jax.random.normal(
            k_head, (d, cfg.vocab_size)) * d ** -0.5).astype(dtype)

    if cfg.layout == "zamba":
        n_mamba = cfg.hybrid_unit_len * cfg.hybrid_n_units
        mamba_cfgs = jax.random.split(k_blocks, n_mamba + cfg.hybrid_tail)
        ssd_cfg = cfg
        unit = []
        for u in range(cfg.hybrid_n_units):
            sub = [{"ln": jnp.zeros((d,), jnp.float32),
                    "ssd": S.init_ssd(ssd_cfg, mamba_cfgs[u * cfg.hybrid_unit_len + i], dtype)}
                   for i in range(cfg.hybrid_unit_len)]
            unit.append(_stack(sub))
        params["units"] = _stack(unit)             # [n_units, unit_len, ...]
        tail = [{"ln": jnp.zeros((d,), jnp.float32),
                 "ssd": S.init_ssd(ssd_cfg, mamba_cfgs[n_mamba + i], dtype)}
                for i in range(cfg.hybrid_tail)]
        params["tail"] = _stack(tail)
        k1, k2 = jax.random.split(k_shared)
        params["shared_attn"] = {
            "ln1": jnp.zeros((d,), jnp.float32),
            "attn": L.init_attention(cfg, k1, dtype),
            "ln2": jnp.zeros((d,), jnp.float32),
            "mlp": L.init_mlp(cfg, k2, dtype)}
    else:
        n = _n_repeats(cfg)
        keys = jax.random.split(k_blocks, n)
        params["blocks"] = _stack(
            [_init_block(cfg, keys[i], dtype, seed_hint=i)
             for i in range(n)])
    return params


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(functools.partial(init_params, cfg))


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Stacked decode caches for the whole network."""
    dtype = _dtype(cfg)
    if cfg.layout == "zamba":
        unit_c = [_stack([S.init_ssd_cache(cfg, batch, dtype)
                          for _ in range(cfg.hybrid_unit_len)])
                  for _ in range(cfg.hybrid_n_units)]
        return {
            "units_ssd": _stack(unit_c),
            "units_attn": _stack([L.init_attn_cache(cfg, batch, cache_len,
                                                    dtype)
                                  for _ in range(cfg.hybrid_n_units)]),
            "tail_ssd": _stack([S.init_ssd_cache(cfg, batch, dtype)
                                for _ in range(cfg.hybrid_tail)]),
        }
    n = _n_repeats(cfg)
    return _stack([_block_cache(cfg, batch, cache_len, dtype)
                   for _ in range(n)])


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, cache_len))


# ================================================================== forward
def _embed(cfg: ModelConfig, params, batch_in) -> jnp.ndarray:
    tokens = batch_in["tokens"]
    if cfg.input_mode == "codebooks":
        # tokens [B, L, n_cb] — sum the codebook embeddings
        x = sum(params["embed"][c][tokens[..., c]]
                for c in range(cfg.n_codebooks))
    else:
        x = params["embed"][tokens]                         # [B, L, D]
    if cfg.layout == "gemma_pair":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.input_mode == "tokens+patches" and "patch_embeds" in batch_in:
        pe = batch_in["patch_embeds"].astype(x.dtype)       # [B, P, D]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _head(cfg: ModelConfig, params, x) -> jnp.ndarray:
    x = L.rms_norm(x, params["final_norm"])
    if cfg.input_mode == "codebooks":
        logits = jnp.einsum("bld,cdv->blcv", x, params["lm_head"])
    else:
        logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return L.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def _scan_stack(cfg, stacked, x, caches, pos, remat: str):
    """Scan blocks over the leading stack axis; caches ride as xs/ys."""
    fn = functools.partial(_apply_block, cfg)
    if remat == "full":
        fn = jax.checkpoint(fn)
    elif remat == "dots":
        fn = jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat == "names":
        # save exactly the tensors whose recomputation is collective-heavy
        # (attention context; gathered expert outputs) — §Perf B3
        fn = jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_ctx", "moe_eout"))

    if caches is None:
        def body(carry, p):
            x, aux = carry
            x2, _, a = fn(p, x, None, None)
            return (x2, aux + a), None
        (x, aux), _ = U.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
        return x, None, aux

    def body(carry, xs):
        x, aux = carry
        p, c = xs
        x2, c2, a = fn(p, x, c, pos)
        return (x2, aux + a), c2
    (x, aux), new_caches = U.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked, caches))
    return x, new_caches, aux


def _zamba_forward(cfg, params, x, caches, pos, remat):
    shared = params["shared_attn"]
    aux0 = jnp.zeros((), jnp.float32)

    def unit_body(carry, xs):
        x, aux = carry
        if caches is None:
            p_unit = xs
            c_ssd = None
            c_attn = None
        else:
            p_unit, c_ssd, c_attn = xs

        # inner scan over the unit's mamba layers
        def inner(carry2, xs2):
            x2 = carry2
            if c_ssd is None:
                p2 = xs2
                y, _, _ = _apply_block(_ssd_view(cfg), p2, x2, None, None)
                return y, None
            p2, cc = xs2
            y, cc2, _ = _apply_block(_ssd_view(cfg), p2, x2, cc, pos)
            return y, cc2

        if c_ssd is None:
            x, _ = U.scan(inner, x, p_unit)
            new_c_ssd = None
        else:
            x, new_c_ssd = U.scan(inner, x, (p_unit, c_ssd))

        # shared attention block (params closed over — reused every unit)
        a, new_c_attn = L.attention(cfg, shared["attn"],
                                    L.rms_norm(x, shared["ln1"]),
                                    cache=c_attn, pos=pos)
        x = x + a
        x = x + L.mlp(cfg, shared["mlp"], L.rms_norm(x, shared["ln2"]))
        if caches is None:
            return (x, aux), None
        return (x, aux), (new_c_ssd, new_c_attn)

    if caches is None:
        (x, aux), _ = U.scan(unit_body, (x, aux0), params["units"])
        x, _, _ = _scan_stack(_ssd_view(cfg), params["tail"], x, None, pos,
                              remat)
        return x, None, aux
    (x, aux), (u_ssd, u_attn) = U.scan(
        unit_body, (x, aux0),
        (params["units"], caches["units_ssd"], caches["units_attn"]))
    x, tail_c, _ = _scan_stack(_ssd_view(cfg), params["tail"], x,
                               caches["tail_ssd"], pos, remat)
    new_caches = {"units_ssd": u_ssd, "units_attn": u_attn,
                  "tail_ssd": tail_c}
    return x, new_caches, aux


@functools.lru_cache(maxsize=None)
def _ssd_view_cached(cfg):
    import dataclasses
    return dataclasses.replace(cfg, layout="ssd")


def _ssd_view(cfg):
    return _ssd_view_cached(cfg)


def forward(cfg: ModelConfig, params, batch_in, *, cache=None, pos=None,
            remat: str = "none") -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (logits, new_cache, aux_loss)."""
    from repro.launch.constrain import BATCH, constrain
    x = constrain(_embed(cfg, params, batch_in), BATCH)
    if cfg.layout == "zamba":
        x, new_cache, aux = _zamba_forward(cfg, params, x, cache, pos, remat)
    else:
        x, new_cache, aux = _scan_stack(cfg, params["blocks"], x, cache, pos,
                                        remat)
    return _head(cfg, params, x), new_cache, aux


# ================================================================ entry points
def lm_loss(cfg: ModelConfig, logits, labels) -> jnp.ndarray:
    """Next-token CE.  labels already shifted; -100 = ignore."""
    valid = (labels >= 0)
    lab = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, lab[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


def train_loss(cfg: ModelConfig, params, batch_in, remat: str = "full"):
    logits, _, aux = forward(cfg, params, batch_in, remat=remat)
    if cfg.input_mode == "tokens+patches":
        # loss over text positions only (patches are prompt context)
        logits = logits[:, cfg.patch_tokens:]
    loss = lm_loss(cfg, logits, batch_in["labels"])
    return loss + 0.01 * aux, {"lm_loss": loss, "aux_loss": aux}


def prefill(cfg: ModelConfig, params, batch_in, cache_len: int):
    """Build decode caches from a prompt.  Returns (logits, cache)."""
    B = batch_in["tokens"].shape[0]
    cache = init_cache(cfg, B, cache_len)
    logits, new_cache, _ = forward(cfg, params, batch_in, cache=cache,
                                   pos=jnp.zeros((), jnp.int32))
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step: tokens [B] (or [B, n_cb]), pos scalar int32.
    Returns (logits [B, V], new_cache)."""
    batch_in = {"tokens": tokens[:, None] if tokens.ndim == 1
                else tokens[:, None, :]}
    logits, new_cache, _ = forward(cfg, params, batch_in, cache=cache,
                                   pos=pos)
    return logits[:, 0], new_cache
