"""Global scan-unroll switch.

XLA's ``cost_analysis`` counts a ``while`` body ONCE regardless of trip
count, so scanned layer stacks undercount FLOPs/bytes/collectives.  The
dry-run's cost-extrapolation mode sets ``unroll_scans()`` and compiles small
unrolled variants (1-2 repeats) to fit an affine cost model in the repeat
count; the full scanned compile is still used for the memory/sharding proof.
"""
from __future__ import annotations

import contextlib

import jax

_UNROLL = False


def unrolled() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unroll_scans():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan(body, carry, xs, length=None):
    """jax.lax.scan, or a python loop when unroll mode is active."""
    if not _UNROLL:
        return jax.lax.scan(body, carry, xs, length=length)
    if xs is None:
        n = length
        get = lambda i: None
    else:
        n = jax.tree.leaves(xs)[0].shape[0]
        get = lambda i: jax.tree.map(lambda a: a[i], xs)
    ys = []
    for i in range(n):
        carry, y = body(carry, get(i))
        ys.append(y)
    if not ys or all(y is None for y in ys):
        return carry, None
    import jax.numpy as jnp
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
