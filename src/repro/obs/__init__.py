"""Whole-stack observability (PR 10): structured tracing, a metrics
registry, and a retrace sentinel.

Host-side and deterministic-friendly by construction:

* ``repro.obs.trace``   — hierarchical spans + events, ring buffer,
  ``REPRO_TRACE=0/1/<jsonl-path>`` gating (zero-cost when off);
* ``repro.obs.metrics`` — process-wide counters/gauges/histograms with
  labeled series, ``snapshot()``/``reset()``, and the shared benchmark
  ``timeit`` loop;
* ``repro.obs.export``  — JSONL / Perfetto ``trace_event`` / summary-tree
  views with deterministic payloads split from report-only wall clock;
* ``repro.obs.jaxmon``  — retrace sentinel (``monitor`` +
  ``assert_max_traces``) turning "never retraces" comments into CI gates.

The obs core never imports jax (``jaxmon``/``timeit`` import it lazily),
so pure-host modules like ``serve.scheduler`` can emit events freely.
Lint R7 (``analysis.lint_rules``) keeps every ``repro.obs`` call out of
custom_vjp/Pallas-traced code — ``jaxmon`` excepted, trace-aware by
design.
"""
from repro.obs import export, jaxmon, metrics, trace
from repro.obs.metrics import counter, gauge, histogram, snapshot, timeit
from repro.obs.trace import capture, enabled, event, span, spanned

__all__ = [
    "trace", "metrics", "export", "jaxmon",
    "span", "spanned", "event", "capture", "enabled",
    "counter", "gauge", "histogram", "snapshot", "timeit",
]
