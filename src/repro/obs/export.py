"""Exporters over the ``repro.obs.trace`` event stream.

Three views of the same records:

* **JSONL** — one ``Event.to_dict()`` per line (the on-disk format the
  ``REPRO_TRACE=<path>`` sink streams); :func:`read_jsonl` round-trips
  it back into :class:`~repro.obs.trace.Event` objects bit-for-bit.
* **Perfetto / Chrome** — ``trace_event`` JSON (``{"traceEvents": [...]}``
  with ``ph`` B/E/i records) loadable in ``ui.perfetto.dev`` or
  ``chrome://tracing``.
* **Summary tree** — plain-text aggregation by span path (call counts,
  total wall time, instant-event tallies): the
  ``python -m repro.obs.summary`` CLI.

The DETERMINISTIC/WALL-CLOCK split is enforced here:
:func:`deterministic_events` strips ``ts_us``/``dur_us`` (and optionally
the ``seq``/``span``/``parent`` ids, which are stable only over a whole
stream, not a filtered slice), so benchmark gates diff payloads that are
pure functions of program behavior.  :func:`checksum` condenses that
view into one pin-able string.

>>> from repro.obs import trace
>>> with trace.capture() as cap:
...     with trace.span("phase", k=1):
...         _ = trace.event("item", i=7)
>>> deterministic_events(cap.events, fields=("kind", "name", "args"))
[{'kind': 'B', 'name': 'phase', 'args': {'k': 1}}, \
{'kind': 'I', 'name': 'item', 'args': {'i': 7}}, \
{'kind': 'E', 'name': 'phase', 'args': None}]
>>> pf = to_perfetto(cap.events)
>>> [e["ph"] for e in pf["traceEvents"]]
['B', 'i', 'E']
"""
from __future__ import annotations

import hashlib
import json
from typing import Iterable, List, Optional, Sequence

from repro.obs.trace import Event

_DET_FIELDS = ("kind", "name", "seq", "span", "parent", "args")


def deterministic_events(events: Iterable[Event],
                         prefix: Optional[str] = None,
                         fields: Sequence[str] = _DET_FIELDS
                         ) -> List[dict]:
    """Gate-safe payload list, in stream order.

    ``prefix`` keeps only events whose name starts with it (e.g.
    ``"serve."``).  For FILTERED streams pass
    ``fields=("kind", "name", "args")``: ``seq``/``span``/``parent``
    number the full stream, so unrelated events (a first-trace autotune
    pick, say) would shift them even though the filtered slice itself is
    unchanged."""
    bad = set(fields) - set(_DET_FIELDS)
    if bad:
        raise ValueError(f"non-deterministic or unknown fields {sorted(bad)}"
                         f"; pick from {_DET_FIELDS}")
    out = []
    for e in events:
        if prefix is not None and not e.name.startswith(prefix):
            continue
        d = e.deterministic()
        out.append({f: d[f] for f in fields})
    return out


def checksum(payloads: List[dict]) -> str:
    """Stable hex digest of a deterministic-payload list — one string a
    benchmark baseline can pin instead of the whole stream."""
    blob = json.dumps(payloads, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


# ---------------------------------------------------------------- JSONL
def to_jsonl(events: Iterable[Event], path: str) -> None:
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e.to_dict(), sort_keys=True) + "\n")


def read_jsonl(path: str) -> List[Event]:
    """Round-trip a JSONL log (sink file or :func:`to_jsonl` output)
    back into :class:`Event` objects."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(Event(d["kind"], d["name"], d["seq"],
                             d.get("span"), d.get("parent"), d.get("args"),
                             d.get("ts_us"), d.get("dur_us")))
    return out


# ------------------------------------------------------------- Perfetto
def to_perfetto(events: Iterable[Event], pid: int = 1,
                tid: int = 1) -> dict:
    """Chrome/Perfetto ``trace_event`` JSON.  ``B``/``E`` map directly;
    instant events become ``ph="i"`` thread-scoped marks.  Events from a
    deterministic-only source (no ``ts_us``) fall back to their ``seq``
    as a synthetic timeline."""
    recs = []
    for e in events:
        ts = e.ts_us if e.ts_us is not None else float(e.seq)
        rec = {"name": e.name, "ph": e.kind if e.kind in ("B", "E") else "i",
               "ts": ts, "pid": pid, "tid": tid}
        if rec["ph"] == "i":
            rec["s"] = "t"
        if e.args:
            rec["args"] = e.args
        recs.append(rec)
    return {"traceEvents": recs, "displayTimeUnit": "ms"}


def write_perfetto(events: Iterable[Event], path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_perfetto(events), f, indent=1, sort_keys=True)


# --------------------------------------------------------- summary tree
class _Node:
    __slots__ = ("name", "calls", "events", "dur_us", "children")

    def __init__(self, name):
        self.name = name
        self.calls = 0       # span begins ("B")
        self.events = 0      # instant events ("I")
        self.dur_us = 0.0    # summed span durations ("E".dur_us)
        self.children = {}

    def child(self, name):
        c = self.children.get(name)
        if c is None:
            c = self.children[name] = _Node(name)
        return c


def _aggregate(events: Iterable[Event]) -> _Node:
    root = _Node("")
    path = [root]
    for e in events:
        if e.kind == "B":
            node = path[-1].child(e.name)
            node.calls += 1
            path.append(node)
        elif e.kind == "E":
            # tolerate unbalanced streams (ring-buffer overflow dropped
            # the matching B): only pop when the top matches
            if len(path) > 1 and path[-1].name == e.name:
                if e.dur_us is not None:
                    path[-1].dur_us += e.dur_us
                path.pop()
        else:
            path[-1].child(e.name).events += 1
    return root


def _fmt_us(us: float) -> str:
    return f"{us / 1e3:.1f}ms" if us >= 1e3 else f"{us:.0f}us"


def summary_tree(events: Iterable[Event]) -> str:
    """Plain-text span tree aggregated by name path: call counts,
    summed wall time (report-only), and instant-event tallies."""
    events = list(events)
    root = _aggregate(events)
    n_spans = sum(1 for e in events if e.kind == "B")
    n_inst = sum(1 for e in events if e.kind == "I")
    lines = [f"trace summary: {len(events)} records "
             f"({n_spans} spans, {n_inst} events)"]

    def render(node, indent):
        kids = list(node.children.values())
        for i, c in enumerate(kids):
            tee = "└─ " if i == len(kids) - 1 else "├─ "
            cont = "   " if i == len(kids) - 1 else "│  "
            if c.calls:
                dur = f", {_fmt_us(c.dur_us)}" if c.dur_us else ""
                extra = f" (+{c.events} events)" if c.events else ""
                lines.append(f"{indent}{tee}{c.name} x{c.calls}{dur}{extra}")
            else:
                lines.append(f"{indent}{tee}[event] {c.name} x{c.events}")
            render(c, indent + cont)

    render(root, "")
    return "\n".join(lines)
