"""Retrace sentinel: make static-shape promises CI-enforced facts.

The serving engine, the paged decode gather, and the sharded SpMM all
promise "never retraces" in comments; this module turns that into an
assertion.  :func:`monitor` wraps a function that jit (or grad / vmap /
scan) will trace; the wrapper bumps a named :class:`Sentinel` ONLY when
called under an active jax trace (``jax.core.trace_state_clean()`` is
False) — i.e. exactly once per (re)trace per call site, and never on
eager calls or jit cache hits.  ``assert_max_traces(target, n)`` then
raises :class:`RetraceError` when the count exceeds the budget.

Wrap the function BEFORE handing it to ``jax.jit`` (the engine does this
for ``_masked_step``), or decorate a function that is called from inside
traced code (``models.layers._paged_decode``,
``launch.dist_spmm.spmm_sharded``) — for the latter, the count is "times
the body was traced", so a function inlined L times per program counts L
per trace; budget accordingly.

This module is the one ``repro.obs`` member that is trace-time-safe by
design (it only reads trace state and mutates host counters), so lint R7
(``obs-host-only``) exempts it.

>>> import jax, jax.numpy as jnp
>>> @monitor(name="doc.f")
... def f(x):
...     return x * 2
>>> g = jax.jit(f)
>>> _ = g(jnp.ones((4,))); _ = g(jnp.ones((4,)))   # one trace, one hit
>>> trace_count("doc.f")
1
>>> _ = g(jnp.ones((8,)))                          # new shape: retrace
>>> assert_max_traces("doc.f", 1)   # doctest: +IGNORE_EXCEPTION_DETAIL
Traceback (most recent call last):
    ...
RetraceError: doc.f: traced 2 times, budget 1
>>> reset("doc.f")
"""
from __future__ import annotations

import functools
import threading
from typing import Dict, Optional

from repro.obs import trace as _trace


class RetraceError(AssertionError):
    """A monitored entry point traced more often than its budget."""


class Sentinel:
    __slots__ = ("name", "count", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self._lock = threading.Lock()

    def bump(self) -> int:
        with self._lock:
            self.count += 1
            return self.count

    def reset(self) -> None:
        with self._lock:
            self.count = 0

    def __repr__(self):
        return f"Sentinel({self.name!r}, count={self.count})"


_REGISTRY: Dict[str, Sentinel] = {}
_LOCK = threading.Lock()


def _trace_active() -> bool:
    try:
        import jax
        return not jax.core.trace_state_clean()
    except ImportError:
        return False


def monitor(fn=None, *, name: Optional[str] = None):
    """Decorator/wrapper installing a retrace sentinel on ``fn``.

    Registers the sentinel process-wide under ``name`` (default: the
    function's qualname; latest registration wins — each ``ServeEngine``
    re-registers ``serve.masked_step`` for its own closure).  The
    sentinel is also reachable as ``wrapped.sentinel``."""
    if fn is None:
        return functools.partial(monitor, name=name)
    s = Sentinel(name or getattr(fn, "__qualname__", repr(fn)))
    with _LOCK:
        _REGISTRY[s.name] = s

    @functools.wraps(fn)
    def wrapper(*a, **kw):
        if _trace_active():
            n = s.bump()
            _trace.event("jax.trace", fn=s.name, n=n)
        return fn(*a, **kw)

    wrapper.sentinel = s
    return wrapper


def _resolve(target) -> Sentinel:
    if isinstance(target, Sentinel):
        return target
    if isinstance(target, str):
        s = _REGISTRY.get(target)
        if s is None:
            known = sorted(_REGISTRY)
            raise KeyError(f"no retrace sentinel named {target!r}; "
                           f"registered: {known}")
        return s
    s = getattr(target, "sentinel", None)
    if isinstance(s, Sentinel):
        return s
    raise TypeError(f"expected a sentinel name, a monitored function, or "
                    f"a Sentinel; got {target!r}")


def trace_count(target) -> int:
    """How many times the monitored body has been traced so far."""
    return _resolve(target).count


def assert_max_traces(target, n: int) -> None:
    """Raise :class:`RetraceError` when ``target`` traced more than ``n``
    times — the CI gate for static-shape promises."""
    s = _resolve(target)
    if s.count > n:
        raise RetraceError(
            f"{s.name}: traced {s.count} times, budget {n} — a "
            "static-shape promise broke (shape/dtype-polymorphic inputs "
            "reached a jitted entry point)")


def reset(target=None) -> None:
    """Zero one sentinel, or every registered sentinel (test isolation)."""
    if target is not None:
        _resolve(target).reset()
        return
    with _LOCK:
        for s in _REGISTRY.values():
            s.reset()


def sentinels() -> Dict[str, int]:
    """Snapshot ``{name: trace_count}`` of every registered sentinel."""
    with _LOCK:
        return {name: s.count for name, s in sorted(_REGISTRY.items())}
