"""Process-wide metrics registry: counters, gauges, histograms with
labeled series, snapshot + reset semantics.

Unlike ``repro.obs.trace`` (ring buffer, gated by ``REPRO_TRACE``), the
registry is always live — a metric update is one dict lookup and one
arithmetic op, cheap enough to leave on unconditionally.  Series are
keyed by ``name`` plus sorted ``label=value`` pairs, so
``counter("ops.dispatch", op="spmm")`` and
``counter("ops.dispatch", op="sddmm")`` are independent.

``snapshot()`` renders everything into plain JSON types (safe to dump);
``reset()`` forgets every series — tests and benchmark harnesses call it
between runs so accumulation windows are explicit.

>>> reset()
>>> counter("demo.hits", op="spmm").inc()
>>> counter("demo.hits", op="spmm").inc(2)
>>> gauge("demo.level").set(0.5)
>>> snap = snapshot()
>>> snap["counters"]["demo.hits{op=spmm}"]
3
>>> snap["gauges"]["demo.level"]
0.5
>>> reset(); snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
True
"""
from __future__ import annotations

import statistics
import threading
import time
from typing import Dict, Optional, Tuple

from repro.obs import trace as _trace

# default histogram bucket upper bounds (values <= bound); one catch-all
# "inf" bucket is always appended
_DEFAULT_BOUNDS = (1, 2, 5, 10, 20, 50, 100, 200, 500, 1000)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    __slots__ = ("bounds", "buckets", "count", "sum", "min", "max")

    def __init__(self, bounds: Tuple[float, ...] = _DEFAULT_BOUNDS):
        self.bounds = tuple(bounds)
        self.buckets = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    def snapshot(self) -> dict:
        keys = [f"le_{b}" for b in self.bounds] + ["inf"]
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "buckets": dict(zip(keys, self.buckets))}


def _series_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """One metrics namespace; the module-level default is process-wide."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name, labels, cls, *args):
        key = _series_key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(*args)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {key!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Tuple[float, ...]] = None,
                  **labels) -> Histogram:
        return self._get(name, labels, Histogram,
                         *(() if bounds is None else (tuple(bounds),)))

    def snapshot(self) -> dict:
        with self._lock:
            out = {"counters": {}, "gauges": {}, "histograms": {}}
            for key, m in sorted(self._metrics.items()):
                if isinstance(m, Counter):
                    out["counters"][key] = m.value
                elif isinstance(m, Gauge):
                    out["gauges"][key] = m.value
                else:
                    out["histograms"][key] = m.snapshot()
            return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_REGISTRY = Registry()


def registry() -> Registry:
    return _REGISTRY


def counter(name: str, **labels) -> Counter:
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, bounds: Optional[Tuple[float, ...]] = None,
              **labels) -> Histogram:
    return _REGISTRY.histogram(name, bounds, **labels)


def snapshot() -> dict:
    return _REGISTRY.snapshot()


def reset() -> None:
    _REGISTRY.reset()


# --------------------------------------------------------------- timing
def timeit(fn, *args, warmup: int = 1, iters: int = 5,
           reduce: str = "median", name: Optional[str] = None,
           **kwargs) -> float:
    """Wall-clock seconds of ``fn(*args, **kwargs)`` — THE benchmark
    timing loop (PR 10 satellite: the per-file copies in
    ``benchmarks/bench_*.py`` delegate here).

    ``warmup`` calls run first (compilation etc.), then ``iters`` timed
    calls reduce by ``"median"`` or ``"min"``.  Results are blocked via
    ``jax.block_until_ready`` when jax is importable, so async dispatch
    cannot fake a fast run.  The measurement is REPORT-ONLY wall clock:
    when ``name`` is given it lands in the ``obs`` stream as a timed
    event's ``dur_us`` and in the ``bench.<name>`` histogram —
    never in a deterministic field.
    """
    if reduce not in ("median", "min"):
        raise ValueError(f"reduce must be 'median' or 'min', got {reduce!r}")
    try:
        import jax
        block = jax.block_until_ready
    except ImportError:                      # obs stays importable sans jax
        def block(x):
            return x
    for _ in range(max(int(warmup), 0)):
        block(fn(*args, **kwargs))
    ts = []
    for _ in range(max(int(iters), 1)):
        t0 = time.perf_counter()
        block(fn(*args, **kwargs))
        ts.append(time.perf_counter() - t0)
    sec = float(min(ts) if reduce == "min" else statistics.median(ts))
    if name is not None:
        _REGISTRY.histogram(f"bench.{name}").observe(sec * 1e6)
        _trace.timed_event(f"bench.{name}", sec * 1e6,
                           iters=len(ts), reduce=reduce)
    return sec
