"""CLI: render a JSONL trace as a span-tree summary.

    REPRO_TRACE=trace.jsonl python examples/quickstart.py
    python -m repro.obs.summary trace.jsonl
    python -m repro.obs.summary trace.jsonl --perfetto trace_perfetto.json
"""
from __future__ import annotations

import argparse
import sys

from repro.obs import export


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.summary", description=__doc__)
    ap.add_argument("trace", help="JSONL trace (REPRO_TRACE sink or "
                                  "export.to_jsonl output)")
    ap.add_argument("--perfetto", default=None, metavar="OUT.json",
                    help="also write Chrome/Perfetto trace_event JSON")
    args = ap.parse_args(argv)
    events = export.read_jsonl(args.trace)
    print(export.summary_tree(events))
    if args.perfetto:
        export.write_perfetto(events, args.perfetto)
        print(f"wrote {args.perfetto}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
