"""Structured tracing: hierarchical spans + instant events in a
thread-safe ring buffer.

Enablement comes from ``REPRO_TRACE`` at import (or :func:`configure` /
:class:`capture` later):

* unset / ``""`` / ``"0"`` — disabled.  ``event()`` returns immediately
  and ``span()`` hands back one shared no-op object, so instrumented hot
  paths never allocate inside this module;
* ``"1"`` — enabled, in-memory ring buffer only;
* anything else — treated as a JSONL path: every record is appended to
  the file as it is emitted (and kept in the ring buffer).

Every record is an :class:`Event` with a DETERMINISTIC payload — ``kind``
(``"B"`` span begin / ``"E"`` span end / ``"I"`` instant), ``name``,
``seq`` (emission order), ``span`` / ``parent`` (span ids = the begin
event's seq), and ``args`` — plus REPORT-ONLY wall-clock fields
(``ts_us``, ``dur_us``).  Exporters (``repro.obs.export``) keep the two
groups separate so benchmark gating stays falsifiable: a CI diff may pin
the deterministic view bit-for-bit while timings remain informational.

Span names are dot-scoped ``<layer>.<what>`` (``prepare.reorder``,
``autotune.tune``, ``serve.step``, ``bench.serving`` — see
docs/ARCHITECTURE.md "Observability").

>>> with capture() as cap:
...     with span("outer", n=2):
...         _ = event("tick", i=0)
>>> [(e.kind, e.name) for e in cap.events]
[('B', 'outer'), ('I', 'tick'), ('E', 'outer')]
>>> cap.events[1].deterministic() == {'kind': 'I', 'name': 'tick',
...     'seq': 1, 'span': None, 'parent': 0, 'args': {'i': 0}}
True
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from collections import deque
from typing import Iterable, List, Optional

_DEFAULT_CAP = 65536
_SELF = object()          # sentinel: "span id = this event's own seq"


def _jsonify(v):
    """Coerce an args value into plain JSON types, so the deterministic
    payload is serializable and stable across in-memory / JSONL views
    (tuples -> lists, numpy scalars -> python scalars)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonify(x) for k, x in v.items()}
    item = getattr(v, "item", None)     # numpy scalars / 0-d arrays
    if callable(item):
        try:
            return _jsonify(item())
        except (TypeError, ValueError):
            pass
    tolist = getattr(v, "tolist", None)  # numpy arrays
    if callable(tolist):
        return _jsonify(tolist())
    return repr(v)


class Event:
    """One trace record; see the module docstring for the field contract."""
    __slots__ = ("kind", "name", "seq", "span", "parent", "args",
                 "ts_us", "dur_us")

    def __init__(self, kind, name, seq, span=None, parent=None, args=None,
                 ts_us=None, dur_us=None):
        self.kind = kind
        self.name = name
        self.seq = seq
        self.span = span
        self.parent = parent
        self.args = args
        self.ts_us = ts_us
        self.dur_us = dur_us

    def deterministic(self) -> dict:
        """The gate-safe payload: no wall-clock fields."""
        return {"kind": self.kind, "name": self.name, "seq": self.seq,
                "span": self.span, "parent": self.parent,
                "args": self.args}

    def to_dict(self) -> dict:
        d = self.deterministic()
        if self.ts_us is not None:
            d["ts_us"] = self.ts_us
        if self.dur_us is not None:
            d["dur_us"] = self.dur_us
        return d

    def __repr__(self):
        return (f"Event({self.kind!r}, {self.name!r}, seq={self.seq}, "
                f"args={self.args!r})")


class _TraceState:
    """One live buffer (+ optional JSONL sink).  All mutation is under
    ``lock`` so concurrent emitters interleave at record granularity."""

    def __init__(self, path: Optional[str] = None,
                 cap: int = _DEFAULT_CAP):
        self.events: deque = deque(maxlen=cap)
        self.lock = threading.Lock()
        self.path = path
        self._sink = None
        self._seq = 0
        self.t0 = time.perf_counter()

    def emit(self, kind, name, span, parent, args, ts_us, dur_us=None):
        with self.lock:
            seq = self._seq
            self._seq += 1
            if span is _SELF:
                span = seq
            ev = Event(kind, name, seq, span, parent, args, ts_us, dur_us)
            self.events.append(ev)
            if self.path is not None:
                if self._sink is None:
                    self._sink = open(self.path, "a")
                self._sink.write(
                    json.dumps(ev.to_dict(), sort_keys=True) + "\n")
                self._sink.flush()
            return ev

    def close(self):
        if self._sink is not None:
            self._sink.close()
            self._sink = None


_state: Optional[_TraceState] = None
_tls = threading.local()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def enabled() -> bool:
    """True when a trace buffer is installed (env or capture())."""
    return _state is not None


def _now_us(state: _TraceState) -> float:
    return round((time.perf_counter() - state.t0) * 1e6, 3)


def event(name: str, **args) -> Optional[Event]:
    """Emit one instant event under the current span (no-op when
    tracing is disabled)."""
    st = _state
    if st is None:
        return None
    stack = _stack()
    parent = stack[-1] if stack else None
    return st.emit("I", name, None, parent,
                   {k: _jsonify(v) for k, v in args.items()} or None,
                   _now_us(st))


def timed_event(name: str, dur_us: float, **args) -> Optional[Event]:
    """An instant event carrying a report-only duration (``obs.timeit``
    uses this: the measurement rides in the wall-clock field, never in
    the deterministic args)."""
    st = _state
    if st is None:
        return None
    stack = _stack()
    parent = stack[-1] if stack else None
    return st.emit("I", name, None, parent,
                   {k: _jsonify(v) for k, v in args.items()} or None,
                   _now_us(st), round(float(dur_us), 3))


class _NullSpan:
    """Shared no-op returned by ``span()`` while tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "args", "_id", "_t0")

    def __init__(self, name, args):
        self.name = name
        self.args = args
        self._id = None
        self._t0 = 0.0

    def __enter__(self):
        st = _state
        if st is None:           # disabled between construction and entry
            self._id = None
            return self
        stack = _stack()
        parent = stack[-1] if stack else None
        ev = st.emit("B", self.name, _SELF, parent,
                     {k: _jsonify(v) for k, v in self.args.items()} or None,
                     _now_us(st))
        self._id = ev.seq
        stack.append(ev.seq)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self._id is None:
            return False
        dur = round((time.perf_counter() - self._t0) * 1e6, 3)
        stack = _stack()
        if stack and stack[-1] == self._id:
            stack.pop()
        parent = stack[-1] if stack else None
        st = _state
        if st is not None:
            st.emit("E", self.name, self._id, parent, None,
                    _now_us(st), dur)
        self._id = None
        return False


def span(name: str, **args):
    """Context manager opening a hierarchical span.  Zero-cost while
    disabled: the same shared no-op object comes back every call."""
    if _state is None:
        return _NULL_SPAN
    return _Span(name, args)


def spanned(name: Optional[str] = None, **static_args):
    """Decorator form of :func:`span`; enablement is re-checked per call,
    so functions decorated at import time still trace under a later
    ``capture()``."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if _state is None:
                return fn(*a, **kw)
            with _Span(label, dict(static_args)):
                return fn(*a, **kw)
        return wrapper
    return deco


def get_events() -> List[Event]:
    """Snapshot of the current ring buffer (empty list when disabled)."""
    st = _state
    if st is None:
        return []
    with st.lock:
        return list(st.events)


class capture:
    """Install a fresh in-memory trace buffer for the ``with`` block —
    regardless of ``REPRO_TRACE`` — and restore the previous state after.
    The span stack is saved/cleared on entry so captured streams are
    self-contained.  ``cap.events`` snapshots the buffer (valid after
    exit too)."""

    def __init__(self, path: Optional[str] = None, cap: int = _DEFAULT_CAP):
        self._path = path
        self._cap = cap
        self._buf = None
        self._saved = None
        self._saved_stack = None

    def __enter__(self):
        global _state
        self._saved = _state
        self._saved_stack = list(_stack())
        _stack().clear()
        _state = _TraceState(path=self._path, cap=self._cap)
        self._buf = _state
        return self

    def __exit__(self, *exc):
        global _state
        self._buf.close()
        _state = self._saved
        _stack()[:] = self._saved_stack
        return False

    @property
    def events(self) -> List[Event]:
        with self._buf.lock:
            return list(self._buf.events)


def configure(mode: Optional[str], cap: Optional[int] = None) -> None:
    """(Re)install the process trace state from a ``REPRO_TRACE``-style
    value: ``None``/``""``/``"0"`` disable, ``"1"`` memory-only, anything
    else is a JSONL sink path."""
    global _state
    if _state is not None:
        _state.close()
    cap = cap or int(os.environ.get("REPRO_TRACE_CAP", _DEFAULT_CAP))
    if mode is None or mode in ("", "0"):
        _state = None
    elif mode == "1":
        _state = _TraceState(cap=cap)
    else:
        _state = _TraceState(path=mode, cap=cap)


def deterministic_payloads(events: Iterable[Event]) -> List[dict]:
    """Convenience passthrough to the exporter's gate-safe view."""
    return [e.deterministic() for e in events]


configure(os.environ.get("REPRO_TRACE"))
