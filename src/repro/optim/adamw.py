"""Sharded AdamW with global-norm clipping and cosine schedule.

Pure pytree ops — optimizer state inherits the parameter shardings, so under
GSPMD the update is fully sharded (ZeRO-style when params carry a `data`-axis
sharding).  Integer/bool leaves (BCSR index arrays of the sparse layers) ride
through untouched; their gradients are float0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: (jnp.zeros(p.shape, jnp.float32) if _is_float(p)
                       else jnp.zeros((), jnp.float32))
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(grads) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads) if _is_float(g)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.where(
        cfg.clip_norm is None, 1.0,
        jnp.minimum(1.0, (cfg.clip_norm or 1.0) / (gnorm + 1e-9)))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        if not _is_float(p):
            return p, m, v
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh, vh = m2 / bc1, v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decoupled weight decay (matrices)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta.astype(jnp.float32)).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
