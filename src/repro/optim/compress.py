"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

At 1000+ nodes the gradient all-reduce over DCI (cross-pod) is the scaling
bottleneck; 4x compression (f32 -> int8 with per-tensor scale) plus error
feedback (residual carried into the next step) is the standard remedy.
Implemented with ``shard_map`` + ``jax.lax.psum`` so the quantized tensor is
what actually crosses the interconnect.

Used by ``train/loop.py`` when ``--grad-compression int8`` is set; the
default GSPMD path keeps exact all-reduce.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """psum of int8-quantized values (scales reduced exactly).  Inside
    shard_map only."""
    q, scale = quantize_int8(x)
    # every shard contributes q*scale; sum_i q_i*s_i = psum over widened ints
    part = q.astype(jnp.float32) * scale
    # the wire format is int8 + one f32: emulate by psumming the int payload
    # (XLA has no typed-compression collective; the int8 cast above bounds
    # the information that crosses the link, which is what we model)
    return jax.lax.psum(part, axis_name)


def ef_compress_grads(grads, residual, axis_name="data"):
    """Error-feedback compression step for one pytree of local grads.

    g_hat = Q(g + r);  r' = (g + r) - g_hat;  return psum(g_hat), r'
    """
    def one(g, r):
        if not jnp.issubdtype(g.dtype, jnp.inexact):
            return g, r
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        g_hat = dequantize_int8(q, scale)
        new_r = g32 - g_hat
        return jax.lax.psum(g_hat, axis_name), new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in out]), td.unflatten(
        [o[1] for o in out])


def init_residual(params):
    return jax.tree.map(
        lambda p: (jnp.zeros(p.shape, jnp.float32)
                   if jnp.issubdtype(p.dtype, jnp.inexact)
                   else jnp.zeros((), jnp.float32)), params)
