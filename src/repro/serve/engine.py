"""Continuous-batching serving engine.

PR 8 redesign: the engine is now a thin executor around two policy
objects —

* ``serve.scheduler.Scheduler`` makes every admit/feed/evict decision on
  the host (FIFO admission into free slots, prefill/decode interleave by
  position grouping, prefix-cache reuse, deterministic trace events);
* ``serve.paged_kv.PagedKVCache`` accounts for the paged block-sparse KV
  view (the decode gather itself rides inside the jitted step via
  ``models.layers._paged_decode`` whenever ``cfg.attn_sparsity`` allows).

Public surface::

    engine = ServeEngine(cfg, params, n_slots=4, cache_len=256)
    for rid, token in engine.generate(requests):   # streaming results
        ...
    events = engine.step()       # or explicit stepping (trace-driven
                                 # benchmarks): [(rid, token)] per step

The legacy fixed-slot surface (``submit()`` + ``run()``) remains as thin
deprecation shims and will be removed after the next release; both now
emit ``DeprecationWarning`` and delegate to the scheduler, producing
token-for-token identical streams (pinned in
``tests/test_serving.py``).

Every decode step is the SAME jitted ``_masked_step`` regardless of how
many slots are active or at which positions — slot masks keep shapes
static, so the scheduler never causes a retrace.  That includes sharded
sparse FFNs: ``cfg.ffn_sparsity`` may carry ``shards="auto"`` /
``shard_chunks`` — the shard count resolves statically from the layer
dims (same leaf shapes every trace) and the overlap-chunked SpMM is
bit-identical to the unchunked one, so the pinned token streams in
``tests/test_serving.py`` are unaffected.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import jaxmon
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.scheduler import Scheduler, SchedulerConfig

# decode-cache batch-axis position by leaf name (same layout conventions as
# launch.sharding.cache_shardings):
#   attn k/v [..., B, S, KV, dh]; mla ckv/krope [..., B, S, r];
#   ssd conv [..., B, cw-1, d] / state [..., B, H, P, N]
_CACHE_BATCH_AXIS = {"k": -4, "v": -4, "ckv": -3, "krope": -3,
                     "conv": -3, "state": -4}

# leaves indexed by position along their second-to-batch axis — the ones a
# cross-slot prefix copy is exact for.  ssd conv/state summarize history
# (the state after the LAST token, not per position), so prefix reuse is
# disabled for layouts that carry them.
_POSITION_INDEXED = ("k", "v", "ckv", "krope")


def _merge_cache(old, new, slot_mask):
    """Keep ``new`` cache entries only for slots in ``slot_mask`` [B] bool.

    A batched ``decode_step`` writes KV at the step's ``pos`` for EVERY
    batch row — including pad tokens of slots that are mid-sequence at a
    different position.  Without this merge, each per-group decode in
    ``ServeEngine.step`` overwrites the other slots' already-written
    cache entries with pad-token KV."""
    def merge(path, o, n):
        name = getattr(path[-1], "key", getattr(path[-1], "name", None))
        ax = _CACHE_BATCH_AXIS.get(name)
        if ax is None:
            # fail loudly: an unmerged leaf would silently reintroduce the
            # cross-slot corruption for whatever layer type added it
            raise KeyError(
                f"unknown decode-cache leaf {name!r} at {path}: add its "
                "batch axis to serve.engine._CACHE_BATCH_AXIS")
        shape = [1] * n.ndim
        shape[ax] = slot_mask.shape[0]
        return jnp.where(slot_mask.reshape(shape), n, o)
    return jax.tree_util.tree_map_with_path(merge, old, new)


def _copy_slot(cache, src: int, dst: int):
    """Copy slot ``src``'s cache rows over slot ``dst`` on every leaf —
    the prefix-cache transfer.  Rows are batch-independent, so the copied
    prefix KV is bitwise identical to recomputing it; positions past the
    shared prefix are overwritten by the admitted request's own prefill
    or masked causally (``k_pos <= pos``)."""
    def cp(path, leaf):
        name = getattr(path[-1], "key", getattr(path[-1], "name", None))
        ax = _CACHE_BATCH_AXIS[name] % leaf.ndim
        row = jnp.take(leaf, jnp.asarray([src]), axis=ax)
        starts = [0] * leaf.ndim
        starts[ax] = dst
        return jax.lax.dynamic_update_slice(leaf, row, tuple(starts))
    return jax.tree_util.tree_map_with_path(cp, cache)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [Lp] (or [Lp, n_cb])
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[list] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 cache_len: int = 256, mesh=None, seed: int = 0,
                 spmm_mesh=None, prefix_cache: bool = True,
                 placement=None):
        """``spmm_mesh``: optional dedicated mesh for the partitioned
        sparse-FFN path (``SparsitySpec(shards=...)``).  When set, decode
        traces run under ``dist_spmm.use_spmm_mesh`` so every sparse layer
        executes as a shard_map over it; when None, sharded layers fall
        back to the in-process equivalent (identical math).

        Sparse layers dispatch on the static structure metas the model
        path re-derives per trace (``models.layers.mlp_sparse_metas`` —
        real per-shard stats), so decode gets the same heterogeneous
        per-shard kernel picks as the raw ``dist_spmm`` API; warm the
        autotune cache across processes with ``REPRO_AUTOTUNE_CACHE``.

        With ``cfg.attn_sparsity`` set (block-sparse attention), decode
        applies the SAME static mask spec the train/prefill path scores —
        through the paged-KV gather (``AttnSparsitySpec.paged_decode``,
        bitwise-equal to the dense-bias fold) or as a positional bias —
        so served tokens match the block-sparse train math;
        ``self.paged_kv`` carries the placement accounting
        (``serve.paged_kv.PagedKVCache``).

        ``prefix_cache`` enables cross-slot KV reuse for shared prompt
        prefixes; it is forced off for layouts whose cache leaves are not
        position-indexed (ssd/zamba conv+state summarize history)."""
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.key = jax.random.PRNGKey(seed)
        self.spmm_mesh = spmm_mesh

        def _masked_step(p, c, t, pos, slot_mask):
            logits, new_c = T.decode_step(cfg, p, c, t, pos)
            # donation is safe: the merge reads the pre-step cache values
            # inside the same traced computation
            return logits, _merge_cache(c, new_c, slot_mask)

        # retrace sentinel BEFORE jit: the wrapper body runs exactly once
        # per trace, so "slot masks keep shapes static — never retraces"
        # is an assertable count (CI: tests/test_obs.py)
        _monitored = jaxmon.monitor(_masked_step, name="serve.masked_step")
        self.step_sentinel = _monitored.sentinel
        _decode_jit = jax.jit(_monitored, donate_argnums=(1,))

        def _decode(*args):
            if self.spmm_mesh is None:
                return _decode_jit(*args)
            # the mesh is read at trace time; the first call after setting
            # it bakes it into the jitted program (later calls hit the
            # cache untouched — change the mesh BEFORE the first step)
            from repro.launch import dist_spmm  # local: layering
            with dist_spmm.use_spmm_mesh(self.spmm_mesh):
                return _decode_jit(*args)

        self._decode = _decode
        self.cache = T.init_cache(cfg, n_slots, cache_len)
        leaf_names = {getattr(p[-1], "key", getattr(p[-1], "name", None))
                      for p, _ in jax.tree_util.tree_flatten_with_path(
                          self.cache)[0]}
        prefix_ok = leaf_names <= set(_POSITION_INDEXED)
        self.scheduler = Scheduler(SchedulerConfig(
            n_slots=n_slots, cache_len=cache_len,
            prefix_cache=bool(prefix_cache) and prefix_ok))
        self.paged_kv = None
        if getattr(cfg, "attn_sparsity", None) is not None and \
                cfg.layout in ("attn_mlp", "gemma_pair"):
            from repro.serve.paged_kv import PagedKVCache
            self.paged_kv = PagedKVCache(cfg, cache_len, n_slots,
                                         placement=placement)
        self.done: Dict[int, Request] = {}

    # ---------------------------------------------------------------- admin
    def enqueue(self, req: Request) -> None:
        """Queue a request; it is admitted to a slot by the next step."""
        req.out_tokens = []
        self.scheduler.enqueue(req)

    def submit(self, req: Request) -> None:
        """Deprecated: use ``enqueue`` (or just ``generate``).  Will be
        removed after the continuous-batching API stabilizes."""
        warnings.warn("ServeEngine.submit() is deprecated; use "
                      "enqueue()/generate()", DeprecationWarning,
                      stacklevel=2)
        self.enqueue(req)

    def _slot_tokens(self, entries) -> jnp.ndarray:
        """Batch token vector with each entry's token in its slot and pad
        elsewhere.  Pad rows produce garbage logits (ignored) and their
        cache writes are discarded by the slot mask in ``_decode``."""
        if self.cfg.input_mode == "codebooks":
            arr = np.zeros((self.n_slots, self.cfg.n_codebooks), np.int32)
        else:
            arr = np.zeros((self.n_slots,), np.int32)
        for slot, token, _ in entries:
            arr[slot] = token
        return jnp.asarray(arr)

    # ----------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, object]]:
        """Admit pending requests, run one decode step for every active
        slot (one batched dispatch per position group), and return the
        tokens sampled this step as ``[(rid, token)]``."""
        with obs_trace.span("serve.step", step=self.scheduler.step_idx):
            produced = self._step_inner()
        obs_metrics.counter("serve.steps").inc()
        obs_metrics.counter("serve.tokens").inc(len(produced))
        return produced

    def _step_inner(self) -> List[Tuple[int, object]]:
        for adm in self.scheduler.admit():
            if adm["reuse"] > 0 and adm["src"] != adm["slot"]:
                self.cache = _copy_slot(self.cache, adm["src"], adm["slot"])
        produced: List[Tuple[int, object]] = []
        for pos, entries in self.scheduler.plan():
            toks = self._slot_tokens(entries)
            mask = np.zeros(self.n_slots, bool)
            for slot, _, _ in entries:
                mask[slot] = True
            logits, self.cache = self._decode(
                self.params, self.cache, toks,
                jnp.asarray(pos, jnp.int32), jnp.asarray(mask))
            need = [e for e in entries if e[2]]
            if need:
                logits = np.asarray(logits, np.float32)
            for slot, token, _ in entries:
                self.scheduler.advance(slot, token)
            for slot, _, _ in need:
                req = self.scheduler.slots[slot].req
                lg = logits[slot]
                if req.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    tok = np.asarray(jax.random.categorical(
                        sub, jnp.asarray(lg) / req.temperature, axis=-1))
                else:
                    tok = lg.argmax(axis=-1)
                tok = int(tok) if np.ndim(tok) == 0 else tok.astype(np.int32)
                if self.scheduler.record_output(slot, tok):
                    self.done[req.rid] = req
                produced.append((req.rid, tok))
        self.scheduler.step_idx += 1
        return produced

    # ------------------------------------------------------------- generate
    def generate(self, requests, max_steps: int = 100_000
                 ) -> Iterator[Tuple[int, object]]:
        """Stream ``(request_id, token)`` pairs as decoding produces them.

        Enqueues ``requests`` and steps the engine until every queued
        request completes — later requests are admitted continuously as
        slots free up, so the iterator interleaves results across
        requests in deterministic (position-group, slot) order."""
        for req in requests:
            self.enqueue(req)
        steps = 0
        while self.scheduler.has_work() and steps < max_steps:
            yield from self.step()
            steps += 1

    # ------------------------------------------------------------------ run
    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Deprecated: drain the queue and return ``{rid: Request}`` —
        use ``generate`` (streaming) or explicit ``step()`` instead.
        Will be removed after the continuous-batching API stabilizes."""
        warnings.warn("ServeEngine.run() is deprecated; use "
                      "generate()/step()", DeprecationWarning, stacklevel=2)
        steps = 0
        while self.scheduler.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return self.done
