"""Batched serving engine: prefill + decode with slot-based continuous
batching.

A fixed decode batch of ``n_slots`` sequences; finished/empty slots are
refilled from the request queue and the KV cache slices for that slot are
reset (cache layout puts batch on a leading-after-stack axis, so per-slot
reset is a masked write).  Sampling: greedy or temperature.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

# decode-cache batch-axis position by leaf name (same layout conventions as
# launch.sharding.cache_shardings):
#   attn k/v [..., B, S, KV, dh]; mla ckv/krope [..., B, S, r];
#   ssd conv [..., B, cw-1, d] / state [..., B, H, P, N]
_CACHE_BATCH_AXIS = {"k": -4, "v": -4, "ckv": -3, "krope": -3,
                     "conv": -3, "state": -4}


def _merge_cache(old, new, slot_mask):
    """Keep ``new`` cache entries only for slots in ``slot_mask`` [B] bool.

    A batched ``decode_step`` writes KV at the step's ``pos`` for EVERY
    batch row — including pad tokens of slots that are mid-sequence at a
    different position.  Without this merge, each per-group decode in
    ``ServeEngine.step`` (and each prompt token in ``_admit``) overwrites
    the other slots' already-written cache entries with pad-token KV."""
    def merge(path, o, n):
        name = getattr(path[-1], "key", getattr(path[-1], "name", None))
        ax = _CACHE_BATCH_AXIS.get(name)
        if ax is None:
            # fail loudly: an unmerged leaf would silently reintroduce the
            # cross-slot corruption for whatever layer type added it
            raise KeyError(
                f"unknown decode-cache leaf {name!r} at {path}: add its "
                "batch axis to serve.engine._CACHE_BATCH_AXIS")
        shape = [1] * n.ndim
        shape[ax] = slot_mask.shape[0]
        return jnp.where(slot_mask.reshape(shape), n, o)
    return jax.tree_util.tree_map_with_path(merge, old, new)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [Lp] (or [Lp, n_cb])
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[list] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 cache_len: int = 256, mesh=None, seed: int = 0,
                 spmm_mesh=None):
        """``spmm_mesh``: optional dedicated mesh for the partitioned
        sparse-FFN path (``SparsitySpec(shards=...)``).  When set, decode
        traces run under ``dist_spmm.use_spmm_mesh`` so every sparse layer
        executes as a shard_map over it; when None, sharded layers fall
        back to the in-process equivalent (identical math).

        Sparse layers dispatch on the static structure metas the model
        path re-derives per trace (``models.layers.mlp_sparse_metas`` —
        real per-shard stats), so decode gets the same heterogeneous
        per-shard kernel picks as the raw ``dist_spmm`` API; warm the
        autotune cache across processes with ``REPRO_AUTOTUNE_CACHE``.

        With ``cfg.attn_sparsity`` set (block-sparse attention), decode
        steps apply the SAME static mask spec as a positional bias, so
        served tokens match the block-sparse train/prefill math —
        ``tests/test_sddmm_attention.py`` pins engine-level equality
        against a dense-attention engine for the causal mask."""
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.key = jax.random.PRNGKey(seed)
        self.spmm_mesh = spmm_mesh

        def _masked_step(p, c, t, pos, slot_mask):
            logits, new_c = T.decode_step(cfg, p, c, t, pos)
            # donation is safe: the merge reads the pre-step cache values
            # inside the same traced computation
            return logits, _merge_cache(c, new_c, slot_mask)

        _decode_jit = jax.jit(_masked_step, donate_argnums=(1,))

        def _decode(*args):
            if self.spmm_mesh is None:
                return _decode_jit(*args)
            # the mesh is read at trace time; the first call after setting
            # it bakes it into the jitted program (later calls hit the
            # cache untouched — change the mesh BEFORE the first step)
            from repro.launch import dist_spmm  # local: layering
            with dist_spmm.use_spmm_mesh(self.spmm_mesh):
                return _decode_jit(*args)

        self._decode = _decode
        self.cache = T.init_cache(cfg, n_slots, cache_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}

    # ---------------------------------------------------------------- admin
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill-by-decode: feed all prompt tokens EXCEPT the last through
        decode steps for the admitted slot (simple and correct; a production
        path would use the batched prefill kernel per slot).  The last
        prompt token is left for the first ``step()``, which decodes it at
        its true position and samples the first output token from its
        logits — prefilling it here would write its KV twice (pos L-1 and
        L) and condition the continuation on a duplicated token."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.slot_req[slot] = req
            self.slot_pos[slot] = 0
            # teacher-force the prompt through this slot; only this slot's
            # cache rows may be touched (other slots can be mid-decode)
            mask = np.zeros(self.n_slots, bool)
            mask[slot] = True
            mask = jnp.asarray(mask)
            for t in range(len(req.prompt) - 1):
                tok = self._slot_tokens(slot, req.prompt[t])
                _, self.cache = self._decode(
                    self.params, self.cache, tok,
                    jnp.asarray(int(self.slot_pos[slot]), jnp.int32), mask)
                self.slot_pos[slot] += 1

    def _slot_tokens(self, slot: int, value) -> jnp.ndarray:
        """Batch token vector with ``value`` in ``slot`` and pad elsewhere.
        Pad rows produce garbage logits (ignored) and their cache writes are
        discarded by the slot mask in ``_decode``."""
        if self.cfg.input_mode == "codebooks":
            arr = np.zeros((self.n_slots, self.cfg.n_codebooks), np.int32)
        else:
            arr = np.zeros((self.n_slots,), np.int32)
        arr[slot] = value
        return jnp.asarray(arr)

    # ----------------------------------------------------------------- step
    def step(self):
        """One decode step for every active slot (batched)."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        # batched greedy decode: slots sharing a position step together; when
        # positions diverge, each group decodes with a slot mask so only the
        # group's cache rows are written (pad rows must never clobber other
        # groups' entries at this pos)
        pos_groups: Dict[int, list] = {}
        for s in active:
            pos_groups.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos, slots in pos_groups.items():
            if self.cfg.input_mode == "codebooks":
                toks = np.zeros((self.n_slots, self.cfg.n_codebooks),
                                np.int32)
            else:
                toks = np.zeros((self.n_slots,), np.int32)
            mask = np.zeros(self.n_slots, bool)
            for s in slots:
                last = (self.slot_req[s].out_tokens[-1]
                        if self.slot_req[s].out_tokens
                        else self.slot_req[s].prompt[-1])
                toks[s] = last
                mask[s] = True
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32), jnp.asarray(mask))
            logits = np.asarray(logits, np.float32)
            for s in slots:
                req = self.slot_req[s]
                lg = logits[s]
                if req.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    tok = np.asarray(jax.random.categorical(
                        sub, jnp.asarray(lg) / req.temperature, axis=-1))
                else:
                    tok = lg.argmax(axis=-1)
                req.out_tokens.append(
                    int(tok) if np.ndim(tok) == 0 else tok.astype(np.int32))
                self.slot_pos[s] += 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    self.done[req.rid] = req
                    self.slot_req[s] = None

    # ------------------------------------------------------------------ run
    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self._admit()
            self.step()
            steps += 1
        return self.done
