"""Paged block-sparse KV: the page table IS the mask BCSR.

The serving decode path never needs the whole KV ring: a static
attention mask (``AttnMaskSpec``) tells us, per query block-row, exactly
which key blocks can ever score — and that row of the memoized mask BCSR
(``models.attention.decode_page_table``) doubles as the page table of a
paged KV cache with page width = the mask block width.  The gather
itself lives in ``models.layers._paged_decode`` (gated by
``AttnSparsitySpec.paged_decode``, bitwise-equal to the full-table run);
this module owns what sits ABOVE the math:

* the **placement policy** — which pages stay device-resident vs
  host-offloaded, decided analytically from page demand (how many mask
  block-rows reference each page = the BCSR column counts) under a
  device page budget;
* the **cost model** — expected per-decode-step read time under HBM vs
  host-link bandwidths, ``(1/nbr) * sum_p demand[p] * page_bytes /
  bw(p)`` (each step lands in one block-row; a page is read iff its
  column appears in that row);
* the **accounting reports** consumed by ``launch.dryrun`` (pages and
  resident bytes per layer group) and ``benchmarks/bench_serving.py``
  (deterministic CI-gated fields).

Everything here is host-side and deterministic in the config — this is
an *analytic* placement layer (the repo runs on CPU; no real offload is
performed), in the same spirit as the dryrun's VMEM feasibility math.

>>> from repro.models import attention as A
>>> page_demand(A.banded(32), 64, (16, 16)).tolist()
[3, 3, 2, 1]
>>> spec = PagePlacementSpec(resident_pages=2)
>>> page_placement(A.banded(32), 64, (16, 16), spec).tolist()
[True, True, False, False]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagePlacementSpec:
    """Static placement policy (hashable — feeds lru_cached placement).

    ``resident_pages`` is the per-layer-group device budget in pages;
    ``None`` keeps everything device-resident.  Bandwidths are the
    analytic cost-model constants (defaults: one HBM2E stack vs a
    PCIe4-ish host link)."""
    policy: str = "greedy"              # greedy | all_device
    resident_pages: Optional[int] = None
    hbm_gbps: float = 819.0
    host_gbps: float = 32.0


@functools.lru_cache(maxsize=None)
def page_demand(mask, seq_len: int, block: Tuple[int, int]) -> np.ndarray:
    """Demand of each KV page = number of mask block-rows referencing it
    (the column counts of the mask BCSR).  Memoized host constant."""
    from repro.models import attention as A
    a = A.attention_mask_bcsr(mask, seq_len, block)
    meta = A.attention_mask_meta(mask, seq_len, block)
    d = np.bincount(a.col_ids, minlength=meta.n_block_cols).astype(np.int64)
    d.setflags(write=False)
    return d


@functools.lru_cache(maxsize=None)
def page_placement(mask, seq_len: int, block: Tuple[int, int],
                   pspec: PagePlacementSpec) -> np.ndarray:
    """[n_pages] bool — True where the page is device-resident.  Greedy:
    most-demanded pages first under the budget (ties -> lowest page id,
    ``np.argsort(kind="stable")`` — deterministic)."""
    demand = page_demand(mask, seq_len, block)
    n_pages = int(demand.size)
    if pspec.policy == "all_device" or pspec.resident_pages is None:
        budget = n_pages
    elif pspec.policy == "greedy":
        budget = max(0, min(n_pages, int(pspec.resident_pages)))
    else:
        raise ValueError(f"unknown placement policy {pspec.policy!r}")
    order = np.argsort(-demand, kind="stable")
    resident = np.zeros(n_pages, bool)
    resident[order[:budget]] = True
    resident.setflags(write=False)
    return resident


class PagedKVCache:
    """Analytic paged view over a ``ServeEngine``'s KV rings.

    Holds NO arrays — the engine's ring buffers stay the storage and the
    page tables are the memoized mask-BCSR constants.  This object binds
    a model config + serving shape to a placement spec and renders the
    per-layer-group accounting: page counts, pages touched per decode
    step (= the mask meta's ``max_bpr``), resident/offloaded bytes, and
    the cost-model step-read estimates (paged vs dense ring read).

    Layer groups follow the transformer layouts that own k/v rings:
    ``attn_mlp`` is one group (all layers share the config mask +
    sliding window); ``gemma_pair`` splits into local (window-capped,
    possibly smaller ring) and global halves.
    """

    def __init__(self, cfg, cache_len: int, n_slots: int,
                 placement: Optional[PagePlacementSpec] = None):
        if getattr(cfg, "attn_sparsity", None) is None:
            raise ValueError("PagedKVCache requires cfg.attn_sparsity")
        if cfg.layout not in ("attn_mlp", "gemma_pair"):
            raise ValueError(
                f"layout {cfg.layout!r} has no k/v attention rings to page")
        self.cfg = cfg
        self.cache_len = int(cache_len)
        self.n_slots = int(n_slots)
        self.placement = placement or PagePlacementSpec()

    def _groups(self):
        """[(name, window, n_layers)] — layer groups sharing one mask."""
        cfg = self.cfg
        if cfg.layout == "attn_mlp":
            return [("attn", cfg.sliding_window, cfg.n_layers)]
        half = cfg.n_layers // 2
        return [("local", cfg.sliding_window, half), ("global", None, half)]

    def group_report(self, name: str, window, n_layers: int) -> dict:
        """Deterministic accounting row for one layer group."""
        from repro.models import layers as L
        cfg = self.cfg
        sc = min(self.cache_len, window) if window else self.cache_len
        mask = L._sparse_mask(cfg, window)
        h, w = cfg.attn_sparsity.block
        table = L._decode_pages(cfg, window, sc)
        row = {"group": name, "n_layers": n_layers, "cache_len": sc,
               "mask": dataclasses.asdict(mask),
               "paged": table is not None}
        if sc % w != 0:
            return row              # dense-bias fallback: no page grid
        n_pages = sc // w
        demand = page_demand(mask, sc, (h, w))
        resident = page_placement(mask, sc, (h, w), self.placement)
        kv_bytes = np.dtype(cfg.dtype).itemsize * cfg.n_kv_heads * \
            cfg.head_dim * 2                      # k + v, per position
        page_bytes = int(w * kv_bytes * self.n_slots)
        nbr = -(-sc // h)
        bw = np.where(resident, self.placement.hbm_gbps,
                      self.placement.host_gbps) * 1e9
        est_us = float(np.sum(demand * page_bytes / bw) / nbr * 1e6)
        dense_us = n_pages * page_bytes / (self.placement.hbm_gbps
                                           * 1e9) * 1e6
        meta = None
        if table is not None:
            from repro.models import attention as A
            meta = A.decode_page_table(mask, sc, (h, w))[2]
        row.update({
            "n_pages": n_pages,
            "page_bytes": page_bytes,
            "pages_touched_per_step": int(meta.max_bpr) if meta else n_pages,
            "resident_pages": int(resident.sum()),
            "resident_bytes": int(resident.sum()) * page_bytes * n_layers,
            "offload_bytes": int((~resident).sum()) * page_bytes * n_layers,
            "est_step_read_us": round(est_us * n_layers, 4),
            "est_step_read_us_dense": round(dense_us * n_layers, 4),
        })
        return row

    def table_leaves(self) -> dict:
        """Page tables of every layer group as device arrays,
        ``{group: {"pages": [nbr, max_bpr] i32, "page_live": bool}}`` —
        the leaves ``launch.sharding.cache_shardings`` replicates by
        name.  The jitted decode path closes over the same tables as
        host constants; this materialized form exists for explicit
        placement under a mesh (dryrun exercises the rule)."""
        import jax.numpy as jnp
        from repro.models import attention as A
        from repro.models import layers as L
        out = {}
        for name, window, _ in self._groups():
            sc = min(self.cache_len, window) if window else self.cache_len
            w = self.cfg.attn_sparsity.block[1]
            if sc % w != 0:
                continue
            mask = L._sparse_mask(self.cfg, window)
            pages, live, _ = A.decode_page_table(
                mask, sc, self.cfg.attn_sparsity.block)
            out[name] = {"pages": jnp.asarray(pages),
                         "page_live": jnp.asarray(live)}
        return out

    def report(self) -> dict:
        """Per-group rows + totals — the ``launch.dryrun`` serving record
        and the hard-gated page fields of ``BENCH_serving.json``."""
        rows = [self.group_report(*g) for g in self._groups()]
        return {
            "cache_len": self.cache_len,
            "n_slots": self.n_slots,
            "placement": dataclasses.asdict(self.placement),
            "groups": rows,
            "resident_bytes_total": sum(r.get("resident_bytes", 0)
                                        for r in rows),
            "offload_bytes_total": sum(r.get("offload_bytes", 0)
                                       for r in rows),
            "resident_page_counts": [r.get("resident_pages", 0)
                                     for r in rows],
        }
