"""Continuous-batching scheduler: slot-level admit/feed/evict decisions.

Pure host-side policy (python/numpy — no jax): the engine executes
whatever this module decides, so every scheduling decision is
deterministic in the request trace alone and can gate hard in CI
(``benchmarks/bench_serving.py`` commits the admit/finish event list).

States per slot: FREE (no request) -> PREFILL (fed < len(prompt) - 1)
-> DECODE (sampling) -> FREE again on completion.  Prefill is
*by-decode*: each engine step feeds every active slot exactly one token
at its own position, so a slot prefilling at position p and a slot
decoding at position p batch into the same jitted decode call —
prefill/decode interleave falls out of position grouping, with static
shapes throughout (slot masks, never retraces).

Prefix-cache reuse: the per-slot history of tokens whose KV was written
(``written``) survives eviction; a new request admits with ``fed = c``
where ``c`` is the longest common prefix against any slot's history
(capped at ``len(prompt) - 1`` so the first sample still decodes the
last prompt token at its true position).  The engine copies the donor
slot's KV rows — batch rows compute independently, so copied KV is
bitwise identical to recomputing the prefix (pinned in
``tests/test_serving.py``).

>>> import numpy as np
>>> class R:                    # anything with these four attributes works
...     def __init__(self, rid, prompt, n=2):
...         self.rid, self.prompt = rid, np.asarray(prompt, np.int32)
...         self.max_new_tokens, self.out_tokens = n, []
>>> s = Scheduler(SchedulerConfig(n_slots=2, cache_len=16))
>>> s.enqueue(R(0, [5, 6, 7])); s.enqueue(R(1, [5, 6, 9]))
>>> [(a["rid"], a["slot"], a["reuse"]) for a in s.admit()]
[(0, 0, 0), (1, 1, 0)]
>>> [(pos, [e[0] for e in entries]) for pos, entries in s.plan()]
[(0, [0, 1])]
>>> for slot, tok, sample in s.plan()[0][1]:
...     s.advance(slot, tok)
>>> [(pos, [e[0] for e in entries]) for pos, entries in s.plan()]
[(1, [0, 1])]
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Static scheduling policy knobs (hashable — R4)."""
    n_slots: int = 4
    cache_len: int = 256
    prefix_cache: bool = True


def _tok_key(value):
    """Hashable identity of one fed token (scalar or codebook row)."""
    import numpy as np
    arr = np.asarray(value)
    return int(arr) if arr.ndim == 0 else tuple(int(x) for x in arr.ravel())


@dataclasses.dataclass
class _Slot:
    req: object         # .rid .prompt .max_new_tokens .out_tokens
    fed: int = 0        # tokens fed through decode == KV rows written
    admitted_step: int = 0   # step_idx at admission (latency accounting)


class Scheduler:
    """FIFO continuous-batching scheduler over ``n_slots`` cache rows."""

    def __init__(self, config: SchedulerConfig):
        self.config = config
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * config.n_slots
        # fed-token keys per slot row; kept after eviction so a later
        # request can prefix-match the KV still sitting in the cache
        self.written: List[tuple] = [()] * config.n_slots
        self.trace: List[dict] = []
        self.step_idx = 0
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0

    def _emit(self, event: str, **fields) -> dict:
        """THE scheduler trace emitter (PR 10 satellite): one record, two
        views.  The returned dict lands in ``self.trace`` — the
        deterministic list ``bench_serving`` gates byte-for-byte — and the
        same payload goes out as a ``serve.<event>`` obs event (spans,
        wall-clock, exporters).  Keeping a private per-instance list means
        the bench gate never depends on ``REPRO_TRACE``."""
        rec = {"event": event, "step": self.step_idx, **fields}
        self.trace.append(rec)
        obs_trace.event(f"serve.{event}", **rec)
        return rec

    # ------------------------------------------------------------- admission
    def enqueue(self, req) -> None:
        total = len(req.prompt) + int(req.max_new_tokens)
        if total > self.config.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt + max_new_tokens = {total} "
                f"exceeds cache_len = {self.config.cache_len} (the paged "
                "decode path requires an unwrapped KV ring)")
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def _best_donor(self, prompt_keys: tuple) -> tuple:
        """(reuse_len, src_slot): longest common prefix of the prompt
        against any slot row's written-KV history; ties -> lowest slot."""
        best_c, best_s = 0, -1
        for s, hist in enumerate(self.written):
            c = 0
            for a, b in zip(prompt_keys, hist):
                if a != b:
                    break
                c += 1
            if c > best_c:
                best_c, best_s = c, s
        return best_c, best_s

    def admit(self) -> List[dict]:
        """Fill free slots FIFO; returns admission records (the engine
        performs the KV row copy for ``reuse > 0``)."""
        out = []
        for slot in range(self.config.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            keys = tuple(_tok_key(t) for t in req.prompt)
            reuse, src = (0, -1)
            if self.config.prefix_cache:
                reuse, src = self._best_donor(keys)
                # the last prompt token must still be decoded at its true
                # position so its logits produce the first sample
                reuse = min(reuse, len(req.prompt) - 1)
                if reuse <= 0:
                    reuse, src = 0, -1
            self.slots[slot] = _Slot(req=req, fed=reuse,
                                     admitted_step=self.step_idx)
            self.written[slot] = keys[:reuse]
            obs_metrics.counter("serve.admit").inc()
            if reuse > 0:
                self.prefix_hits += 1
                self.prefix_tokens_reused += reuse
                obs_metrics.counter("serve.prefix_hit").inc()
                obs_metrics.counter("serve.prefix_tokens_reused").inc(reuse)
            out.append(self._emit("admit", rid=req.rid, slot=slot,
                                  reuse=reuse, src=src))
        return out

    # ------------------------------------------------------------------ step
    def plan(self) -> List[tuple]:
        """Work for one engine step: ``[(pos, [(slot, token, sample)])]``
        — groups sorted by position, slots ascending within a group.
        ``token`` is the value to feed at ``pos`` (prompt during prefill,
        the last sample during decode); ``sample`` marks slots whose
        logits produce an output token this step."""
        groups: dict = {}
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            lp = len(st.req.prompt)
            token = (st.req.prompt[st.fed] if st.fed < lp
                     else st.req.out_tokens[st.fed - lp])
            groups.setdefault(st.fed, []).append(
                (slot, token, st.fed >= lp - 1))
        return [(pos, groups[pos]) for pos in sorted(groups)]

    def advance(self, slot: int, token) -> None:
        """Record that ``token``'s KV was written at this slot's position."""
        st = self.slots[slot]
        self.written[slot] = self.written[slot] + (_tok_key(token),)
        st.fed += 1

    def record_output(self, slot: int, token) -> bool:
        """Append a sampled token; evict on completion.  Returns True when
        the request just finished."""
        st = self.slots[slot]
        st.req.out_tokens.append(token)
        if len(st.req.out_tokens) >= st.req.max_new_tokens:
            self._emit("finish", rid=st.req.rid, slot=slot,
                       n_out=len(st.req.out_tokens))
            obs_metrics.counter("serve.finish").inc()
            obs_metrics.histogram("serve.latency_steps").observe(
                self.step_idx - st.admitted_step)
            self.slots[slot] = None
            return True
        return False
