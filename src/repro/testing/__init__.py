# Test-support helpers (dependency shims for the offline CI container).
