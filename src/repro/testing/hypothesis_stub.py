"""Minimal, deterministic stand-in for the ``hypothesis`` API we use.

The CI container is offline and may lack ``hypothesis``; rather than skip the
property tests, ``conftest.py`` installs this module under the
``hypothesis`` / ``hypothesis.strategies`` names when the real package is
missing.  It implements just the surface the test-suite touches:

  * ``strategies.integers / floats / sampled_from``
  * ``@settings(max_examples=..., deadline=...)``
  * ``@given(**kwargs)``

``given`` drives the wrapped test with ``max_examples`` pseudo-random
examples from a fixed seed, so runs are reproducible (no shrinking, no
database — this is a deterministic sampler, not a property-testing engine).
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Records ``max_examples`` on the (possibly already-``given``-wrapped)
    test function; order of @settings/@given does not matter."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(0)
            for i in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from e
        # hide the drawn parameters from pytest's fixture resolution
        # (functools.wraps exposes fn's signature via __wrapped__)
        runner.__signature__ = inspect.Signature()
        del runner.__wrapped__
        return runner
    return deco


def install(sys_modules: dict) -> None:
    """Register this stub as ``hypothesis`` (+ ``.strategies``) in
    ``sys_modules`` — call only when the real package is unimportable."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    mod.__is_repro_stub__ = True
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = strat
