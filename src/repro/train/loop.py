"""Training loop with large-scale fault-tolerance mechanics:

  * checkpoint/restart — async CheckpointManager; on (re)start the loop
    resumes from the latest checkpoint automatically;
  * failure injection — ``fail_at_step`` raises SimulatedFailure mid-run
    (the launcher catches it and relaunches; see launch/train.py);
  * straggler watchdog — EWMA of step times; steps slower than
    ``straggler_factor`` x EWMA are logged with their step index (on a real
    pod this signal feeds the controller's hot-spare swap);
  * elastic re-mesh — checkpoints are mesh-agnostic, so a relaunch on a
    different device count re-shards transparently;
  * optional int8 error-feedback gradient compression over the data axis
    (shard_map path, for cross-pod DCI relief).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeCell
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import sharding as sh
from repro.launch import steps as st
from repro.models import transformer as T
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import adamw

log = logging.getLogger("repro.train")


class SimulatedFailure(RuntimeError):
    """Injected node failure (exercise the restart path)."""


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list
    step_times: list
    restarts_used: int
    straggler_steps: list


def train(cfg: ModelConfig, shape: ShapeCell, mesh, *,
          total_steps: int = 50,
          opt_cfg: Optional[adamw.AdamWConfig] = None,
          ckpt_dir: Optional[str] = None,
          ckpt_every: int = 20,
          fail_at_step: Optional[int] = None,
          straggler_factor: float = 3.0,
          remat: str = "none",
          data_cfg: DataConfig = DataConfig(),
          log_every: int = 10) -> TrainResult:
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=total_steps)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    params_specs = T.param_specs(cfg)
    p_shard = sh.param_shardings(mesh, params_specs)
    o_specs = jax.eval_shape(adamw.init, params_specs)
    o_shard = sh.opt_state_shardings(mesh, o_specs)
    b_specs = st.input_specs(cfg, shape)
    b_shard = sh.batch_shardings(mesh, b_specs)

    with mesh:
        train_step = jax.jit(
            st.make_train_step(cfg, opt_cfg, remat=remat),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1))

        # ---- init or resume
        start_step = 0
        if mgr and mgr.latest_step() is not None:
            like = {"params": params_specs, "opt": o_specs}
            state, start_step = mgr.restore(
                like, shardings={"params": p_shard, "opt": o_shard})
            params, opt_state = state["params"], state["opt"]
            log.info("resumed from step %d (elastic re-shard onto %s)",
                     start_step, mesh.devices.shape)
        else:
            params = jax.device_put(T.init_params(cfg, seed=0), p_shard)
            opt_state = jax.device_put(adamw.init(params), o_shard)

        losses, times, stragglers = [], [], []
        ewma = None
        for step in range(start_step, total_steps):
            if fail_at_step is not None and step == fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = jax.device_put(
                make_batch(cfg, shape, step, data_cfg), b_shard)
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            times.append(dt)
            # step/loss series: loss is deterministic for a fixed seed, so
            # it rides in the event args; the step time is wall clock and
            # stays in metrics (report-only histogram) + the dur field
            obs_trace.timed_event("train.step", dt * 1e6,
                                  step=step, loss=loss)
            obs_metrics.counter("train.steps").inc()
            obs_metrics.gauge("train.loss").set(loss)
            obs_metrics.histogram("train.step_time_us").observe(dt * 1e6)
            if ewma is None:
                ewma = dt
            else:
                if dt > straggler_factor * ewma:
                    stragglers.append(step)
                    obs_metrics.counter("train.stragglers").inc()
                    log.warning("straggler suspected at step %d: "
                                "%.2fs vs EWMA %.2fs", step, dt, ewma)
                ewma = 0.9 * ewma + 0.1 * dt
            if step % log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1,
                         {"params": params, "opt": opt_state})
        if mgr:
            mgr.save(total_steps, {"params": params, "opt": opt_state},
                     block=True)
    return TrainResult(total_steps, losses, times, 0, stragglers)


def train_with_restarts(cfg, shape, mesh_factory, *, max_restarts: int = 2,
                        **kw) -> TrainResult:
    """The launcher: retries after (injected or real) failures; each retry
    rebuilds the mesh (elastic: the new mesh may differ) and resumes from
    the latest checkpoint."""
    restarts = 0
    fail_at = kw.pop("fail_at_step", None)
    while True:
        try:
            mesh = mesh_factory(restarts)
            res = train(cfg, shape, mesh, fail_at_step=fail_at, **kw)
            res = dataclasses.replace(res, restarts_used=restarts)
            return res
        except SimulatedFailure as e:
            restarts += 1
            fail_at = None                       # only fail once
            log.warning("%s -> restart %d/%d", e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
