"""Static contract analyzer tests: every lint rule fires on a fixture it
must flag, the full analyzer is zero-findings on the real tree (no false
positives), and the launch verifier accepts every structure-zoo schedule
while rejecting deliberate corruptions for each kernel family."""
import dataclasses
import os
import textwrap

import numpy as np
import pytest

from repro.analysis import fingerprint_audit as fpa
from repro.analysis import lint_rules as lint
from repro.analysis import verify_launch as vl
from repro.analysis import workspace
from repro.core import bcsr as bcsr_lib
from repro.kernels import autotune, ops


def _src(text):
    return textwrap.dedent(text)


# =============================================================== lint rules
class TestLintFixtures:
    """Each rule must flag its fixture with a file:line diagnostic."""

    def test_traced_numpy_reachable(self):
        fs = lint.lint_source(_src("""
            import functools, jax
            import numpy as np
            @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
            def f(cfg, x):
                return helper(x)
            def f_fwd(cfg, x):
                return f(cfg, x), (x,)
            def f_bwd(cfg, res, g):
                return (g,)
            f.defvjp(f_fwd, f_bwd)
            def helper(x):
                return np.asarray(x) * 2
            """), "fix.py")
        assert [f.rule for f in fs] == ["traced-numpy"]
        assert fs[0].path == "fix.py" and fs[0].line > 0

    def test_traced_numpy_in_pallas_kernel(self):
        fs = lint.lint_source(_src("""
            import numpy as np
            import jax.experimental.pallas as pl
            def _kern(x_ref, o_ref):
                o_ref[...] = np.tanh(x_ref[...])
            def launch(x):
                return pl.pallas_call(_kern, out_shape=x)(x)
            """), "fix.py")
        assert [f.rule for f in fs] == ["traced-numpy"]

    def test_traced_numpy_float0_allowlisted_and_lru_boundary(self):
        fs = lint.lint_source(_src("""
            import functools, jax
            import numpy as np
            @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
            def f(cfg, x):
                return x + host(3)
            def f_fwd(cfg, x):
                return f(cfg, x), (x,)
            def f_bwd(cfg, res, g):
                z = jax.tree.map(
                    lambda t: np.zeros(t.shape, jax.dtypes.float0), res)
                return (g,)
            f.defvjp(f_fwd, f_bwd)
            @functools.lru_cache(maxsize=None)
            def host(n):
                return float(np.ones(n).sum())
            """), "fix.py")
        assert fs == []

    def test_lru_cache_unhashable_annotation(self):
        fs = lint.lint_source(_src("""
            import functools
            @functools.lru_cache(maxsize=None)
            def f(xs: list, d: int = 3):
                return sum(xs) + d
            """), "fix.py")
        assert [f.rule for f in fs] == ["lru-cache-static"]

    def test_lru_cache_mutable_default(self):
        fs = lint.lint_source(_src("""
            import functools
            @functools.lru_cache(maxsize=None)
            def f(n, xs=[]):
                return n
            """), "fix.py")
        assert [f.rule for f in fs] == ["lru-cache-static"]

    def test_lru_cache_unannotated_params_ok(self):
        """mlp_sparse_metas-style signatures (unannotated spec) pass."""
        fs = lint.lint_source(_src("""
            import functools
            @functools.lru_cache(maxsize=None)
            def f(spec, d: int, hints: tuple):
                return (spec, d, hints)
            """), "fix.py")
        assert fs == []

    def test_custom_vjp_missing_defvjp(self):
        fs = lint.lint_source(_src("""
            import jax
            @jax.custom_vjp
            def f(x):
                return x
            """), "fix.py")
        assert [f.rule for f in fs] == ["custom-vjp-pairing"]

    def test_custom_vjp_bad_bwd_arity(self):
        fs = lint.lint_source(_src("""
            import functools, jax
            @functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
            def f(a, b, x, y):
                return x
            def f_fwd(a, b, x, y):
                return f(a, b, x, y), (x,)
            def f_bwd(a, b, res, g):
                return (g,)
            f.defvjp(f_fwd, f_bwd)
            """), "fix.py")
        assert [f.rule for f in fs] == ["custom-vjp-pairing"]
        assert "cotangent" in fs[0].message

    def test_custom_vjp_computed_return_skipped(self):
        """_attn_fused_bwd-style ``return vjp(g)`` must not be flagged."""
        fs = lint.lint_source(_src("""
            import functools, jax
            @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
            def f(cfg, x, y):
                return x
            def f_fwd(cfg, x, y):
                return f(cfg, x, y), (x, y)
            def f_bwd(cfg, res, g):
                vjp = res[0]
                return vjp(g)
            f.defvjp(f_fwd, f_bwd)
            """), "fix.py")
        assert fs == []

    def test_static_aux_not_frozen(self):
        fs = lint.lint_source(_src("""
            import dataclasses
            @dataclasses.dataclass
            class FooMeta:
                n: int
            """), "fix.py")
        assert [f.rule for f in fs] == ["static-aux-frozen"]

    def test_static_aux_unhashable_field(self):
        fs = lint.lint_source(_src("""
            import dataclasses
            @dataclasses.dataclass(frozen=True)
            class FooSpec:
                xs: list
            """), "fix.py")
        assert [f.rule for f in fs] == ["static-aux-frozen"]

    def test_static_aux_frozen_ok_and_name_scope(self):
        fs = lint.lint_source(_src("""
            import dataclasses
            @dataclasses.dataclass(frozen=True)
            class FooMeta:
                n: int
            @dataclasses.dataclass
            class ScratchBuffer:
                xs: list
            """), "fix.py")
        assert fs == []

    def test_fingerprint_missing_meta_field(self):
        fs = lint.check_fingerprint_fields(
            _src("""
                import dataclasses
                @dataclasses.dataclass(frozen=True)
                class SparseMeta:
                    nnzb: int
                    max_bpr: int
                """),
            _src("""
                import dataclasses
                @dataclasses.dataclass(frozen=True)
                class Fingerprint:
                    nnzb: int
                    def key(self):
                        return f"v6|nnzb={self.nnzb}"
                def fingerprint(meta, n):
                    return Fingerprint(nnzb=meta.nnzb)
                """))
        assert [f.rule for f in fs] == ["fingerprint-fields"]
        assert "max_bpr" in fs[0].message

    def test_fingerprint_field_not_in_key(self):
        fs = lint.check_fingerprint_fields(
            _src("""
                import dataclasses
                @dataclasses.dataclass(frozen=True)
                class SparseMeta:
                    nnzb: int
                """),
            _src("""
                import dataclasses
                @dataclasses.dataclass(frozen=True)
                class Fingerprint:
                    nnzb: int
                    orphan: int
                    def key(self):
                        return f"v6|nnzb={self.nnzb}"
                def fingerprint(meta, n):
                    return Fingerprint(nnzb=meta.nnzb, orphan=0)
                """))
        assert [f.rule for f in fs] == ["fingerprint-fields"]
        assert "orphan" in fs[0].message


def test_lint_tree_zero_findings_on_src():
    """No false positives: the current tree satisfies every invariant."""
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    findings = lint.lint_tree(root)
    assert findings == [], "\n".join(str(f) for f in findings)


# =========================================================== launch verifier
def _rand_case():
    a = bcsr_lib.random_bcsr_exact(0, (256, 256), (16, 16), 64)
    return a, ops.prepare_sparse_meta(a)


class TestVerifier:
    def test_zoo_all_clean(self):
        findings = vl.run_verify()
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_spmm_corruption_rejected(self):
        a, meta = _rand_case()
        fi, fc, rl = vl.spmm_row_loop_schedule_host(
            a.row_ids, a.col_ids, meta.n_block_rows, meta.max_bpr)
        assert vl.verify_schedule("spmm", fi, fc, a.row_ids, a.col_ids,
                                  meta, row_len=rl) == []
        # slot dropped: the loop mask skips a live entry
        bad_rl = rl.copy()
        bad_rl[int(np.argmax(rl))] -= 1
        assert vl.verify_schedule("spmm", fi, fc, a.row_ids, a.col_ids,
                                  meta, row_len=bad_rl)
        # duplicate entry on a live slot (the spmm-family analogue of a
        # sentinel on a live block: padding value 0 overwrites a slot)
        live = np.flatnonzero(fi != 0)
        bad_fi = fi.copy()
        bad_fi[live[0]] = 0
        assert vl.verify_schedule("spmm", bad_fi, fc, a.row_ids, a.col_ids,
                                  meta, row_len=rl)

    def test_sddmm_corruption_rejected(self):
        a, meta = _rand_case()
        fi, fc = vl.sddmm_row_loop_schedule_host(
            a.row_ids, a.col_ids, meta.n_block_rows, meta.max_bpr)
        assert vl.verify_schedule("sddmm", fi, fc, a.row_ids, a.col_ids,
                                  meta) == []
        # sentinel on a live block: one entry is never computed
        live = np.flatnonzero(fi != meta.nnzb)
        bad = fi.copy()
        bad[live[3]] = meta.nnzb
        assert vl.verify_schedule("sddmm", bad, fc, a.row_ids, a.col_ids,
                                  meta)
        # wrong column on a live slot: the kernel would read the wrong
        # K-panel
        bad_fc = fc.copy()
        bad_fc[live[0]] = (bad_fc[live[0]] + 1) % meta.n_block_cols
        errs = vl.verify_schedule("sddmm", fi, bad_fc, a.row_ids,
                                  a.col_ids, meta)
        assert errs and any("col" in e for e in errs)

    def test_attn_corruption_rejected(self):
        """The fused-attention schedule (built exactly as
        ``models.attention._fused_inputs`` builds it) under the attn
        family: dropped slot AND sentinel-on-live both rejected."""
        from repro.core.attention_mask import banded
        from repro.models import attention as A
        spec, seq = banded(32), 128
        a = A.attention_mask_bcsr(spec, seq, (16, 16))
        meta = A.attention_mask_meta(spec, seq, (16, 16))
        fi, fc = vl.sddmm_row_loop_schedule_host(
            a.row_ids, a.col_ids, meta.n_block_rows, meta.max_bpr)
        assert vl.verify_schedule("attn", fi, fc, a.row_ids, a.col_ids,
                                  meta) == []
        live = np.flatnonzero(fi != meta.nnzb)
        bad = fi.copy()
        bad[live[0]] = meta.nnzb          # sentinel on a live block
        assert vl.verify_schedule("attn", bad, fc, a.row_ids, a.col_ids,
                                  meta)
        bad = fi.copy()
        bad[live[1]] = int(fi[live[0]])   # slot dropped (duplicated twin)
        assert vl.verify_schedule("attn", bad, fc, a.row_ids, a.col_ids,
                                  meta)

    def test_meta_invariants(self):
        _, meta = _rand_case()
        assert vl.verify_meta(meta) == []
        assert vl.verify_meta(dataclasses.replace(meta, nnzb=meta.nnzb * 100))
        assert vl.verify_meta(dataclasses.replace(meta, nnzb_t=meta.nnzb - 1))
        assert vl.verify_meta(
            dataclasses.replace(meta, max_bpr=meta.n_block_cols + 1))

    def test_sharded_meta_invariants(self):
        from repro.launch import dist_spmm
        a = bcsr_lib.random_bcsr_exact(7, (320, 256), (16, 16), 80)
        smeta = dist_spmm.prepare_sharded_meta(a, 4)
        assert vl.verify_sharded_meta(smeta) == []
        bad = dataclasses.replace(smeta,
                                  nnzb_t_per_shard=smeta.nnzb_t_per_shard - 1)
        assert vl.verify_sharded_meta(bad)
        bad = dataclasses.replace(smeta, rows_per_shard=1)
        assert vl.verify_sharded_meta(bad)

    def test_dims_only_meta_tolerated_but_not_schedulable(self):
        from repro.core.sparse_linear import SparsitySpec, sparse_linear_specs
        _, meta = sparse_linear_specs(
            96, 64, SparsitySpec(density=0.3, block=(16, 16)))
        assert meta.max_bpr == 0
        assert vl.verify_meta(meta) == []     # dims-only budgets are legal
        assert vl.verify_launch(meta, "row_loop", n=64)  # but not row_loop
        assert vl.verify_launch(meta, "xla", n=64) == []

    def test_vmem_budget(self):
        _, meta = _rand_case()
        assert vl.verify_launch(meta, "row_loop", n=512) == []
        errs = vl.verify_launch(meta, "row_loop", n=512, vmem_budget=1024)
        assert errs and any("VMEM" in e for e in errs)

    def test_chunk_schedule_invariants(self):
        """Overlap schedules: every builder output passes; every corrupted
        schedule (gap, overlap, empty chunk, wrong span) is caught."""
        from repro.launch.dist_spmm import chunk_schedule
        for n in (1, 7, 64, 512):
            for k in (1, 2, 4, 8):
                assert vl.verify_chunk_schedule(
                    chunk_schedule(n, k), n, block=(16, 16)) == []
        # overlap: column range accumulated twice -> not bit-identical
        errs = vl.verify_chunk_schedule([(0, 3), (2, 6), (6, 10)], 10)
        assert errs and "overlap" in errs[0]
        # gap: columns dropped from the output panel
        errs = vl.verify_chunk_schedule([(0, 3), (4, 10)], 10)
        assert errs and "gap" in errs[0]
        # empty / descending chunk
        assert vl.verify_chunk_schedule([(0, 6), (6, 6), (6, 10)], 10)
        assert vl.verify_chunk_schedule([(0, 8), (8, 7)], 10)
        # wrong span at either end
        errs = vl.verify_chunk_schedule([(1, 6), (6, 9)], 10)
        assert len(errs) == 2
        assert vl.verify_chunk_schedule([], 10)
        assert vl.verify_chunk_schedule("nope", 10)
        # per-chunk VMEM gate fires under a tiny budget
        errs = vl.verify_chunk_schedule(
            chunk_schedule(512, 4), 512, block=(16, 16), vmem_budget=1024)
        assert errs and all("VMEM" in e for e in errs)

    def test_resolve_backend_hook(self, monkeypatch):
        a, meta = _rand_case()
        monkeypatch.setenv("REPRO_VERIFY_LAUNCH", "1")
        assert ops.resolve_backend("row_loop", 512, meta, 64) == \
            ("row_loop", 512)
        bad = dataclasses.replace(meta, nnzb=meta.nnzb * 100)
        with pytest.raises(vl.LaunchError):
            ops.resolve_backend("row_loop", 512, bad, 64)
        monkeypatch.delenv("REPRO_VERIFY_LAUNCH")
        ops.resolve_backend("row_loop", 512, bad, 64)   # opt-in: no check


# ======================================================== shared estimators
def test_workspace_matches_benchmark_formulas():
    """The unified estimator must reproduce the exact expressions the
    attention benchmark baseline pinned (satellite: dedupe, not change)."""
    _, meta = _rand_case()
    h, w = meta.block
    assert workspace.attn_composed_workspace_bytes(meta) == \
        2 * meta.nnzb * h * w * 4
    for d in (64, 128, 256):
        dpad = max(-(-d // 128), 1) * 128
        assert workspace.attn_fused_state_bytes((16, 16), d) == \
            16 * (2 * 128 + dpad) * 4


def test_workspace_matches_pick_bn_feasibility():
    """``fits_vmem`` is the same predicate ``autotune.pick_bn`` budgets
    with: every candidate pick_bn accepts, fits_vmem accepts, and
    vice versa — the estimator and the autotuner cannot drift."""
    candidates = (128, 256, 512, 1024, 2048, 8192, 65536)
    for block in ((16, 16), (32, 32), (128, 128)):
        _, meta = _rand_case()
        meta = dataclasses.replace(meta, block=block)
        for n in (128, 512, 4096):
            bn = autotune.pick_bn(meta, n, candidates)
            feasible = [c for c in candidates
                        if workspace.fits_vmem(block, c)]
            if feasible:
                assert workspace.fits_vmem(block, bn)
                assert bn == max(c for c in feasible
                                 if c <= max(n, min(feasible)))


def test_dryrun_attention_report_uses_shared_estimator():
    import repro.configs as C
    from repro.launch import dryrun
    cfg = C.get_config("smat-attn-1.3b:smoke")
    rep = dryrun.sparse_attention_report(cfg, seq_len=64)
    assert rep["verify"]["ok"], rep["verify"]
    spec = cfg.attn_sparsity
    from repro.models import attention as A
    seq = max(64, spec.block[0] * 2)
    meta = A.attention_mask_meta(spec.mask, seq, spec.block)
    assert rep["composed_workspace_bytes"] == \
        workspace.attn_composed_workspace_bytes(meta)
    assert rep["fused_state_bytes"] == \
        workspace.attn_fused_state_bytes(spec.block, cfg.head_dim)


# ========================================================= fingerprint audit
class TestFingerprintAudit:
    def test_round_trip(self):
        _, meta = _rand_case()
        for op in ("spmm", "sddmm", "attn"):
            fp = autotune.fingerprint(meta, 512, op=op)
            assert fpa.parse_key(fp.key()) == fp

    def test_stale_version_actionable(self):
        fp = autotune.fingerprint(_rand_case()[1], 512)
        stale = "v5" + fp.key()[2:]
        with pytest.raises(fpa.StaleKeyError) as ei:
            fpa.parse_key(stale)
        msg = str(ei.value)
        assert "v5" in msg and "v7" in msg and "refresh" in msg
        # the immediately-previous grammar (no nk= field) is stale too
        with pytest.raises(fpa.StaleKeyError):
            fpa.parse_key("v6" + fp.key()[2:].rsplit("|nk=", 1)[0])

    def test_malformed_key_rejected(self):
        with pytest.raises(ValueError):
            fpa.parse_key("v7|op=spmm|nbr=oops")
        with pytest.raises(ValueError):
            fpa.parse_key("not a key at all")

    def test_injectivity_over_sampled_space(self):
        assert fpa.audit_injectivity() == []

    def test_committed_artifacts_parse(self):
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        findings = fpa.audit_files(root)
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_stale_cache_file_flagged(self, tmp_path, monkeypatch):
        fp = autotune.fingerprint(_rand_case()[1], 512)
        cache = tmp_path / "cache.json"
        cache.write_text(
            '{"version": 1, "entries": {"v5%s": '
            '{"variant": "nnz_stream", "bn": 512}}}' % fp.key()[2:])
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
        findings = fpa.audit_files(str(tmp_path))
        assert findings and all(f.rule == "fingerprint-audit"
                                for f in findings)


# ===================================================================== CLI
def test_cli_all_green_on_current_tree():
    from repro.analysis.__main__ import main
    assert main(["--all"]) == 0


def test_cli_nonzero_with_diagnostics_on_bad_tree(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text(_src("""
        import dataclasses
        @dataclasses.dataclass
        class BadMeta:
            n: int
        """))
    rc = main(["--lint", "--src", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert f"{bad}:" in out and "[static-aux-frozen]" in out
