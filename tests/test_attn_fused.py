"""Fused one-kernel block-sparse attention (PR 6): the bit-for-bit f32
forward pin against the composed SDDMM -> block_softmax -> SpMM triple
across all three mask families, gradient parity through the composed VJP,
the v6 ``op=attn`` fingerprint non-aliasing contract, and the
attention-level dispatch rules (``backend="auto"``/``"fused"``; sharded
and explicit-kernel specs stay composed).

Runs unchanged under forced multi-host-device CI
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the fused
kernel is per-instance math; the sharded-spec test exercises the
composed fallback path those devices feed."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import autotune, bcsr_attn, ops
from repro.models import attention as A


@pytest.fixture(autouse=True)
def _fresh_tuner():
    autotune.set_autotuner(autotune.Autotuner())
    yield
    autotune.set_autotuner(None)


MASKS = {
    "banded": A.banded(24),
    "local_global": A.local_global(16, 8),
    "blockwise_causal": A.blockwise_causal(),
}


def _qkv(L, d, B=2, H=2, seed=0):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.standard_normal((B, L, H, d)), jnp.float32)
                 for _ in range(3))


def _specs(mask, block=(8, 8)):
    fused = A.AttnSparsitySpec(mask=mask, block=block, backend="fused",
                               interpret=True)
    composed = A.AttnSparsitySpec(mask=mask, block=block, backend="xla")
    return fused, composed


# ===================================================== bit-for-bit forward
@pytest.mark.parametrize("mask_kind", list(MASKS))
@pytest.mark.parametrize("L", [64, 61])   # aligned + ragged tail block-row
def test_fused_forward_bitwise_equals_composed(mask_kind, L):
    """The tentpole pin: fused forward == composed forward BIT-FOR-BIT in
    f32 — not allclose — across mask families, including ragged tails
    whose padded query rows have no valid element."""
    q, k, v = _qkv(L, 8, seed=hash(mask_kind) % 1000)
    spec_f, spec_c = _specs(MASKS[mask_kind])
    got = A.block_sparse_attention(q, k, v, spec_f)
    want = A.block_sparse_attention(q, k, v, spec_c)
    assert got.dtype == want.dtype == jnp.float32
    assert bool(jnp.all(got == want)), (
        f"max abs diff {float(jnp.max(jnp.abs(got - want)))}")


def test_fused_bitwise_vs_composed_pallas_backend():
    """Same pin against the composed path on its Pallas (interpret)
    kernels — the production composed arm, not just the xla oracle."""
    q, k, v = _qkv(64, 16)
    spec_f, _ = _specs(A.banded(24))
    spec_p = A.AttnSparsitySpec(mask=A.banded(24), block=(8, 8),
                                backend="pallas", interpret=True)
    got = A.block_sparse_attention(q, k, v, spec_f)
    want = A.block_sparse_attention(q, k, v, spec_p)
    assert bool(jnp.all(got == want))


def test_fused_capped_matches_at_float_tolerance():
    """The optional tanh soft-clip: XLA's tanh lowering is not
    bitwise-stable across fusion contexts (documented in bcsr_attn), so
    capped attention pins at tight float tolerance instead."""
    q, k, v = _qkv(64, 8)
    spec_f, spec_c = _specs(MASKS["local_global"])
    got = A.block_sparse_attention(q, k, v, spec_f, cap=30.0)
    want = A.block_sparse_attention(q, k, v, spec_c, cap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-6)


def test_fused_empty_block_row_zero_context():
    """A block-row whose schedule holds only sentinel slots (no stored
    blocks at all) must produce exactly-zero context — the fused analogue
    of the composed path's clamped empty-row softmax."""
    L, d, h = 8, 4, 4
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((1, L, d)), jnp.float32)
               for _ in range(3))
    # row 0 stores block (0,0) fully unmasked; row 1 stores NOTHING
    emask = np.ones((1, h, h), np.float32)
    flat_idx = np.array([0, 1], np.int32)     # row 1 -> sentinel (nnzb=1)
    flat_col = np.array([0, 0], np.int32)
    out = bcsr_attn.bcsr_attn_fused(
        q, k, v, emask, flat_idx, flat_col, n_block_rows=2, n_block_cols=2,
        block=(h, h), scale=0.5, interpret=True)
    s = (q[0, :h] @ k[0, :h].T) * 0.5
    p = jax.nn.softmax(s, axis=-1)
    np.testing.assert_allclose(np.asarray(out[0, :h]),
                               np.asarray(p @ v[0, :h]), atol=1e-5)
    assert bool(jnp.all(out[0, h:] == 0.0))


# ========================================================== gradient parity
@pytest.mark.parametrize("mask_kind", ["banded", "blockwise_causal"])
def test_fused_gradients_match_composed(mask_kind):
    """Backward rides the composed dual-VJP route, so grads through the
    fused op must match differentiating the composed path directly."""
    q, k, v = _qkv(64, 8, seed=3)
    spec_f, spec_c = _specs(MASKS[mask_kind])

    def loss(spec):
        return lambda q, k, v: jnp.sum(
            A.block_sparse_attention(q, k, v, spec) ** 2)

    gf = jax.grad(loss(spec_f), argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(loss(spec_c), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
        assert float(jnp.abs(a).sum()) > 0


# ================================================= v7 fingerprints + dispatch
def test_v6_attn_key_pinned_and_never_aliases():
    """The v7 ``op=attn`` key layout is a cross-process cache contract,
    and fused/composed picks live in a key space disjoint from the
    composed path's sddmm/spmm picks over the SAME structure."""
    fp = autotune.Fingerprint(
        n_block_rows=4, n_block_cols=5, block=(16, 16), nnzb=10,
        pad_bucket=1, skew_bucket=2, n_bucket=64, reorder="jaccard",
        n_shards=2, max_bpr=3, op="attn")
    assert fp.key() == ("v7|op=attn|nbr=4|nbc=5|b=16x16|nnzb=10|pad=1"
                        "|skew=2|n=64|ro=jaccard|ns=2|mb=3|nk=1")

    meta = A.attention_mask_meta(A.banded(24), 64, (8, 8))
    keys = {op: autotune.fingerprint(meta, 8, op=op).key()
            for op in ("attn", "sddmm", "spmm")}
    assert len(set(keys.values())) == 3
    assert keys["attn"].startswith("v7|op=attn|")
    # a cached attn pick is invisible to the composed families
    tuner = autotune.get_autotuner()
    tuner.put(autotune.fingerprint(meta, 8, op="attn"),
              autotune.KernelChoice("attn_fused", 512), persist=False)
    assert tuner.get(autotune.fingerprint(meta, 8, op="sddmm")) is None
    assert tuner.get(autotune.fingerprint(meta, 8, op="spmm")) is None


def test_attn_family_registered_and_defaults_composed():
    assert set(autotune.variant_names("attn")) == {"attn_fused",
                                                   "attn_composed"}
    assert autotune.default_variant("attn") == "attn_composed"
    meta = A.attention_mask_meta(A.banded(24), 64, (8, 8))
    pick = autotune.get_autotuner().pick(meta, 8, op="attn")
    assert pick.variant in autotune.variant_names("attn")


def test_auto_backend_selects_fused_and_matches():
    """``backend="auto"`` must surface the fused kernel through the
    ``op=attn`` pick for a typical banded mask (the analytic model: one
    launch + no probs traffic beats three launches), and the result must
    still equal the composed reference bitwise."""
    mask = A.banded(24)
    spec_a = A.AttnSparsitySpec(mask=mask, block=(8, 8), backend="auto",
                                interpret=True)
    assert A.resolve_attn_impl(spec_a, 64, 8) == "fused"
    q, k, v = _qkv(64, 8)
    _, spec_c = _specs(mask)
    got = A.block_sparse_attention(q, k, v, spec_a)
    want = A.block_sparse_attention(q, k, v, spec_c)
    assert bool(jnp.all(got == want))


def test_explicit_and_sharded_specs_stay_composed():
    mask = A.banded(24)
    for backend in ("xla", "pallas", "row_loop", "dense"):
        spec = A.AttnSparsitySpec(mask=mask, block=(8, 8), backend=backend)
        assert A.resolve_attn_impl(spec, 64, 8) == "composed"
    sharded = A.AttnSparsitySpec(mask=mask, block=(8, 8), backend="fused",
                                 interpret=True, shards=2)
    assert A.resolve_attn_impl(sharded, 64, 8) == "composed"
    # ...and the sharded composed fallback still agrees with the
    # unsharded composed math (backend "fused" normalized to "auto")
    q, k, v = _qkv(64, 8)
    got = A.block_sparse_attention(q, k, v, sharded)
    want = A.block_sparse_attention(q, k, v, _specs(mask)[1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_under_jit_and_report_fields():
    """The fused dispatch is trace-safe (static info only) and the
    dry-run report carries the attention-level resolution."""
    mask = A.banded(24)
    spec = A.AttnSparsitySpec(mask=mask, block=(8, 8), backend="auto",
                              interpret=True)
    q, k, v = _qkv(64, 8)
    out = jax.jit(lambda q, k, v: A.block_sparse_attention(q, k, v, spec))(
        q, k, v)
    ref = A.block_sparse_attention(q, k, v, _specs(mask)[1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    rep = A.attention_mask_report(spec, 64, head_dim=8)
    assert rep["attn_impl"] == "fused"
    assert rep["attn_pick"] in autotune.variant_names("attn")
    # explicit kernel backends report the composed resolution
    rep_x = A.attention_mask_report(
        dataclasses.replace(spec, backend="xla"), 64, head_dim=8)
    assert rep_x["attn_impl"] == "composed"


def test_fused_schedule_matches_ops_row_loop_schedule():
    """The host schedule the fused path memoizes must be the exact
    (flat_idx, flat_col) layout ``ops._sddmm_row_loop_schedule`` builds —
    one schedule contract across the composed and fused kernels."""
    arrays, meta = A.attention_mask_arrays(A.local_global(16, 8), 61, (8, 8))
    emask, flat_idx, flat_col, meta2 = A._fused_inputs(
        A.local_global(16, 8), 61, (8, 8))
    assert meta2 == meta
    ref_idx, ref_col = ops._sddmm_row_loop_schedule(
        jnp.asarray(arrays.row_ids), jnp.asarray(arrays.col_ids),
        meta.n_block_rows, meta.max_bpr)
    np.testing.assert_array_equal(flat_idx, np.asarray(ref_idx))
    np.testing.assert_array_equal(flat_col, np.asarray(ref_col))
    assert emask.shape == arrays.vals.shape
