"""Autotuned dispatch tests: registry, fingerprints, analytic + measured
picks, JSON cache persistence, and the ``backend="auto"`` wiring through
``ops.spmm`` and ``SparsitySpec``."""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcsr as bcsr_lib
from repro.core.sparse_linear import (SparsitySpec, apply_sparse_linear,
                                      init_sparse_linear)
from repro.kernels import autotune, ops


@pytest.fixture(autouse=True)
def _fresh_tuner():
    """Isolate the process-wide tuner per test."""
    autotune.set_autotuner(autotune.Autotuner())
    yield
    autotune.set_autotuner(None)


def _mk(seed=0, shape=(96, 128), block=(16, 16), density=0.3):
    return bcsr_lib.random_bcsr(seed, shape, block,
                                density).ensure_nonempty_rows()


# ------------------------------------------------------------------ registry
def test_registry_has_all_variants():
    names = autotune.variant_names()
    for want in ("nnz_stream", "row_loop", "xla", "dense"):
        assert want in names
    for n in names:
        v = autotune.get_variant(n)
        assert v.backend in ops.BACKENDS
        assert v.bn_candidates


def test_register_duplicate_rejected():
    v = autotune.get_variant("xla")
    with pytest.raises(ValueError):
        autotune.register_variant(v)


# --------------------------------------------------------------- fingerprint
def test_fingerprint_meta_matches_bcsr():
    a = _mk()
    _, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    assert (autotune.fingerprint(meta, 64).key()
            == autotune.fingerprint_bcsr(a, 64).key())


def test_fingerprint_buckets_n():
    a = _mk()
    _, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    assert (autotune.fingerprint(meta, 65).key()
            == autotune.fingerprint(meta, 128).key())
    assert (autotune.fingerprint(meta, 64).key()
            != autotune.fingerprint(meta, 128).key())


# ------------------------------------------------------------ analytic picks
def test_analytic_choice_is_registered_and_supported():
    a = _mk()
    _, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    c = autotune.analytic_choice(meta, 256)
    v = autotune.get_variant(c.variant)
    assert v.supported(meta)
    assert c.bn in v.bn_candidates
    assert c.source == "analytic"


def test_analytic_choice_skips_row_loop_without_max_bpr():
    # hand-built meta (specs path): max_bpr unknown
    meta = ops.SparseMeta(shape=(128, 128), block=(16, 16), n_block_rows=8,
                          n_block_cols=8, nnzb=16, nnzb_t=16)
    c = autotune.analytic_choice(meta, 128)
    assert c.variant != "row_loop"


def test_pick_caches_in_memory():
    a = _mk()
    _, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    t = autotune.get_autotuner()
    c1 = t.pick(meta, 64)
    assert len(t) == 1
    assert t.pick(meta, 64) is c1


# ----------------------------------------------------------- measured sweeps
def test_tune_never_slower_than_default_and_persists(tmp_path):
    cache = tmp_path / "autotune.json"
    tuner = autotune.Autotuner(cache_path=str(cache))
    a = _mk(seed=2, shape=(128, 128), density=0.2)
    choice, timings = tuner.tune(a, 64, iters=2)
    assert choice.source == "measured"
    default_label = f"{autotune.DEFAULT_VARIANT}/bn{autotune.DEFAULT_BN}"
    tuned_label = f"{choice.variant}/bn{choice.bn}"
    assert default_label in timings
    # acceptance gate: the cached pick is never slower than the hardcoded
    # default (2% tie-break band)
    assert timings[tuned_label] <= timings[default_label] * 1.02

    # persisted and reloaded by a fresh tuner
    payload = json.loads(cache.read_text())
    assert payload["version"] == 1 and payload["entries"]
    tuner2 = autotune.Autotuner(cache_path=str(cache))
    fp = autotune.fingerprint_bcsr(a, 64)
    hit = tuner2.get(fp)
    assert hit is not None
    assert (hit.variant, hit.bn, hit.source) == (choice.variant, choice.bn,
                                                 "measured")


def test_corrupt_cache_tolerated(tmp_path):
    cache = tmp_path / "bad.json"
    cache.write_text("{not json")
    tuner = autotune.Autotuner(cache_path=str(cache))
    assert len(tuner) == 0


# ---------------------------------------------------------------- ops wiring
def test_spmm_auto_matches_oracle():
    a = _mk(seed=3)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    b = jnp.asarray(np.random.default_rng(5).standard_normal(
        (a.shape[1], 64)).astype(np.float32))
    want = ops.spmm(arrays, meta, b, backend="xla")
    got = ops.spmm(arrays, meta, b, backend="auto", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_spmm_auto_uses_measured_cache_entry():
    a = _mk(seed=4)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    n = 64
    choice, _ = autotune.get_autotuner().tune(a, n, iters=1)
    backend, bn = ops.resolve_backend("auto", 512, meta, n)
    assert backend == autotune.get_variant(choice.variant).backend
    assert bn == choice.bn


def test_spmm_row_loop_matches_oracle_and_grads():
    a = _mk(seed=6, shape=(64, 96), density=0.4)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    assert meta.max_bpr > 0
    b = jnp.asarray(np.random.default_rng(7).standard_normal(
        (a.shape[1], 32)).astype(np.float32))
    want = ops.spmm(arrays, meta, b, backend="xla")
    got = ops.spmm(arrays, meta, b, backend="row_loop", bn=32,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    def loss(vals, bb, be):
        arr = ops.SparseArrays(vals, *arrays[1:])
        return jnp.sum(ops.spmm(arr, meta, bb, backend=be, bn=32,
                                interpret=True) ** 2)

    g_rl = jax.grad(loss, argnums=(0, 1))(arrays.vals, b, "row_loop")
    g_x = jax.grad(loss, argnums=(0, 1))(arrays.vals, b, "xla")
    for got_g, want_g in zip(g_rl, g_x):
        np.testing.assert_allclose(np.asarray(got_g), np.asarray(want_g),
                                   rtol=1e-3, atol=1e-3)


def test_backend_alias_and_unknown():
    a = _mk(seed=8)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    assert ops.resolve_backend("nnz_stream", 256, meta, 64) == ("pallas", 256)
    with pytest.raises(ValueError, match="unknown backend"):
        ops.resolve_backend("cuda", 256, meta, 64)


def test_explicit_row_loop_without_max_bpr_raises():
    meta = ops.SparseMeta(shape=(128, 128), block=(16, 16), n_block_rows=8,
                          n_block_cols=8, nnzb=16, nnzb_t=16)
    # explicit request cannot be honored -> loud failure, not a silent
    # switch to a different kernel than the caller asked to measure
    with pytest.raises(ValueError, match="max_bpr"):
        ops.resolve_backend("row_loop", 512, meta, 128)
    # auto never proposes it for such metas (supported() gate)
    assert ops.resolve_backend("auto", 512, meta, 128)[0] != "row_loop"


# -------------------------------------------------------- SparsitySpec wiring
def test_sparse_linear_auto_backend():
    x = jnp.asarray(np.random.default_rng(4).standard_normal(
        (2, 8, 64)).astype(np.float32))
    n_tokens = x.shape[0] * x.shape[1]
    spec = SparsitySpec(density=0.3, block=(16, 16), backend="auto",
                        bn=64, interpret=True, tune_n=n_tokens)
    params, meta = init_sparse_linear(0, 64, 96, spec, dtype=jnp.float32)
    # the warmed bucket is the one apply-time dispatch actually hits
    warmed = autotune.get_autotuner().pick(meta, n_tokens)
    assert warmed.source == "measured"
    y = apply_sparse_linear(params, meta, x, spec)
    assert y.shape == (2, 8, 96)
    ref_spec = SparsitySpec(density=0.3, block=(16, 16), backend="xla",
                            bn=64)
    y_ref = apply_sparse_linear(params, meta, x, ref_spec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
