"""BCSR invariants the autotuner's fingerprint + dispatch rely on:
transpose round-trips, row padding preserves the operator, and the two
``from_csr`` construction paths (scipy fast path / pure-numpy fallback)
agree exactly."""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import bcsr as bcsr_lib


def _random_csr(seed, shape, density=0.15):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal(shape).astype(np.float32)
    dense[rng.random(shape) > density] = 0
    return sp.csr_matrix(dense), dense


# ------------------------------------------------------------------ transpose
@pytest.mark.parametrize("shape,block", [((96, 64), (16, 16)),
                                         ((60, 100), (8, 16)),
                                         ((128, 128), (32, 8))])
def test_transpose_matches_dense_transpose(shape, block):
    a = bcsr_lib.random_bcsr(7, shape, block, 0.35, fill_density=0.7)
    at = a.transpose()
    np.testing.assert_array_equal(at.to_dense(), a.to_dense().T)
    assert at.shape == (shape[1], shape[0])
    assert at.block == (block[1], block[0])


def test_transpose_round_trip_identity():
    a = bcsr_lib.random_bcsr(11, (80, 112), (16, 16), 0.3)
    att = a.transpose().transpose()
    np.testing.assert_array_equal(att.to_dense(), a.to_dense())
    assert att.nnzb == a.nnzb
    # canonical ordering restored (row-major, rows sorted)
    assert np.all(np.diff(att.row_ids) >= 0)
    np.testing.assert_array_equal(att.rowptr, a.rowptr)


# ------------------------------------------------------- ensure_nonempty_rows
def test_ensure_nonempty_rows_preserves_product():
    # many empty block-rows: tall matrix, low density
    a = bcsr_lib.random_bcsr(3, (256, 64), (16, 16), 0.08)
    assert (a.blocks_per_row() == 0).any(), "want empty rows in the fixture"
    a_p = a.ensure_nonempty_rows()
    assert np.all(a_p.blocks_per_row() >= 1)
    b = np.random.default_rng(4).standard_normal((64, 24)).astype(np.float32)
    np.testing.assert_allclose(a_p.to_dense() @ b, a.to_dense() @ b,
                               rtol=1e-6, atol=1e-6)
    # padding adds all-zero blocks only — nnz (true nonzeros) is unchanged
    assert a_p.nnz == a.nnz
    assert a_p.nnzb >= a.nnzb


def test_ensure_nonempty_rows_idempotent():
    a = bcsr_lib.random_bcsr(5, (128, 64), (16, 16), 0.1)
    a_p = a.ensure_nonempty_rows()
    assert a_p.ensure_nonempty_rows() is a_p


# ----------------------------------------------------------- from_csr paths
@pytest.mark.parametrize("shape,block", [((64, 64), (16, 16)),
                                         ((100, 72), (8, 16))])
def test_from_csr_scipy_and_numpy_paths_agree(monkeypatch, shape, block):
    csr, dense = _random_csr(9, shape)
    via_scipy = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data,
                                  csr.shape, block)
    monkeypatch.setattr(bcsr_lib, "_sp", None)
    via_numpy = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data,
                                  csr.shape, block)
    assert via_scipy.nnzb == via_numpy.nnzb
    np.testing.assert_array_equal(via_scipy.row_ids, via_numpy.row_ids)
    np.testing.assert_array_equal(via_scipy.col_ids, via_numpy.col_ids)
    np.testing.assert_array_equal(via_scipy.rowptr, via_numpy.rowptr)
    np.testing.assert_array_equal(via_scipy.vals, via_numpy.vals)
    np.testing.assert_array_equal(via_scipy.to_dense(), dense)


def test_from_csr_matches_from_dense_blocking():
    csr, dense = _random_csr(10, (96, 96), density=0.2)
    a = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data, csr.shape,
                          (16, 16))
    b = bcsr_lib.from_dense(dense, (16, 16))
    assert a.nnzb == b.nnzb
    np.testing.assert_array_equal(a.to_dense(), b.to_dense())


@pytest.mark.parametrize("use_scipy", [True, False])
def test_from_csr_accumulates_duplicates(monkeypatch, use_scipy):
    """Duplicate COO coordinates must SUM (scipy sum_duplicates parity),
    not keep only the last-written value."""
    rng = np.random.default_rng(12)
    n_entries = 200
    rows = rng.integers(0, 48, n_entries)
    cols = rng.integers(0, 64, n_entries)
    data = rng.standard_normal(n_entries).astype(np.float32)
    # force collisions: repeat a third of the coordinates
    rows = np.concatenate([rows, rows[:70]])
    cols = np.concatenate([cols, cols[:70]])
    data = np.concatenate([data, rng.standard_normal(70).astype(np.float32)])
    # hand-build CSR arrays WITH duplicate column entries per row
    # (scipy's constructors would silently pre-sum them)
    order = np.argsort(rows, kind="stable")
    rows_s, indices, data_s = rows[order], cols[order], data[order]
    indptr = np.zeros(49, np.int64)
    np.add.at(indptr, rows_s + 1, 1)
    indptr = np.cumsum(indptr)
    want = sp.coo_matrix((data, (rows, cols)), shape=(48, 64)).tocsr()
    want.sum_duplicates()
    if not use_scipy:
        monkeypatch.setattr(bcsr_lib, "_sp", None)
    a = bcsr_lib.from_csr(indptr, indices, data_s, (48, 64), (16, 16))
    np.testing.assert_allclose(a.to_dense(), want.toarray(),
                               rtol=1e-6, atol=1e-6)


def test_ensure_nonempty_rows_return_mask_tags_padding_only():
    """real_mask=False exactly on the appended padding entries: genuinely
    zero ORIGINAL blocks must stay real (trainable)."""
    a = bcsr_lib.random_bcsr(6, (256, 64), (16, 16), 0.08, fill_density=0.5)
    # manufacture a genuinely-zero stored block
    a.vals[0][:] = 0
    assert (a.blocks_per_row() == 0).any(), "want empty rows in the fixture"
    a_p, real = a.ensure_nonempty_rows(return_mask=True)
    assert real.sum() == a.nnzb                  # every original entry real
    zero_blocks = np.abs(a_p.vals).sum(axis=(1, 2)) == 0
    # some real entries ARE zero blocks (the one we zeroed) — the old
    # nonzero-content heuristic would have dropped them
    assert (real & zero_blocks).any()
    # padding entries are all zero blocks in previously-empty rows
    bpr0 = a.blocks_per_row()
    for s in np.flatnonzero(~real):
        assert zero_blocks[s]
        assert bpr0[a_p.row_ids[s]] == 0
