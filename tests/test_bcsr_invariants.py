"""BCSR invariants the autotuner's fingerprint + dispatch rely on:
transpose round-trips, row padding preserves the operator, and the two
``from_csr`` construction paths (scipy fast path / pure-numpy fallback)
agree exactly."""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import bcsr as bcsr_lib


def _random_csr(seed, shape, density=0.15):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal(shape).astype(np.float32)
    dense[rng.random(shape) > density] = 0
    return sp.csr_matrix(dense), dense


# ------------------------------------------------------------------ transpose
@pytest.mark.parametrize("shape,block", [((96, 64), (16, 16)),
                                         ((60, 100), (8, 16)),
                                         ((128, 128), (32, 8))])
def test_transpose_matches_dense_transpose(shape, block):
    a = bcsr_lib.random_bcsr(7, shape, block, 0.35, fill_density=0.7)
    at = a.transpose()
    np.testing.assert_array_equal(at.to_dense(), a.to_dense().T)
    assert at.shape == (shape[1], shape[0])
    assert at.block == (block[1], block[0])


def test_transpose_round_trip_identity():
    a = bcsr_lib.random_bcsr(11, (80, 112), (16, 16), 0.3)
    att = a.transpose().transpose()
    np.testing.assert_array_equal(att.to_dense(), a.to_dense())
    assert att.nnzb == a.nnzb
    # canonical ordering restored (row-major, rows sorted)
    assert np.all(np.diff(att.row_ids) >= 0)
    np.testing.assert_array_equal(att.rowptr, a.rowptr)


# ------------------------------------------------------- ensure_nonempty_rows
def test_ensure_nonempty_rows_preserves_product():
    # many empty block-rows: tall matrix, low density
    a = bcsr_lib.random_bcsr(3, (256, 64), (16, 16), 0.08)
    assert (a.blocks_per_row() == 0).any(), "want empty rows in the fixture"
    a_p = a.ensure_nonempty_rows()
    assert np.all(a_p.blocks_per_row() >= 1)
    b = np.random.default_rng(4).standard_normal((64, 24)).astype(np.float32)
    np.testing.assert_allclose(a_p.to_dense() @ b, a.to_dense() @ b,
                               rtol=1e-6, atol=1e-6)
    # padding adds all-zero blocks only — nnz (true nonzeros) is unchanged
    assert a_p.nnz == a.nnz
    assert a_p.nnzb >= a.nnzb


def test_ensure_nonempty_rows_idempotent():
    a = bcsr_lib.random_bcsr(5, (128, 64), (16, 16), 0.1)
    a_p = a.ensure_nonempty_rows()
    assert a_p.ensure_nonempty_rows() is a_p


# ----------------------------------------------------------- from_csr paths
@pytest.mark.parametrize("shape,block", [((64, 64), (16, 16)),
                                         ((100, 72), (8, 16))])
def test_from_csr_scipy_and_numpy_paths_agree(monkeypatch, shape, block):
    csr, dense = _random_csr(9, shape)
    via_scipy = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data,
                                  csr.shape, block)
    monkeypatch.setattr(bcsr_lib, "_sp", None)
    via_numpy = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data,
                                  csr.shape, block)
    assert via_scipy.nnzb == via_numpy.nnzb
    np.testing.assert_array_equal(via_scipy.row_ids, via_numpy.row_ids)
    np.testing.assert_array_equal(via_scipy.col_ids, via_numpy.col_ids)
    np.testing.assert_array_equal(via_scipy.rowptr, via_numpy.rowptr)
    np.testing.assert_array_equal(via_scipy.vals, via_numpy.vals)
    np.testing.assert_array_equal(via_scipy.to_dense(), dense)


def test_from_csr_matches_from_dense_blocking():
    csr, dense = _random_csr(10, (96, 96), density=0.2)
    a = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data, csr.shape,
                          (16, 16))
    b = bcsr_lib.from_dense(dense, (16, 16))
    assert a.nnzb == b.nnzb
    np.testing.assert_array_equal(a.to_dense(), b.to_dense())
