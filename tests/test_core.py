"""Core library tests: BCSR format invariants, reordering, perf model,
sparse linear layer (incl. hypothesis property tests)."""
import numpy as np
import scipy.sparse as sp
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bcsr as bcsr_lib
from repro.core import perf_model, reorder, topology
from repro.core.sparse_linear import (SparsitySpec, apply_sparse_linear,
                                      init_sparse_linear,
                                      sparse_linear_specs)
from repro.kernels import ops


# ------------------------------------------------------------------ BCSR core
def test_from_dense_roundtrip():
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((60, 90)).astype(np.float32)
    dense[np.abs(dense) < 1.2] = 0
    a = bcsr_lib.from_dense(dense, (8, 16))
    np.testing.assert_array_equal(a.to_dense(), dense)


def test_from_csr_matches_from_dense():
    rng = np.random.default_rng(1)
    dense = rng.standard_normal((64, 64)).astype(np.float32)
    dense[np.abs(dense) < 1.0] = 0
    csr = sp.csr_matrix(dense)
    a = bcsr_lib.from_csr(csr.indptr, csr.indices, csr.data, csr.shape,
                          (16, 16))
    b = bcsr_lib.from_dense(dense, (16, 16))
    np.testing.assert_array_equal(a.to_dense(), b.to_dense())
    assert a.nnzb == b.nnzb


def test_transpose_structure():
    a = bcsr_lib.random_bcsr(2, (96, 64), (16, 16), 0.4)
    at = a.transpose()
    np.testing.assert_allclose(at.to_dense(), a.to_dense().T)
    # sorted row-major
    assert np.all(np.diff(at.row_ids) >= 0)


def test_eq2_bounds():
    a = bcsr_lib.random_bcsr(3, (128, 128), (16, 16), 0.3, fill_density=0.5)
    lo, hi = a.block_bounds()
    assert lo <= a.nnzb <= hi


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 8), k=st.integers(2, 8),
    h=st.sampled_from([4, 8]), w=st.sampled_from([4, 8]),
    density=st.floats(0.05, 0.9), seed=st.integers(0, 1000),
)
def test_property_bcsr_roundtrip_and_bounds(m, k, h, w, density, seed):
    """Property: from_dense/to_dense roundtrip exactly; Eq.2 bounds hold;
    ensure_nonempty_rows preserves the dense matrix and kills empty rows."""
    a = bcsr_lib.random_bcsr(seed, (m * h, k * w), (h, w), density)
    dense = a.to_dense()
    b = bcsr_lib.from_dense(dense, (h, w))
    np.testing.assert_array_equal(b.to_dense(), dense)
    lo, hi = b.block_bounds()
    assert lo <= max(b.nnzb, 1) and b.nnzb <= hi + 1
    c = a.ensure_nonempty_rows()
    np.testing.assert_array_equal(c.to_dense(), dense)
    assert np.all(np.diff(c.rowptr) >= 1)
    assert np.all(np.diff(c.row_ids) >= 0)          # still sorted


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), density=st.floats(0.1, 0.6))
def test_property_spmm_linear(seed, density):
    """Property: SpMM is linear — A(x+y) == Ax + Ay and A(cx) == c Ax."""
    a = bcsr_lib.random_bcsr(seed, (32, 48), (8, 8), density)
    a = a.ensure_nonempty_rows()
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((48, 8)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((48, 8)).astype(np.float32))
    f = lambda v: ops.spmm(arrays, meta, v, backend="xla")
    np.testing.assert_allclose(np.asarray(f(x + y)),
                               np.asarray(f(x) + f(y)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f(3.0 * x)),
                               np.asarray(3.0 * f(x)), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ reordering
def test_jaccard_reduces_blocks_on_clustered_matrix():
    csr = topology.blocked_random(n=768, nnz_target=12_000, cluster=32,
                                  seed=0)
    block = (16, 16)
    before = bcsr_lib.from_scipy(csr, block).nnzb
    perm = reorder.jaccard_rows(csr, block_w=16, tau=0.7)
    after = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm), block).nnzb
    assert sorted(perm.tolist()) == list(range(csr.shape[0]))  # permutation
    assert after < before, (before, after)


def test_jaccard_identity_on_band_matrix():
    """Paper IV-C: band matrices are already block-dense; reordering must not
    blow up the block count (it may perturb slightly)."""
    csr = topology.band(512, 16)
    block = (16, 16)
    before = bcsr_lib.from_scipy(csr, block).nnzb
    perm = reorder.jaccard_rows(csr, block_w=16, tau=0.7)
    after = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm), block).nnzb
    assert after <= before * 1.6


def test_rcm_permutation_valid():
    csr = topology.power_law(512, 6.0, seed=1)
    perm = reorder.rcm(csr)
    assert sorted(perm.tolist()) == list(range(512))


def test_shard_balance_reduces_imbalance():
    a = bcsr_lib.from_scipy(topology.power_law(1024, 8.0, seed=2), (16, 16))
    n_shards = 8
    bpr = a.blocks_per_row()

    def shard_loads(order):
        per = np.array_split(order, n_shards)
        return np.array([bpr[idx].sum() for idx in per])

    natural = shard_loads(np.arange(a.n_block_rows))
    balanced = shard_loads(reorder.shard_balance(a.row_ids, a.rowptr,
                                                 n_shards))
    assert balanced.std() <= natural.std()


# ------------------------------------------------------------------ perf model
def test_perf_model_fit_recovers_linear():
    rng = np.random.default_rng(3)
    n_e = np.linspace(100, 10000, 20)
    t = 3e-6 * n_e + 2e-4 + rng.normal(0, 1e-6, 20)
    f = perf_model.fit(n_e, t)
    assert abs(f.t_e - 3e-6) / 3e-6 < 0.05
    assert f.r2 > 0.99


def test_block_roofline_sane():
    t_c, t_m, t_e = perf_model.block_mma_time(128, 128, 512)
    assert t_e == max(t_c, t_m) > 0
    # dense crossover: at high density BCSR time ~ dense time
    m = k = 16384
    t_dense = perf_model.dense_gemm_time(m, k, 128)
    n_e_full = (m // 128) * (k // 128)
    t_sparse_full = perf_model.spmm_model_time(n_e_full, 128, 128, 128)
    assert 0.2 < t_sparse_full / t_dense < 5


# ------------------------------------------------------------- sparse linear
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sparse_linear_forward_and_grad(backend):
    spec = SparsitySpec(density=0.3, block=(16, 16), backend=backend,
                        bn=128, interpret=True)
    params, meta = init_sparse_linear(0, 64, 96, spec, dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(4).standard_normal(
        (2, 8, 64)).astype(np.float32))
    y = apply_sparse_linear(params, meta, x, spec)
    assert y.shape == (2, 8, 96)
    dense_w = ops.materialize_dense(
        ops.SparseArrays(params["vals"], params["row_ids"],
                         params["col_ids"], params["real_mask"],
                         params["t_perm"], params["t_row_ids"],
                         params["t_col_ids"]), meta)[:96, :64]
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ dense_w.T), rtol=1e-3,
                               atol=1e-3)

    def loss(p):
        return jnp.sum(apply_sparse_linear(p, meta, x, spec) ** 2)

    # int/bool index leaves get float0 cotangents (the train step does the
    # same via allow_int)
    g = jax.grad(loss, allow_int=True)(params)
    assert g["vals"].shape == params["vals"].shape
    assert np.isfinite(np.asarray(g["vals"], np.float32)).all()
    assert float(jnp.abs(g["vals"]).sum()) > 0


def test_sparse_linear_specs_match_init():
    spec = SparsitySpec(density=0.25, block=(16, 16))
    params, meta = init_sparse_linear(1, 128, 128, spec)
    specs, meta_s = sparse_linear_specs(128, 128, spec)
    assert meta.nnzb == meta_s.nnzb
    assert meta.shape == meta_s.shape
    for k in params:
        assert params[k].shape == specs[k].shape, k
        assert params[k].dtype == specs[k].dtype, k
