"""Sharded SpMM execution tests (``launch.dist_spmm``).

Equivalence vs the single-device reference across shard counts {1, 2, 4, 8}
— forward within dtype tolerance and the VJP (dvals on the real support,
dB) — including ragged block-row counts, a partial trailing block-row, and
empty shards; plus the overlap chunk pipeline (bit-identical across chunk
depths, local and shard_map), the heavy-row guard and entry-granular
splits, the shard-count autotune axis (``resolve_n_shards`` determinism +
cache round-trip), the shard_bins occupancy invariants, the v7 autotune
fingerprint, the mixed-variant lax.switch path, and the model wiring
(``SparsitySpec(shards=...)`` including ``shards="auto"``).

shard_map cases need real devices: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``test-multidevice`` job does); on fewer devices they skip, the local-mode
equivalences still run.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcsr as bcsr_lib
from repro.core import permute, topology
from repro.core.sparse_linear import (SparsitySpec, apply_sparse_linear,
                                      init_sparse_linear,
                                      sparse_linear_specs)
from repro.kernels import autotune, ops
from repro.launch import dist_spmm

SHARD_COUNTS = (1, 2, 4, 8)


def _cases():
    """(name, BCSR) — ragged row count + partial trailing block-row, skewed
    power-law (empty element rows), and a clustered structure."""
    return [
        ("ragged_partial", bcsr_lib.random_bcsr(0, (23 * 16 + 5, 160),
                                                (16, 16), 0.3)),
        ("power_law_skew", bcsr_lib.from_scipy(
            topology.power_law(500, 5.0, seed=2), (16, 16))),
        ("clustered", bcsr_lib.from_scipy(
            topology.blocked_random(n=512, nnz_target=9000, cluster=16,
                                    seed=1), (16, 16))),
    ]


def _ref(a, b):
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    return arrays, meta, ops.spmm(arrays, meta, b, backend="xla")


def _b_for(a, n=48, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((a.shape[1], n)).astype(np.float32))


# ------------------------------------------------------------ bin assignment
def test_shard_bins_occupancy_invariants():
    """Every block-row lands in exactly one bin, cardinality caps hold, and
    the LPT loads beat (or match) a naive contiguous split on skew."""
    a = bcsr_lib.from_scipy(topology.power_law(800, 6.0, seed=3), (16, 16))
    a_p = a.ensure_nonempty_rows()
    bpr = np.diff(a_p.rowptr)
    for S in (2, 4, 8):
        rps = -(-a_p.n_block_rows // S)
        assign = permute.shard_bins(bpr, S, rows_per_shard=rps)
        assert assign.shape == (a_p.n_block_rows,)
        assert assign.min() >= 0 and assign.max() < S
        counts = np.bincount(assign, minlength=S)
        assert counts.max() <= rps
        assert counts.sum() == a_p.n_block_rows
        loads = np.asarray([bpr[assign == s].sum() for s in range(S)])
        assert loads.sum() == a_p.nnzb
        contig = np.asarray([bpr[s * rps:(s + 1) * rps].sum()
                             for s in range(S)])
        assert loads.max() <= contig.max()


def test_shard_bins_capacity_raises():
    with pytest.raises(ValueError, match="budget|capacity|cannot fit"):
        permute.shard_bins(np.asarray([10, 10, 10, 10]), 2,
                           rows_per_shard=2, max_load=12)


def test_prepare_sharded_budget_raises():
    a = bcsr_lib.random_bcsr(0, (128, 128), (16, 16), 0.5)
    with pytest.raises(ValueError):
        dist_spmm.prepare_sharded(a, 2, nnzb_per_shard=2)


def test_shard_balance_stats_beats_contiguous():
    a = bcsr_lib.from_scipy(topology.power_law(800, 6.0, seed=3), (16, 16))
    st = dist_spmm.shard_balance_stats(a, 4)
    assert st["imbalance"] <= st["contig_imbalance"] + 1e-9
    assert sum(st["loads"]) == st["nnzb"]


# ------------------------------------------------------- local-mode equality
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sharded_fwd_matches_reference(n_shards, backend):
    for name, a in _cases():
        b = _b_for(a)
        _, _, ref = _ref(a, b)
        sharr, smeta = dist_spmm.prepare_sharded(a, n_shards,
                                                 dtype=jnp.float32)
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend=backend,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4, err_msg=name)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_sharded_grads_match_reference(n_shards):
    """dvals bit-comparable on the shared flat entry order; dB within fp
    tolerance (summation order differs across shards)."""
    a = bcsr_lib.from_scipy(topology.power_law(500, 5.0, seed=2), (16, 16))
    b = _b_for(a)
    arrays, meta, _ = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, n_shards, dtype=jnp.float32)

    def loss_sh(v, bb):
        out = dist_spmm.spmm_sharded(sharr._replace(vals=v), smeta, bb,
                                     backend="xla")
        return jnp.sum(out ** 2)

    def loss_ref(v, bb):
        arr = ops.SparseArrays(v, *arrays[1:])
        return jnp.sum(ops.spmm(arr, meta, bb, backend="xla") ** 2)

    gv, gb = jax.grad(loss_sh, argnums=(0, 1))(sharr.vals, b)
    rv, rb = jax.grad(loss_ref, argnums=(0, 1))(arrays.vals, b)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                               rtol=1e-4, atol=1e-3)


def test_empty_shards_more_shards_than_rows():
    a = bcsr_lib.random_bcsr(1, (30, 64), (16, 16), 0.5)  # 2 block-rows
    b = _b_for(a, n=8)
    _, _, ref = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 8, dtype=jnp.float32)
    out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_pre_reorder_composes_with_partition():
    """jaccard pre-permutation + partition: output still in ORIGINAL order."""
    a = bcsr_lib.from_scipy(
        topology.blocked_random(n=512, nnz_target=9000, cluster=16, seed=1),
        (16, 16))
    b = _b_for(a)
    _, _, ref = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32,
                                             reorder="jaccard")
    out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


# ----------------------------------------------------- fingerprint (v7)
def test_fingerprint_shard_count_no_alias():
    a = bcsr_lib.random_bcsr(0, (256, 256), (16, 16), 0.2)
    _, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    k_full = autotune.fingerprint(meta, 64).key()
    k_shard = autotune.fingerprint(smeta.shard_metas[0], 64).key()
    assert k_full.startswith("v7|") and k_shard.startswith("v7|")
    assert "ns=1" in k_full and "ns=4" in k_shard
    # the key carries the row_loop schedule bound (v4 field) — real stats
    # on both sides
    assert f"mb={meta.max_bpr}" in k_full and meta.max_bpr > 0
    assert k_full != k_shard
    # v7: the chunk-depth field keys shard-count decisions; default nk=1
    assert k_full.endswith("|nk=1")
    k_chunked = autotune.fingerprint(meta, 64, n_chunks=4).key()
    assert k_chunked.endswith("|nk=4") and k_chunked != k_full


def test_tune_shards_caches_measured_picks():
    """tune_shards (the SparsitySpec(tune_n=...) path for sharded layers)
    must leave a measured entry under every shard fingerprint, and auto
    dispatch must then match the reference."""
    a = bcsr_lib.from_scipy(topology.power_law(400, 5.0, seed=2), (16, 16))
    b = _b_for(a, n=32)
    _, _, ref = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 2, dtype=jnp.float32)
    tuner = autotune.Autotuner()
    old = autotune.get_autotuner()
    autotune.set_autotuner(tuner)
    try:
        tuned = dist_spmm.tune_shards(sharr, smeta, 32, iters=1,
                                      tuner=tuner)
        for m in smeta.shard_metas:
            hit = tuner.get(autotune.fingerprint(m, 32))
            assert hit is not None and hit.source == "measured"
        assert tuned
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="auto",
                                     interpret=True)
    finally:
        autotune.set_autotuner(old)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_per_shard_auto_choices_resolve():
    a = bcsr_lib.from_scipy(topology.power_law(500, 5.0, seed=2), (16, 16))
    _, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    choices = dist_spmm._resolve_shard_choices(smeta, 64, "auto", 512)
    assert len(choices) == 4
    for be, bn in choices:
        assert be in ops.BACKENDS and bn >= 1


# --------------------------------------------------------- shard_map mode
def _mesh_or_skip(n_shards, col_shards=1):
    if jax.device_count() < n_shards * col_shards:
        pytest.skip(f"needs {n_shards * col_shards} devices "
                    f"(have {jax.device_count()}); run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return dist_spmm.make_spmm_mesh(n_shards, col_shards)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_shard_map_matches_reference(n_shards):
    mesh = _mesh_or_skip(n_shards)
    for name, a in _cases():
        b = _b_for(a)
        _, _, ref = _ref(a, b)
        sharr, smeta = dist_spmm.prepare_sharded(a, n_shards,
                                                 dtype=jnp.float32)
        out = jax.jit(lambda v, bb, _s=sharr, _m=smeta, _me=mesh:
                      dist_spmm.spmm_sharded(_s._replace(vals=v), _m, bb,
                                             backend="xla", mesh=_me)
                      )(sharr.vals, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4, err_msg=name)


@pytest.mark.parametrize("n_shards", (2, 4, 8))
def test_shard_map_grads_match_reference(n_shards):
    mesh = _mesh_or_skip(n_shards)
    a = bcsr_lib.from_scipy(topology.power_law(500, 5.0, seed=2), (16, 16))
    b = _b_for(a)
    arrays, meta, _ = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, n_shards, dtype=jnp.float32)

    def loss_sh(v, bb):
        out = dist_spmm.spmm_sharded(sharr._replace(vals=v), smeta, bb,
                                     backend="pallas", interpret=True,
                                     mesh=mesh)
        return jnp.sum(out ** 2)

    def loss_ref(v, bb):
        arr = ops.SparseArrays(v, *arrays[1:])
        return jnp.sum(ops.spmm(arr, meta, bb, backend="xla") ** 2)

    gv, gb = jax.jit(jax.grad(loss_sh, argnums=(0, 1)))(sharr.vals, b)
    rv, rb = jax.grad(loss_ref, argnums=(0, 1))(arrays.vals, b)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                               rtol=1e-4, atol=1e-3)


def test_shard_map_2d_col_split():
    mesh = _mesh_or_skip(2, 2)
    a = bcsr_lib.from_scipy(topology.power_law(500, 5.0, seed=2), (16, 16))
    b = _b_for(a, n=50)          # N not divisible by col_shards: pads+trims
    _, _, ref = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 2, col_shards=2,
                                             dtype=jnp.float32)
    out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla", mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_mixed_variant_switch_dispatch():
    """Shards with different structure stats get DIFFERENT cached picks:
    the shard_map body must dispatch through lax.switch and still match
    the reference.  A well-balanced partition yields identical per-shard
    fingerprints (shared cache entry — by design), so this uses a skewed
    structure whose LPT bins genuinely differ."""
    mesh = _mesh_or_skip(2)
    dense = np.zeros((64, 512), np.float32)
    rng = np.random.default_rng(0)
    dense[:16, :480] = rng.standard_normal((16, 480))      # heavy block-row
    for r in range(1, 4):                                  # light rows
        dense[16 * r, 16 * r] = 1.0
    a = bcsr_lib.from_dense(dense, (16, 16))
    b = _b_for(a)
    _, _, ref = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 2, dtype=jnp.float32)
    fps = [autotune.fingerprint(m, 48).key() for m in smeta.shard_metas]
    assert fps[0] != fps[1]                   # stats really diverge
    tuner = autotune.Autotuner()
    for m, (variant, bn) in zip(smeta.shard_metas,
                                [("nnz_stream", 128), ("xla", 512)]):
        tuner.put(autotune.fingerprint(m, 48), autotune.KernelChoice(
            variant, bn, source="measured"), persist=False)
    old = autotune.get_autotuner()
    autotune.set_autotuner(tuner)
    try:
        choices = dist_spmm._resolve_shard_choices(smeta, 48, "auto", 512)
        assert len(set(choices)) > 1          # really a multi-branch switch
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="auto",
                                     interpret=True, mesh=mesh)
    finally:
        autotune.set_autotuner(old)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------------- overlap chunking (pipeline)
def test_chunk_schedule_contract():
    """The schedule partitions [0, n) exactly; depth clamps to n."""
    assert dist_spmm.chunk_schedule(10, 4) == ((0, 3), (3, 6), (6, 9),
                                               (9, 10))
    assert dist_spmm.chunk_schedule(8, 1) == ((0, 8),)
    assert dist_spmm.chunk_schedule(2, 8) == ((0, 1), (1, 2))
    with pytest.raises(ValueError):
        dist_spmm.chunk_schedule(0, 2)
    with pytest.raises(ValueError):
        dist_spmm.chunk_schedule(8, 0)


@pytest.mark.parametrize("n_chunks", (2, 4))
def test_chunked_local_bitwise(n_chunks):
    """Chunked dispatch concatenates disjoint column panels: the result is
    BIT-identical to the unchunked run (the overlap contract)."""
    for name, a in _cases():
        b = _b_for(a)
        sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
        base = np.asarray(dist_spmm.spmm_sharded(sharr, smeta, b,
                                                 backend="xla"))
        out = np.asarray(dist_spmm.spmm_sharded(sharr, smeta, b,
                                                backend="xla",
                                                n_chunks=n_chunks))
        assert np.array_equal(out.view(np.uint32), base.view(np.uint32)), \
            f"{name}: nk={n_chunks} diverged from unchunked"


@pytest.mark.parametrize("n_chunks", (2, 4))
def test_chunked_shard_map_bitwise(n_chunks):
    """Under a real mesh the staged all-gather pipeline must still emit
    the exact unchunked bits."""
    mesh = _mesh_or_skip(4)
    a = bcsr_lib.from_scipy(topology.power_law(500, 5.0, seed=2), (16, 16))
    b = _b_for(a)
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    base = np.asarray(dist_spmm.spmm_sharded(sharr, smeta, b,
                                             backend="xla", mesh=mesh))
    out = np.asarray(jax.jit(lambda bb: dist_spmm.spmm_sharded(
        sharr, smeta, bb, backend="xla", mesh=mesh,
        n_chunks=n_chunks))(b))
    assert np.array_equal(out.view(np.uint32), base.view(np.uint32))


def test_chunked_grads_route_through_unchunked_exec():
    """The chunked forward's custom VJP differentiates the unchunked exec:
    grads are bit-identical across chunk depths."""
    a = bcsr_lib.from_scipy(topology.power_law(300, 5.0, seed=2), (16, 16))
    b = _b_for(a)
    sharr, smeta = dist_spmm.prepare_sharded(a, 2, dtype=jnp.float32)

    def grads(k):
        def loss(v, bb):
            out = dist_spmm.spmm_sharded(sharr._replace(vals=v), smeta,
                                         bb, backend="xla", n_chunks=k)
            return jnp.sum(out ** 2)
        return jax.grad(loss, argnums=(0, 1))(sharr.vals, b)

    gv1, gb1 = grads(1)
    for k in (2, 4):
        gvk, gbk = grads(k)
        assert np.array_equal(np.asarray(gvk).view(np.uint32),
                              np.asarray(gv1).view(np.uint32))
        assert np.array_equal(np.asarray(gbk).view(np.uint32),
                              np.asarray(gb1).view(np.uint32))


# --------------------------------------- heavy rows: guard + entry splits
def _heavy_row_case():
    """One 64-block row towering over 3 single-block rows: under S=4 the
    balanced budget is ~18 blocks, so the heavy row alone blows it 3x."""
    dense = np.zeros((64, 1024), np.float32)
    rng = np.random.default_rng(0)
    dense[:16, :] = rng.standard_normal((16, 1024))
    for r in range(1, 4):
        dense[16 * r, 16 * r] = 1.0
    return bcsr_lib.from_dense(dense, (16, 16))


def test_heavy_row_overflow_raises():
    """Regression for the silent over-allocation: a block-row heavier than
    2x the balanced per-shard budget must raise, not quietly serialize."""
    a = _heavy_row_case()
    with pytest.raises(ValueError, match="heaviest block-row"):
        dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)


def test_split_heavy_rows_restores_balance():
    """split_heavy_rows=True fragments the heavy row across shards and the
    scatter-add combine reproduces the reference (allclose: the row's
    partial sums now accumulate across fragments)."""
    a = _heavy_row_case()
    b = _b_for(a)
    _, _, ref = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32,
                                             split_heavy_rows=True)
    assert smeta.n_split_fragments > 0
    assert sharr.split_src is not None and sharr.split_src.shape[0] > 0
    loads = [m.nnzb for m in smeta.shard_metas]
    assert max(loads) <= 2 * (-(-a.nnzb // 4) + smeta.rows_per_shard)
    for k in (1, 2, 4):
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla",
                                     n_chunks=k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=f"nk={k}")


def test_split_heavy_rows_vjp_matches_reference():
    a = _heavy_row_case()
    b = _b_for(a)
    arrays, meta, _ = _ref(a, b)
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32,
                                             split_heavy_rows=True)

    def loss_sh(v, bb):
        out = dist_spmm.spmm_sharded(sharr._replace(vals=v), smeta, bb,
                                     backend="xla")
        return jnp.sum(out ** 2)

    def loss_ref(v, bb):
        arr = ops.SparseArrays(v, *arrays[1:])
        return jnp.sum(ops.spmm(arr, meta, bb, backend="xla") ** 2)

    gv, gb = jax.grad(loss_sh, argnums=(0, 1))(sharr.vals, b)
    rv, rb = jax.grad(loss_ref, argnums=(0, 1))(arrays.vals, b)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                               rtol=1e-4, atol=1e-3)


def test_split_heavy_rows_needs_derived_budget():
    """Entry splits re-derive per-shard budgets; a pinned nnzb_per_shard
    (the scan-stacking contract) cannot host fragments."""
    a = _heavy_row_case()
    with pytest.raises(ValueError, match="split_heavy_rows"):
        dist_spmm.prepare_sharded(a, 4, nnzb_per_shard=80,
                                  split_heavy_rows=True)


# ----------------------------------------- shard-count autotune (S="auto")
def test_resolve_n_shards_deterministic_and_structure_dependent():
    """Same structure -> same S (twice in-process); the skewed structure
    shards, the small uniform one does not (acceptance invariant)."""
    skew = bcsr_lib.from_scipy(topology.power_law(512, 5.0, seed=2),
                               (16, 16))
    uni = bcsr_lib.random_bcsr(0, (512, 256), (16, 16), 0.15)
    c1 = dist_spmm.resolve_n_shards(skew, n=64, max_shards=8, n_chunks=2)
    c2 = dist_spmm.resolve_n_shards(skew, n=64, max_shards=8, n_chunks=2)
    assert (c1.n_shards, c1.source) == (c2.n_shards, c2.source)
    assert c1.n_shards > 1
    assert dist_spmm.resolve_n_shards(uni, n=64, max_shards=8,
                                      n_chunks=2).n_shards == 1


def test_resolve_n_shards_deterministic_across_processes(tmp_path):
    """A subprocess building the same structure resolves the same S, and
    the decision round-trips through the REPRO_AUTOTUNE_CACHE JSON."""
    import json
    import os
    import subprocess
    import sys
    cache = tmp_path / "tune.json"
    prog = (
        "import numpy as np, jax.numpy as jnp\n"
        "from repro.core import bcsr as bcsr_lib, topology\n"
        "from repro.kernels import autotune, ops\n"
        "from repro.launch import dist_spmm\n"
        "a = bcsr_lib.from_scipy(topology.power_law(512, 5.0, seed=2),"
        " (16, 16))\n"
        "t = autotune.Autotuner()\n"
        "c = dist_spmm.resolve_n_shards(a, n=64, max_shards=8,"
        " n_chunks=2, tuner=t)\n"
        "fp = autotune.fingerprint(ops.prepare_sparse_meta(a), 64,"
        " n_chunks=2)\n"
        "t.put_shards(fp, 8, c, persist=True)\n"
        "print(c.n_shards, autotune.shard_entry_key(fp, 8))\n")
    env = {**os.environ, "REPRO_AUTOTUNE_CACHE": str(cache),
           "PYTHONPATH": os.pathsep.join(
               [p for p in sys.path if p.endswith("src")] +
               [os.environ.get("PYTHONPATH", "")])}
    outs = [subprocess.run([sys.executable, "-c", prog], env=env,
                           capture_output=True, text=True, check=True)
            .stdout.split() for _ in range(2)]
    assert outs[0] == outs[1]
    s_sub, key = int(outs[0][0]), outs[0][1]
    here = dist_spmm.resolve_n_shards(
        bcsr_lib.from_scipy(topology.power_law(512, 5.0, seed=2), (16, 16)),
        n=64, max_shards=8, n_chunks=2, tuner=autotune.Autotuner())
    assert here.n_shards == s_sub
    # the persisted JSON loads back into a fresh tuner with the same pick
    data = json.loads(cache.read_text())
    assert key in data.get("shard_entries", {})
    fresh = autotune.Autotuner(cache_path=str(cache))
    a = bcsr_lib.from_scipy(topology.power_law(512, 5.0, seed=2), (16, 16))
    fp = autotune.fingerprint(ops.prepare_sparse_meta(a), 64, n_chunks=2)
    hit = fresh.get_shards(fp, 8)
    assert hit is not None and hit.n_shards == s_sub


def test_shard_key_chunk_depth_no_alias():
    """nk=1 and nk=2 shard decisions live under different cache keys: a
    deeper pipeline may justify a larger S (collective amortized)."""
    a = bcsr_lib.from_scipy(topology.power_law(512, 5.0, seed=2), (16, 16))
    meta = ops.prepare_sparse_meta(a)
    k1 = autotune.shard_entry_key(autotune.fingerprint(meta, 64), 8)
    k2 = autotune.shard_entry_key(
        autotune.fingerprint(meta, 64, n_chunks=2), 8)
    assert k1 != k2 and k1.startswith("shards|max=8|v7|")
    tuner = autotune.Autotuner()
    tuner.put_shards(autotune.fingerprint(meta, 64), 8,
                     autotune.ShardChoice(1), persist=False)
    assert tuner.get_shards(
        autotune.fingerprint(meta, 64, n_chunks=2), 8) is None


# ------------------------------------------------------------- model wiring
def _specs(shards=0):
    base = dict(density=0.3, block=(16, 16), backend="xla")
    return (SparsitySpec(**base),
            SparsitySpec(**base, shards=shards) if shards else None)


def test_sparse_linear_sharded_matches_unsharded():
    spec0, specS = _specs(shards=4)
    d, f = 96, 160
    p0, m0 = init_sparse_linear(11, d, f, spec0, dtype=jnp.float32)
    pS, mS = init_sparse_linear(11, d, f, specS, dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 5, d)).astype(np.float32))
    y0 = apply_sparse_linear(p0, m0, x, spec0)
    yS = apply_sparse_linear(pS, mS, x, specS)
    np.testing.assert_allclose(np.asarray(yS), np.asarray(y0),
                               rtol=1e-5, atol=1e-4)

    def loss(v, p, m, s):
        return jnp.sum(apply_sparse_linear({**p, "vals": v}, m, x, s) ** 2)
    gS = jax.grad(loss)(pS["vals"], pS, mS, specS)
    g0 = jax.grad(loss)(p0["vals"], p0, m0, spec0)
    np.testing.assert_allclose(np.asarray(gS), np.asarray(g0),
                               rtol=1e-5, atol=1e-4)


def test_sparse_linear_specs_match_init_shapes():
    """The dims-only spec shapes are the contract that lets structures of
    DIFFERENT seeds scan-stack; init must land exactly on them."""
    _, specS = _specs(shards=4)
    d, f = 96, 160
    ps_specs, ms_specs = sparse_linear_specs(d, f, specS, dtype=jnp.float32)
    for seed in (11, 12, 13):
        pS, mS = init_sparse_linear(seed, d, f, specS, dtype=jnp.float32)
        assert set(pS) == set(ps_specs)
        for k in pS:
            assert ps_specs[k].shape == pS[k].shape, k
            assert ps_specs[k].dtype == pS[k].dtype, k
        assert ms_specs.rows_per_shard == mS.rows_per_shard
        assert ms_specs.nnzb_per_shard == mS.nnzb_per_shard


def test_sparse_linear_sharded_under_mesh():
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    spec0, specS = _specs(shards=4)
    d, f = 96, 160
    p0, m0 = init_sparse_linear(11, d, f, spec0, dtype=jnp.float32)
    pS, mS = init_sparse_linear(11, d, f, specS, dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 5, d)).astype(np.float32))
    y0 = apply_sparse_linear(p0, m0, x, spec0)
    mesh = dist_spmm.make_spmm_mesh(4)
    with dist_spmm.use_spmm_mesh(mesh):
        yS = jax.jit(lambda p, xx: apply_sparse_linear(p, mS, xx, specS)
                     )(pS, x)
    np.testing.assert_allclose(np.asarray(yS), np.asarray(y0),
                               rtol=1e-5, atol=1e-4)


def test_sparse_linear_auto_shards_resolves_statically():
    """shards="auto": the resolved S is a pure function of (dims, spec) —
    specs, init, and re-derivation agree; apply matches the unsharded
    path bit-for-bit at the default chunk depth."""
    from repro.core import sparse_linear as sl
    spec0, _ = _specs()
    specA = dataclasses.replace(spec0, shards="auto")
    d, f = 96, 160
    assert sl.is_sharded(specA) and not sl.is_sharded(spec0)
    s1 = sl.resolved_shards(specA, f, d)
    assert s1 == sl.resolved_shards(specA, f, d) and s1 >= 1
    ps_specs, _ = sparse_linear_specs(d, f, specA, dtype=jnp.float32)
    for seed in (11, 12):
        pA, mA = init_sparse_linear(seed, d, f, specA, dtype=jnp.float32)
        for k in pA:
            assert ps_specs[k].shape == pA[k].shape, k
    p0, m0 = init_sparse_linear(11, d, f, spec0, dtype=jnp.float32)
    pA, mA = init_sparse_linear(11, d, f, specA, dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 5, d)).astype(np.float32))
    y0 = np.asarray(apply_sparse_linear(p0, m0, x, spec0))
    yA = np.asarray(apply_sparse_linear(pA, mA, x, specA))
    np.testing.assert_allclose(yA, y0, rtol=1e-5, atol=1e-4)
    # chunk depth is spec-controlled and value-preserving
    spec1 = dataclasses.replace(specA, shard_chunks=1)
    y1 = np.asarray(apply_sparse_linear(pA, mA, x, spec1))
    assert np.array_equal(yA.view(np.uint32), y1.view(np.uint32))


def test_model_mlp_sharded_matches_dense_path():
    """cfg.ffn_sparsity.shards wires through init_mlp/mlp unchanged."""
    from repro.configs import get_config
    from repro.models import layers as L
    cfg0 = dataclasses.replace(get_config("smat-ffn-1.3b:smoke"),
                               dtype="float32")
    specS = dataclasses.replace(cfg0.ffn_sparsity, shards=2)
    cfgS = dataclasses.replace(cfg0, ffn_sparsity=specS)
    key = jax.random.PRNGKey(0)
    p0 = L.init_mlp(cfg0, key, jnp.float32, seed_hint=3)
    pS = L.init_mlp(cfgS, key, jnp.float32, seed_hint=3)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg0.d_model),
                          jnp.float32)
    y0 = L.mlp(cfg0, p0, x)
    yS = L.mlp(cfgS, pS, x)
    np.testing.assert_allclose(np.asarray(yS), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
