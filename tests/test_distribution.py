"""Distribution-layer unit tests: MoE dispatch arms, unroll-mode scan
equivalence, serve-mode sharding rules, sharding fit logic."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh
from repro.models import moe as M
from repro.models import transformer as T
from repro.models import unroll as U


# ----------------------------------------------------------------- MoE arms
def test_moe_gather_matches_einsum_dispatch():
    """The scatter/gather dispatch (ours) and the GShard one-hot einsum
    (reference) implement the same routing semantics — identical outputs
    up to slot-assignment order when capacity is not exceeded."""
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b:smoke"),
                              dtype="float32", capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = M.init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_g, aux_g = M.moe_ffn(cfg, p, x, dispatch="gather")
    y_e, aux_e = M.moe_ffn(cfg, p, x, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_e),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_e), rtol=1e-5)


def test_moe_gather_respects_capacity():
    """With capacity_factor ~0, (almost) all tokens are dropped and only the
    shared-expert path contributes."""
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b:smoke"),
                              dtype="float32", capacity_factor=1e-9)
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y_full, _ = M.moe_ffn(cfg, p, x, dispatch="gather")
    # capacity floor is 1 slot/expert; outputs must stay finite and bounded
    assert np.isfinite(np.asarray(y_full)).all()


def test_moe_gather_grads_flow():
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b:smoke"),
                              dtype="float32")
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = M.moe_ffn(cfg, p, x, dispatch="gather")
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    for name in ("w_gate", "w_up", "w_down", "router"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


# ------------------------------------------------------------- unroll mode
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mamba2-1.3b",
                                  "zamba2-7b", "deepseek-v2-lite-16b"])
def test_unrolled_forward_matches_scanned(arch):
    """Cost-extrapolation depends on unrolled == scanned semantics."""
    cfg = dataclasses.replace(get_config(arch + ":smoke"), dtype="float32")
    params = T.init_params(cfg, seed=0)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 32)),
        jnp.int32)
    logits_scan, _, _ = T.forward(cfg, params, {"tokens": toks})
    with U.unroll_scans():
        logits_unroll, _, _ = T.forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits_scan),
                               np.asarray(logits_unroll),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- shardings
def _mesh():
    # AbstractMesh: axis names/sizes without needing >1 real device
    # (built via the version-compat helper — signatures differ across JAX)
    return mesh_lib.make_abstract_mesh((2, 2), ("data", "model"))


def test_fit_spec_drops_nondivisible_axes():
    mesh = _mesh()
    spec = sh.fit_spec(mesh, P("data", "model"), (4, 3))
    assert spec == P("data", None)          # 3 % 2 != 0 -> dropped
    spec = sh.fit_spec(mesh, P(("data", "model"), None), (2, 8))
    assert spec == P(("data",), None) or spec == P("data", None)


def test_serve_mode_strips_fsdp():
    mesh = _mesh()
    cfg = get_config("qwen2.5-14b:smoke")
    specs = T.param_specs(cfg)
    train_sh = sh.param_shardings(mesh, specs, mode="train")
    serve_sh = sh.param_shardings(mesh, specs, mode="serve")

    def axes_used(shardings):
        used = set()
        for s in jax.tree.leaves(shardings):
            for a in s.spec:
                if isinstance(a, tuple):
                    used.update(a)
                elif a is not None:
                    used.add(a)
        return used

    assert "data" in axes_used(train_sh)            # FSDP on
    assert "data" not in axes_used(serve_sh)        # FSDP off for serving
    assert "model" in axes_used(serve_sh)           # TP stays


def test_cache_seq_shard_for_single_request():
    mesh = _mesh()
    cfg = get_config("gemma2-27b:smoke")
    cs = T.cache_specs(cfg, 1, 256)
    shard = sh.cache_shardings(mesh, cs, cfg, seq_shard=True)
    leaves = jax.tree_util.tree_flatten_with_path(shard)[0]
    k_leaves = [s for p, s in leaves
                if getattr(p[-1], "key", None) == "k"]
    assert k_leaves
    for s in k_leaves:
        # batch dim unsharded (B=1), sequence dim carries the data axes
        b_dim_axis = s.spec[-4]
        seq_axis = s.spec[-3]
        assert b_dim_axis is None
        assert seq_axis is not None


def test_batch_sharding_replicates_batch_of_one():
    mesh = _mesh()
    specs = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
    b = sh.batch_shardings(mesh, specs)
    assert b["tokens"].spec == P(None, None)
