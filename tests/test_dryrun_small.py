"""Dry-run machinery tests on a small virtual-device mesh (subprocess so the
XLA device-count flag applies cleanly), plus roofline HLO-parsing units."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import roofline as rl

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["DRYRUN_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=500)


@pytest.mark.slow
def test_dryrun_all_shapes_small_mesh(tmp_path):
    out = tmp_path / "r.json"
    p = _run_dryrun("--arch", "h2o-danube-1.8b:smoke",
                    "--mesh-shape", "2,4", "--batch", "8", "--seq", "128",
                    "--no-extrapolate", "--out", str(out))
    assert p.returncode == 0, p.stdout + p.stderr
    records = json.loads(out.read_text())
    assert len(records) == 4
    assert all(r["status"] == "ok" for r in records)
    train = next(r for r in records if r["shape"] == "train_4k")
    assert train["roofline"]["flops_per_device"] > 0
    assert train["memory"]["peak_bytes_per_device"] > 0


@pytest.mark.slow
def test_dryrun_multipod_axes_small(tmp_path):
    out = tmp_path / "r.json"
    p = _run_dryrun("--arch", "mamba2-1.3b:smoke", "--shape", "train_4k",
                    "--mesh-shape", "2,2,2", "--batch", "8", "--seq", "64",
                    "--no-extrapolate", "--out", str(out))
    assert p.returncode == 0, p.stdout + p.stderr
    records = json.loads(out.read_text())
    assert records[0]["status"] == "ok"
    assert records[0]["mesh"] == "2x2x2"


# ---------------------------------------------------------- roofline parsing
HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups=[8,4]<=[32], to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[32]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %d = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = rl.parse_collectives(HLO_SAMPLE)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    assert st.bytes_total["all-gather"] == 512 * 256 * 4
    assert st.bytes_total["all-reduce"] == 1024 * 2
    # ring factors: AG (n-1)/n, AR 2(n-1)/n
    expected = (3 / 4) * 512 * 256 * 4 + 2 * (3 / 4) * 1024 * 2 + \
        (1 / 2) * 64 * 64 * 4 + 32 * 4
    assert abs(st.wire_bytes - expected) < 1e-6


def test_parse_collectives_ignores_done_ops():
    txt = """
  %ags = f32[256]{0} all-gather-start(%p), replica_groups={{0,1}}
  %agd = f32[256]{0} all-gather-done(%ags)
"""
    st = rl.parse_collectives(txt)
    assert st.counts.get("all-gather", 0) == 1


def test_roofline_terms_and_bottleneck():
    r = rl.compute_roofline(
        flops=197e12 * 0.010,        # 10 ms of compute
        bytes_acc=819e9 * 0.002,     # 2 ms of HBM
        wire_bytes=50e9 * 0.050,     # 50 ms of ICI
        n_devices=256, model_flops=197e12 * 0.010 * 256 * 0.5)
    assert r.bottleneck == "collective"
    assert abs(r.t_compute - 0.010) < 1e-9
    assert abs(r.useful_ratio - 0.5) < 1e-9


def test_model_flops_moe_uses_active_params():
    from repro.configs import SHAPES, get_config
    lite = get_config("deepseek-v2-lite-16b")
    total, active = lite.param_count(), lite.active_param_count()
    assert active < total * 0.45        # MoE: activates well under half
    mf = rl.model_flops_for(lite, SHAPES["train_4k"])
    assert mf == pytest.approx(6.0 * active * 4096 * 256)
