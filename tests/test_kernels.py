"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes, block sizes, densities and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcsr as bcsr_lib
from repro.kernels import bcsr_spmm as pk
from repro.kernels import ops, ref


def _mk(shape, block, density, seed=0, dtype=np.float32, fill=1.0):
    a = bcsr_lib.random_bcsr(seed, shape, block, density, dtype=dtype,
                             fill_density=fill)
    return a.ensure_nonempty_rows()


SHAPES = [
    ((64, 64), (8, 8), 0.5),
    ((128, 256), (16, 32), 0.3),
    ((256, 128), (32, 16), 0.15),
    ((96, 160), (16, 16), 0.4),
]


@pytest.mark.parametrize("shape,block,density", SHAPES)
@pytest.mark.parametrize("n", [8, 64])
def test_nnz_stream_matches_ref(shape, block, density, n):
    a = _mk(shape, block, density)
    b = np.random.default_rng(1).standard_normal(
        (shape[1], n)).astype(np.float32)
    got = pk.bcsr_spmm_nnz_stream(
        jnp.asarray(a.vals), jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
        jnp.asarray(b), a.n_block_rows, bn=min(64, n), interpret=True)
    want = ref.bcsr_spmm_ref(
        jnp.asarray(a.vals), jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
        jnp.asarray(b), a.n_block_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,block,density", SHAPES[:2])
def test_nnz_stream_matches_dense(shape, block, density):
    a = _mk(shape, block, density, fill=0.6)
    b = np.random.default_rng(2).standard_normal(
        (shape[1], 32)).astype(np.float32)
    got = pk.bcsr_spmm_nnz_stream(
        jnp.asarray(a.vals), jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
        jnp.asarray(b), a.n_block_rows, bn=32, interpret=True)
    want = a.to_dense() @ b
    np.testing.assert_allclose(np.asarray(got)[: shape[0]], want,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_nnz_stream_dtypes(dtype):
    shape, block = (128, 128), (16, 16)
    a = _mk(shape, block, 0.3, dtype=np.float32)
    vals = jnp.asarray(a.vals).astype(dtype)
    b = jnp.asarray(np.random.default_rng(3).standard_normal(
        (128, 64)).astype(np.float32)).astype(dtype)
    got = pk.bcsr_spmm_nnz_stream(
        vals, jnp.asarray(a.row_ids), jnp.asarray(a.col_ids), b,
        a.n_block_rows, bn=64, interpret=True)
    want = ref.bcsr_spmm_ref(vals, jnp.asarray(a.row_ids),
                             jnp.asarray(a.col_ids), b, a.n_block_rows)
    assert got.dtype == b.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("shape,block,density", SHAPES[:3])
def test_row_loop_matches_ref(shape, block, density):
    a = _mk(shape, block, density)
    b = np.random.default_rng(4).standard_normal(
        (shape[1], 32)).astype(np.float32)
    flat_idx, flat_col, row_len, max_bpr = ops.make_row_loop_schedule(a)
    got = pk.bcsr_spmm_row_loop(
        jnp.asarray(a.vals), flat_idx, flat_col, row_len,
        jnp.asarray(b), a.n_block_rows, bn=32, interpret=True)
    want = ref.bcsr_spmm_ref(
        jnp.asarray(a.vals), jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
        jnp.asarray(b), a.n_block_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_row_loop_handles_empty_and_skewed_rows():
    # adversarial: rows with 0 blocks and one row with many (the dc2 case)
    rng = np.random.default_rng(5)
    dense = np.zeros((64, 128), np.float32)
    dense[3, :] = rng.standard_normal(128)      # very dense row
    dense[17, 5] = 1.0                           # singleton
    a = bcsr_lib.from_dense(dense, (8, 16))
    b = rng.standard_normal((128, 16)).astype(np.float32)
    flat_idx, flat_col, row_len, _ = ops.make_row_loop_schedule(a)
    got = pk.bcsr_spmm_row_loop(
        jnp.asarray(a.vals), flat_idx, flat_col, row_len, jnp.asarray(b),
        a.n_block_rows, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), dense @ b, rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("shape,block,density", SHAPES[:3])
def test_sddmm_matches_ref(shape, block, density):
    a = _mk(shape, block, density)
    h, w = block
    rng = np.random.default_rng(6)
    M = a.n_block_rows * h
    dc = rng.standard_normal((M, 32)).astype(np.float32)
    b = rng.standard_normal((a.n_block_cols * w, 32)).astype(np.float32)
    got = pk.bcsr_sddmm(jnp.asarray(dc), jnp.asarray(b),
                        jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
                        h, w, bn=32, interpret=True)
    want = ref.bcsr_sddmm_ref(jnp.asarray(dc), jnp.asarray(b),
                              jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
                              h, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ ops level
@pytest.mark.parametrize("backend", ["pallas", "xla", "dense"])
def test_ops_spmm_forward(backend):
    shape, block = (96, 128), (16, 16)
    a = _mk(shape, block, 0.3)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    b = jnp.asarray(np.random.default_rng(7).standard_normal(
        (shape[1], 40)).astype(np.float32))
    got = ops.spmm(arrays, meta, b, backend=backend, bn=128, interpret=True)
    want = a.to_dense() @ np.asarray(b)
    assert got.shape == (shape[0], 40)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_ops_spmm_grads(backend):
    shape, block = (64, 96), (16, 16)
    a = _mk(shape, block, 0.4)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    rng = np.random.default_rng(8)
    b = jnp.asarray(rng.standard_normal((shape[1], 24)).astype(np.float32))

    def loss(vals, b):
        arr = arrays._replace(vals=vals)
        out = ops.spmm(arr, meta, b, backend=backend, bn=128, interpret=True)
        return jnp.sum(out * out)

    g_vals, g_b = jax.grad(loss, argnums=(0, 1))(arrays.vals, b)

    # numeric oracle via the dense equivalent
    def loss_dense(vals, b):
        arr = arrays._replace(vals=vals)
        dense = ops.materialize_dense(arr, meta)[: shape[0], : shape[1]]
        out = dense @ b
        return jnp.sum(out * out)

    g_vals_d, g_b_d = jax.grad(loss_dense, argnums=(0, 1))(arrays.vals, b)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_b_d),
                               rtol=1e-3, atol=1e-3)
    mask = np.asarray(arrays.real_mask)[:, None, None]
    np.testing.assert_allclose(np.asarray(g_vals),
                               np.asarray(g_vals_d) * mask,
                               rtol=1e-3, atol=1e-3)


def test_ops_unaligned_shapes():
    # M, K, N not multiples of the block/tile — wrapper pads & slices
    dense = np.random.default_rng(9).standard_normal((50, 70)).astype(
        np.float32)
    dense[np.abs(dense) < 1.0] = 0
    a = bcsr_lib.from_dense(dense, (16, 16))
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    b = jnp.asarray(np.random.default_rng(10).standard_normal(
        (70, 33)).astype(np.float32))
    got = ops.spmm(arrays, meta, b, backend="pallas", bn=128,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(got)[:50], dense @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)


# ===================================================================== SDDMM
def _sddmm_oracle(a, dc, b):
    """Dense masked-einsum oracle: blocks of dC @ B^T at the stored
    coordinates (f32 accumulation)."""
    h, w = a.block
    full = np.asarray(dc, np.float32) @ np.asarray(b, np.float32).T
    nbr, nbc = full.shape[0] // h, full.shape[1] // w
    blocks = full.reshape(nbr, h, nbc, w).transpose(0, 2, 1, 3)
    return blocks[np.asarray(a.row_ids), np.asarray(a.col_ids)]


@pytest.mark.parametrize("shape,block,density", SHAPES)
@pytest.mark.parametrize("n", [8, 64])
def test_sddmm_matches_dense_masked_einsum(shape, block, density, n):
    a = _mk(shape, block, density)
    rng = np.random.default_rng(11)
    h, w = block
    M = a.n_block_rows * h
    K = a.n_block_cols * w
    dc = rng.standard_normal((M, n)).astype(np.float32)
    b = rng.standard_normal((K, n)).astype(np.float32)
    want = _sddmm_oracle(a, dc, b)
    got = pk.bcsr_sddmm(jnp.asarray(dc), jnp.asarray(b),
                        jnp.asarray(a.row_ids), jnp.asarray(a.col_ids),
                        h, w, bn=min(64, n), interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
    got_ref = ref.bcsr_sddmm_ref(jnp.asarray(dc), jnp.asarray(b),
                                 jnp.asarray(a.row_ids),
                                 jnp.asarray(a.col_ids), h, w)
    np.testing.assert_allclose(np.asarray(got_ref), want,
                               rtol=1e-5, atol=1e-5)
    got_dense = ref.bcsr_sddmm_dense_ref(jnp.asarray(dc), jnp.asarray(b),
                                         jnp.asarray(a.row_ids),
                                         jnp.asarray(a.col_ids), h, w)
    np.testing.assert_allclose(np.asarray(got_dense), want,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,block,density", SHAPES[:3])
def test_sddmm_row_loop_matches_ref(shape, block, density):
    a = _mk(shape, block, density)
    rng = np.random.default_rng(12)
    h, w = block
    dc = rng.standard_normal((a.n_block_rows * h, 32)).astype(np.float32)
    b = rng.standard_normal((a.n_block_cols * w, 32)).astype(np.float32)
    flat_idx, flat_col, _, max_bpr = ops.make_row_loop_schedule(a)
    # sddmm schedule: padding slots must point at the SENTINEL entry, not 0
    sched_idx, sched_col = ops._sddmm_row_loop_schedule(
        jnp.asarray(a.row_ids), jnp.asarray(a.col_ids), a.n_block_rows,
        max_bpr)
    got = pk.bcsr_sddmm_row_loop(
        jnp.asarray(dc), jnp.asarray(b), sched_idx, sched_col,
        a.n_block_rows, a.nnzb, h, w, bn=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), _sddmm_oracle(a, dc, b),
                               rtol=1e-5, atol=1e-5)


def test_sddmm_row_loop_skewed_and_empty_rows():
    # dc2-style skew + empty block-rows: sentinel slots must not clobber
    # entry 0 (the regression the sentinel output block exists for)
    rng = np.random.default_rng(13)
    dense = np.zeros((64, 128), np.float32)
    dense[3, :] = rng.standard_normal(128)       # one very dense row
    dense[17, 5] = 1.0                           # singleton
    a = bcsr_lib.from_dense(dense, (8, 16)).ensure_nonempty_rows()
    dc = rng.standard_normal((64, 16)).astype(np.float32)
    b = rng.standard_normal((128, 16)).astype(np.float32)
    _, _, _, max_bpr = ops.make_row_loop_schedule(a)
    sched_idx, sched_col = ops._sddmm_row_loop_schedule(
        jnp.asarray(a.row_ids), jnp.asarray(a.col_ids), a.n_block_rows,
        max_bpr)
    got = pk.bcsr_sddmm_row_loop(
        jnp.asarray(dc), jnp.asarray(b), sched_idx, sched_col,
        a.n_block_rows, a.nnzb, 8, 16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), _sddmm_oracle(a, dc, b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_sddmm_dtypes_f32_accumulation(dtype):
    # mixed-precision contract: inputs may be bf16, accumulation is f32
    # VMEM scratch, output takes the requested dtype
    shape, block = (128, 128), (16, 16)
    a = _mk(shape, block, 0.3)
    rng = np.random.default_rng(14)
    dc = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32)
                     ).astype(dtype)
    b = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32)
                    ).astype(dtype)
    got = pk.bcsr_sddmm(dc, b, jnp.asarray(a.row_ids),
                        jnp.asarray(a.col_ids), 16, 16, bn=64,
                        out_dtype=jnp.float32, interpret=True)
    assert got.dtype == jnp.float32
    want = _sddmm_oracle(a, np.asarray(dc, np.float32),
                         np.asarray(b, np.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol)


def test_ops_sddmm_ragged_and_empty_rows():
    # M, K not multiples of the block; genuinely empty block-rows whose
    # padding entries must come back exactly zero (real_mask)
    rng = np.random.default_rng(15)
    dense = np.zeros((50, 70), np.float32)
    dense[0:8, 0:16] = rng.standard_normal((8, 16))
    dense[33:41, 48:64] = rng.standard_normal((8, 16))
    a = bcsr_lib.from_dense(dense, (8, 16))
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((50, 24)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((70, 24)).astype(np.float32))
    x_pad = np.zeros((meta.n_block_rows * 8, 24), np.float32)
    x_pad[:50] = np.asarray(x)
    y_pad = np.zeros((meta.n_block_cols * 16, 24), np.float32)
    y_pad[:70] = np.asarray(y)
    h, w = meta.block
    full = x_pad @ y_pad.T
    blocks = full.reshape(meta.n_block_rows, h, meta.n_block_cols, w
                          ).transpose(0, 2, 1, 3)
    want = blocks[np.asarray(arrays.row_ids), np.asarray(arrays.col_ids)]
    want *= np.asarray(arrays.real_mask)[:, None, None]
    for backend in ("pallas", "row_loop", "xla", "dense"):
        got = ops.sddmm(arrays, meta, x, y, backend=backend, bn=64,
                        interpret=True)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                   atol=1e-4, err_msg=backend)
        pad_rows = ~np.asarray(arrays.real_mask)
        assert pad_rows.any()            # the case genuinely has padding
        assert np.all(np.asarray(got)[pad_rows] == 0.0)
