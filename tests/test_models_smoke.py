"""Per-architecture smoke tests: reduced same-family config, one forward +
train-grad step and one prefill+decode step on CPU; asserts shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as T

ALL_ARCHS = list_archs()
B, L = 2, 64


def _batch(cfg, rng):
    batch = {}
    if cfg.input_mode == "codebooks":
        toks = rng.integers(0, cfg.vocab_size, size=(B, L, cfg.n_codebooks))
        batch["tokens"] = jnp.asarray(toks, jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, L, cfg.n_codebooks)),
            jnp.int32)
    elif cfg.input_mode == "tokens+patches":
        lt = L - cfg.patch_tokens
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, lt)), jnp.int32)
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.patch_tokens, cfg.d_model)),
            jnp.bfloat16)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, lt)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, L)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, L)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + ":smoke")
    params = T.init_params(cfg, seed=0)
    batch = _batch(cfg, np.random.default_rng(0))
    logits, _, aux = T.forward(cfg, params, batch)
    if cfg.input_mode == "codebooks":
        assert logits.shape == (B, L, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, L, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_grad_finite(arch):
    cfg = get_config(arch + ":smoke")
    params = T.init_params(cfg, seed=0)
    batch = _batch(cfg, np.random.default_rng(1))

    def loss_fn(p):
        loss, _ = T.train_loss(cfg, p, batch, remat="none")
        return loss

    loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    leaves = [g for g in jax.tree.leaves(grads)
              if hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.inexact)]
    assert leaves
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch + ":smoke")
    params = T.init_params(cfg, seed=0)
    rng = np.random.default_rng(2)
    batch = _batch(cfg, rng)
    cache_len = L
    logits, cache = T.prefill(cfg, params, batch, cache_len)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    if cfg.input_mode == "codebooks":
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       size=(B, cfg.n_codebooks)), jnp.int32)
    else:
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B,)),
                          jnp.int32)
    seq_pos = L if cfg.input_mode != "tokens+patches" else L
    dl, cache2 = T.decode_step(cfg, params, cache,
                               tok, jnp.asarray(seq_pos, jnp.int32))
    assert np.isfinite(np.asarray(dl, np.float32)).all()
    # cache actually changed
    changed = jax.tree.map(
        lambda a, b: not np.array_equal(np.asarray(a, np.float32),
                                        np.asarray(b, np.float32)),
        cache, cache2)
    assert any(jax.tree.leaves(changed))


def test_decode_matches_prefill_dense_arch():
    """Teacher-forced decode must reproduce prefill logits step by step
    (h2o-danube: GQA + SWA path)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b:smoke"),
                              dtype="float32")
    params = T.init_params(cfg, seed=0)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, size=(1, 16))
    full_logits, _, _ = T.forward(
        cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)})

    cache = T.init_cache(cfg, 1, 16)
    # feed token 0 via prefill of length 1? decode from scratch instead:
    outs = []
    for t in range(16):
        logits_t, cache = T.decode_step(
            cfg, params, cache, jnp.asarray(toks[:, t], jnp.int32),
            jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(logits_t, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_decode_matches_prefill_ssm_arch():
    """Same teacher-forcing check through the Mamba2 recurrence."""
    import dataclasses
    cfg = dataclasses.replace(get_config("mamba2-1.3b:smoke"),
                              dtype="float32")
    params = T.init_params(cfg, seed=0)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab_size, size=(1, 32))
    full_logits, _, _ = T.forward(
        cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)})
    cache = T.init_cache(cfg, 1, 32)
    outs = []
    for t in range(32):
        logits_t, cache = T.decode_step(
            cfg, params, cache, jnp.asarray(toks[:, t], jnp.int32),
            jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(logits_t, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_param_specs_no_alloc_matches_init():
    cfg = get_config("qwen2.5-14b:smoke")
    specs = T.param_specs(cfg)
    params = T.init_params(cfg, seed=0)
    flat_s = jax.tree.leaves(specs)
    flat_p = jax.tree.leaves(params)
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        assert s.shape == p.shape and s.dtype == p.dtype
