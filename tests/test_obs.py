"""PR 10 observability pins: deterministic event payloads (bitwise-stable
across identical runs), exporter round-trips (JSONL, Perfetto, summary
tree), metrics snapshot/reset semantics, the retrace sentinel
(positive AND negative), the CI retrace gates for the three monitored
entry points (``serve.masked_step``, ``models.paged_decode``,
``launch.spmm_sharded``), and the zero-cost contract when tracing is
disabled."""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bcsr as bcsr_lib
from repro.kernels import autotune, ops
from repro.launch import dist_spmm
from repro.models import attention as A
from repro.models import transformer as T
from repro.obs import export, jaxmon, metrics, trace
from repro.serve.engine import Request, ServeEngine


def _sparse_cfg() -> ModelConfig:
    return ModelConfig(
        name="obs-test", family="dense", layout="attn_mlp",
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=97, dtype="float32",
        attn_sparsity=A.AttnSparsitySpec(mask=A.banded(32), block=(16, 16),
                                         backend="xla", interpret=True))


def _requests(n=3, max_new=3):
    rng = np.random.default_rng(0)
    lens = (3, 7, 5, 2, 6)
    return [Request(rid=i,
                    prompt=rng.integers(0, 97, size=lens[i % len(lens)],
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _instrumented_spmm_run():
    """One prepare+dispatch pass under a fresh autotuner, returning the
    captured events — the instrumented path the determinism pin replays."""
    autotune.set_autotuner(autotune.Autotuner())
    a = bcsr_lib.random_bcsr(0, (128, 64), (16, 16), 0.3)
    with trace.capture() as cap:
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32,
                                          reorder="jaccard")
        b = jnp.asarray(np.random.default_rng(1).standard_normal((64, 32)),
                        jnp.float32)
        ops.spmm(arrays, meta, b, backend="auto", interpret=True)
    return cap.events


# ------------------------------------------------------------ determinism
def test_deterministic_payloads_bitwise_stable_across_runs():
    """Two identical runs (fresh autotuner each) must produce IDENTICAL
    deterministic payloads — (kind, name, seq, span, parent, args) — and
    the same checksum.  Wall-clock fields are excluded by construction."""
    ev1 = _instrumented_spmm_run()
    ev2 = _instrumented_spmm_run()
    p1, p2 = (export.deterministic_events(e) for e in (ev1, ev2))
    assert p1, "instrumented path emitted no events"
    assert p1 == p2
    assert export.checksum(p1) == export.checksum(p2)
    names = {e.name for e in ev1}
    # the instrumented prepare pipeline + dispatch all show up
    assert {"prepare.reorder", "prepare.meta", "prepare.done",
            "autotune.pick", "ops.dispatch"} <= names


def test_span_nesting_and_args_are_jsonified():
    with trace.capture() as cap:
        with trace.span("outer", n=np.int64(3)):
            with trace.span("inner"):
                trace.event("leaf", xs=(1, 2), arr=np.arange(2))
    kinds = [(e.kind, e.name) for e in cap.events]
    assert kinds == [("B", "outer"), ("B", "inner"), ("I", "leaf"),
                     ("E", "inner"), ("E", "outer")]
    outer_b, inner_b, leaf = cap.events[:3]
    assert inner_b.parent == outer_b.span
    assert leaf.parent == inner_b.span      # instant events hang off the
    assert leaf.span is None                # enclosing span via parent
    # numpy scalars/arrays and tuples normalize to plain JSON types
    assert outer_b.args == {"n": 3}
    assert leaf.args == {"xs": [1, 2], "arr": [0, 1]}


# -------------------------------------------------------------- exporters
def test_jsonl_round_trip(tmp_path):
    path = os.path.join(tmp_path, "trace.jsonl")
    with trace.capture(path=path) as cap:
        with trace.span("work", k=1):
            trace.event("mark", v="x")
    read = export.read_jsonl(path)
    assert [e.to_dict() for e in read] == [e.to_dict() for e in cap.events]
    # and the sink wrote one JSON object per line
    with open(path) as f:
        lines = [json.loads(ln) for ln in f]
    assert len(lines) == len(cap.events)


def test_perfetto_export_is_valid(tmp_path):
    with trace.capture() as cap:
        with trace.span("a"):
            trace.event("i1")
        with trace.span("b"):
            pass
    doc = export.to_perfetto(cap.events)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    phases = [te["ph"] for te in doc["traceEvents"]]
    assert phases.count("B") == phases.count("E") == 2
    assert phases.count("i") == 1
    for te in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(te)
    out = os.path.join(tmp_path, "p.json")
    export.write_perfetto(cap.events, out)
    assert json.load(open(out)) == doc


def test_summary_tree_renders_span_hierarchy():
    with trace.capture() as cap:
        for _ in range(2):
            with trace.span("phase"):
                with trace.span("sub"):
                    pass
                trace.event("tick")
    text = export.summary_tree(cap.events)
    assert "phase x2" in text
    assert "sub x2" in text
    assert "[event] tick x2" in text


# ---------------------------------------------------------------- metrics
def test_metrics_labels_snapshot_reset():
    r = metrics.Registry()
    r.counter("hits", op="spmm").inc()
    r.counter("hits", op="spmm").inc(2)
    r.counter("hits", op="sddmm").inc()
    r.gauge("level").set(0.25)
    h = r.histogram("lat")
    for v in (0.5, 3, 10_000):
        h.observe(v)
    snap = r.snapshot()
    assert snap["counters"] == {"hits{op=sddmm}": 1, "hits{op=spmm}": 3}
    assert snap["gauges"] == {"level": 0.25}
    hs = snap["histograms"]["lat"]
    assert hs["count"] == 3 and hs["min"] == 0.5 and hs["max"] == 10_000
    assert hs["buckets"]["le_1"] == 1 and hs["buckets"]["inf"] == 1
    r.reset()
    assert r.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_metrics_type_conflict_raises():
    r = metrics.Registry()
    r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")


def test_timeit_reduce_modes_and_validation():
    calls = []
    sec = metrics.timeit(lambda: calls.append(1), warmup=1, iters=3,
                         reduce="min")
    assert len(calls) == 4 and sec >= 0.0
    with pytest.raises(ValueError):
        metrics.timeit(lambda: None, reduce="mean")


# --------------------------------------------------------- retrace sentinel
def test_retrace_sentinel_counts_traces_not_calls():
    @jaxmon.monitor
    def poly(x):
        return x * 2

    f = jax.jit(poly)
    f(jnp.ones((2,)))
    f(jnp.ones((2,)))                       # cache hit: no new trace
    assert jaxmon.trace_count(poly) == 1
    jaxmon.assert_max_traces(poly, 1)
    f(jnp.ones((3,)))                       # new shape -> retrace
    assert jaxmon.trace_count(poly) == 2
    with pytest.raises(jaxmon.RetraceError):
        jaxmon.assert_max_traces(poly, 1)
    poly(jnp.ones((4,)))                    # eager call: NOT a trace
    assert jaxmon.trace_count(poly) == 2
    jaxmon.reset(poly)
    assert jaxmon.trace_count(poly) == 0


def test_sentinel_registry_lookup_by_name():
    @jaxmon.monitor(name="obs_test.named")
    def g(x):
        return x + 1

    jax.jit(g)(jnp.zeros((2,)))
    assert jaxmon.trace_count("obs_test.named") == 1
    assert "obs_test.named" in jaxmon.sentinels()


# ----------------------------------------------------------- CI trace gates
def test_serve_engine_never_retraces():
    """The static-shape promise of the masked decode step: a full
    continuous-batching run with mixed prompt lengths, admissions and
    evictions traces ``serve.masked_step`` EXACTLY once."""
    cfg = _sparse_cfg()
    params = T.init_params(cfg, seed=0)
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64)
    for _ in eng.generate([dataclasses.replace(r) for r in _requests()]):
        pass
    assert eng.step_sentinel.count == 1
    jaxmon.assert_max_traces(eng.step_sentinel, 1)


def test_paged_decode_traces_once_per_engine():
    """The paged KV decode body is scanned over layers — one trace per
    engine program, regardless of layer count or tokens decoded."""
    cfg = _sparse_cfg()
    params = T.init_params(cfg, seed=0)
    jaxmon.reset("models.paged_decode")
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64)
    assert eng.paged_kv is not None        # the paged path is actually on
    for _ in eng.generate([dataclasses.replace(r) for r in _requests()]):
        pass
    assert jaxmon.trace_count("models.paged_decode") == 1
    jaxmon.assert_max_traces("models.paged_decode", 1)


def test_spmm_sharded_traces_once_under_jit():
    a = bcsr_lib.random_bcsr(0, (128, 64), (16, 16), 0.3)
    sharr, smeta = dist_spmm.prepare_sharded(a, 2, dtype=jnp.float32)
    b = jnp.asarray(np.random.default_rng(0).standard_normal((64, 32)),
                    jnp.float32)
    jaxmon.reset("launch.spmm_sharded")
    fn = jax.jit(lambda bb: dist_spmm.spmm_sharded(sharr, smeta, bb,
                                                   backend="xla",
                                                   n_chunks=2))
    ref = np.asarray(fn(b))
    np.testing.assert_allclose(np.asarray(fn(b)), ref)
    assert jaxmon.trace_count("launch.spmm_sharded") == 1
    jaxmon.assert_max_traces("launch.spmm_sharded", 1)


# ------------------------------------------------------- disabled => free
def test_disabled_tracing_is_zero_cost():
    """With REPRO_TRACE off: no state, a shared null span (no per-call
    allocation), event() returns None, and nothing is buffered."""
    assert trace._state is None or trace.enabled()  # env-dependent guard
    trace.configure(None)
    try:
        assert not trace.enabled()
        s1 = trace.span("x", a=1)
        s2 = trace.span("y")
        assert s1 is s2 is trace._NULL_SPAN
        with s1:
            pass
        assert trace.event("z", k=2) is None
        assert trace.timed_event("w", 1.0) is None
        assert trace.get_events() == []
        assert metrics.timeit(lambda: None, warmup=0, iters=1) >= 0.0
    finally:
        trace.configure(os.environ.get("REPRO_TRACE"))


def test_capture_works_even_when_disabled():
    trace.configure(None)
    try:
        with trace.capture() as cap:
            with trace.span("s"):
                trace.event("e")
        assert [e.name for e in cap.events] == ["s", "e", "s"]
        assert not trace.enabled()          # restored to disabled
    finally:
        trace.configure(os.environ.get("REPRO_TRACE"))
