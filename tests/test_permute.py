"""Permutation subsystem tests: fast Jaccard clustering vs the reference,
the single SCHEMES dispatch table, and end-to-end permutation transparency
of ``prepare_sparse(reorder=...)`` + ``spmm`` (forward AND both gradients
must match ``reorder="identity"``).

Exactness contract (f32, interpret mode):
  * forward: bit-for-bit (the un-permute gather reorders finished rows);
  * dvals:   bit-for-bit on the nonzero support, mapped back to dense and
             un-permuted (off-support entries belong to different stored
             blocks under different blockings, so coverage legitimately
             differs);
  * dB:      allclose at f32 rounding tolerance — re-blocking regroups the
             A^T accumulation, so partial sums round differently.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as core
from repro.core import bcsr as bcsr_lib
from repro.core import native, permute, reorder, topology
from repro.core.sparse_linear import (SparsitySpec, apply_sparse_linear,
                                      init_sparse_linear,
                                      sparse_linear_specs)
from repro.kernels import autotune, ops

ROW_SCHEMES = ("identity", "jaccard", "rcm", "shard_balance")


# ---------------------------------------------------------- fast clustering
def test_jaccard_fast_valid_permutation_and_reduction():
    csr = topology.blocked_random(n=768, nnz_target=12_000, cluster=32,
                                  seed=0)
    block = (16, 16)
    base = bcsr_lib.from_scipy(csr, block).nnzb
    p_fast = permute.jaccard_rows_fast(csr, block_w=16, tau=0.7)
    assert sorted(p_fast.tolist()) == list(range(csr.shape[0]))
    fast = bcsr_lib.from_scipy(reorder.apply_perm(csr, p_fast), block).nnzb
    p_slow = reorder.jaccard_rows(csr, block_w=16, tau=0.7)
    slow = bcsr_lib.from_scipy(reorder.apply_perm(csr, p_slow), block).nnzb
    assert fast < base
    # the vectorized rounds must cluster at least as well as the reference
    # greedy scan on clustered topologies (acceptance criterion)
    assert fast <= slow * 1.05, (fast, slow)


@pytest.mark.parametrize("tau,max_candidates", [(0.7, None), (0.5, 256),
                                                (0.9, 64)])
def test_native_kernel_matches_reference_exactly(tau, max_candidates):
    """The compiled kernel runs the exact reference greedy (sequential
    growing-union scan) — the permutation must be bit-identical."""
    if native.get_kernel() is None:
        pytest.skip("no C toolchain in this environment")
    csr = topology.blocked_random(n=1024, nnz_target=20_000, cluster=32,
                                  seed=3)
    p_fast = permute.jaccard_rows_fast(csr, block_w=16, tau=tau,
                                       max_candidates=max_candidates)
    p_ref = reorder.jaccard_rows(csr, block_w=16, tau=tau,
                                 max_candidates=max_candidates)
    np.testing.assert_array_equal(p_fast, p_ref)


def test_numpy_fallback_valid_and_comparable(monkeypatch):
    """Without the native kernel, the vectorized rounds must still produce
    a valid permutation clustering at least as well as the reference."""
    csr = topology.blocked_random(n=768, nnz_target=12_000, cluster=32,
                                  seed=4)
    block = (16, 16)
    p_ref = reorder.jaccard_rows(csr, block_w=16, tau=0.7)
    ref = bcsr_lib.from_scipy(reorder.apply_perm(csr, p_ref), block).nnzb
    monkeypatch.setenv("REPRO_NO_NATIVE_JACCARD", "1")
    p_np = permute.jaccard_rows_fast(csr, block_w=16, tau=0.7)
    assert sorted(p_np.tolist()) == list(range(csr.shape[0]))
    got = bcsr_lib.from_scipy(reorder.apply_perm(csr, p_np), block).nnzb
    assert got <= ref * 1.05, (got, ref)


def test_jaccard_fast_respects_max_candidates_window():
    csr = topology.blocked_random(n=512, nnz_target=8_000, cluster=32,
                                  seed=1)
    p = permute.jaccard_rows_fast(csr, block_w=16, tau=0.7,
                                  max_candidates=64)
    assert sorted(p.tolist()) == list(range(csr.shape[0]))


def test_jaccard_fast_empty_rows_cluster_together():
    dense = np.zeros((40, 64), np.float32)
    dense[::7, :8] = 1.0      # a few populated rows, many empty
    import scipy.sparse as sp
    p = permute.jaccard_rows_fast(sp.csr_matrix(dense), block_w=16, tau=0.7)
    assert sorted(p.tolist()) == list(range(40))


# ------------------------------------------------------------------ registry
def test_schemes_single_dispatch_table():
    assert core.SCHEMES is permute.SCHEMES
    assert reorder.SCHEMES is permute.SCHEMES
    for name in ("identity", "jaccard", "jaccard_rows_cols", "rcm",
                 "shard_balance"):
        assert name in permute.SCHEMES, name
    csr = topology.blocked_random(n=256, nnz_target=3_000, cluster=32,
                                  seed=2)
    # reorder() dispatches through the table (jaccard -> fast impl)
    p_dispatch = reorder.reorder(csr, "jaccard", block_w=16, tau=0.7)
    p_direct = permute.jaccard_rows_fast(csr, block_w=16, tau=0.7)
    np.testing.assert_array_equal(p_dispatch, p_direct)
    rp, cp = permute.SCHEMES["jaccard_rows_cols"](csr, block=(16, 16))
    assert sorted(rp.tolist()) == list(range(csr.shape[0]))
    assert sorted(cp.tolist()) == list(range(csr.shape[1]))
    with pytest.raises(ValueError, match="unknown reorder scheme"):
        reorder.reorder(csr, "nope")


def test_prepare_sparse_rejects_col_permuting_scheme():
    a = bcsr_lib.random_bcsr(3, (64, 64), (16, 16), 0.3)
    with pytest.raises(ValueError, match="column permutation"):
        ops.prepare_sparse(a, dtype=jnp.float32, reorder="jaccard_rows_cols")


# ------------------------------------------------- transparency (fwd + VJP)
def _mk_operand(seed, m, k, h, w, density, zero_rows):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)).astype(np.float32)
    dense[rng.random((m, k)) > density] = 0
    if zero_rows and m > 2 * h:
        dense[h:2 * h] = 0            # a whole empty block-row
    if not dense.any():
        dense[0, 0] = 1.0
    return bcsr_lib.from_dense(dense, (h, w)), dense


def _spmm_outputs(a, scheme, b, backend, interpret):
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32, reorder=scheme)

    def loss(vals, bb):
        out = ops.spmm(arrays._replace(vals=vals), meta, bb,
                       backend=backend, bn=128, interpret=interpret)
        return jnp.sum(out * jnp.cos(out))

    y = ops.spmm(arrays, meta, b, backend=backend, bn=128,
                 interpret=interpret)
    dvals, db = jax.grad(loss, argnums=(0, 1))(arrays.vals, b)
    # map dvals to dense ORIGINAL row order for cross-blocking comparison
    dw = np.asarray(ops.materialize_dense(
        arrays._replace(vals=dvals), meta))[: meta.shape[0], : meta.shape[1]]
    if arrays.inv_perm is not None:
        dw = dw[np.asarray(arrays.inv_perm)]
    return np.asarray(y), np.asarray(db), dw


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), m=st.integers(30, 90),
       k=st.integers(33, 100), h=st.sampled_from([8, 16]),
       w=st.sampled_from([8, 16]), density=st.floats(0.05, 0.5),
       zero_rows=st.booleans())
def test_property_every_scheme_matches_identity(seed, m, k, h, w, density,
                                                zero_rows):
    """spmm(prepare_sparse(A, reorder=s), B) == identity result for every
    row scheme — forward and both grads — including non-multiple-of-block
    shapes and empty block-rows."""
    a, dense = _mk_operand(seed, m, k, h, w, density, zero_rows)
    nz = dense != 0
    b = jnp.asarray(np.random.default_rng(seed + 1).standard_normal(
        (k, 17)).astype(np.float32))
    y0, db0, dw0 = _spmm_outputs(a, "identity", b, "xla", False)
    for scheme in ROW_SCHEMES[1:]:
        y, db, dw = _spmm_outputs(a, scheme, b, "xla", False)
        np.testing.assert_array_equal(y, y0, err_msg=f"{scheme} fwd")
        np.testing.assert_allclose(db, db0, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{scheme} dB")
        np.testing.assert_array_equal(dw[nz], dw0[nz],
                                      err_msg=f"{scheme} dvals support")


@pytest.mark.parametrize("scheme", ROW_SCHEMES[1:])
def test_pallas_interpret_matches_identity(scheme):
    a, dense = _mk_operand(42, 50, 70, 16, 16, 0.3, True)
    nz = dense != 0
    b = jnp.asarray(np.random.default_rng(43).standard_normal(
        (70, 33)).astype(np.float32))
    y0, db0, dw0 = _spmm_outputs(a, "identity", b, "pallas", True)
    y, db, dw = _spmm_outputs(a, scheme, b, "pallas", True)
    np.testing.assert_array_equal(y, y0)
    np.testing.assert_allclose(db, db0, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(dw[nz], dw0[nz])


# -------------------------------------------------- block-row granularity
@pytest.mark.parametrize("scheme", ROW_SCHEMES)
def test_block_row_granularity_preserves_nnzb(scheme):
    a = bcsr_lib.random_bcsr(5, (120, 64), (16, 16), 0.25)  # partial last row
    a2, row_perm = permute.permute_bcsr(a, scheme,
                                        granularity="block_row", n_shards=4)
    assert a2.nnzb == a.nnzb
    assert sorted(row_perm.tolist()) == list(range(120))
    np.testing.assert_array_equal(a2.to_dense(), a.to_dense()[row_perm])


@pytest.mark.parametrize("scheme", ROW_SCHEMES[1:])
def test_sparse_linear_reorder_matches_identity(scheme):
    x = jnp.asarray(np.random.default_rng(7).standard_normal(
        (2, 8, 64)).astype(np.float32))
    spec0 = SparsitySpec(density=0.3, block=(16, 16), backend="xla",
                         bn=128, interpret=False)
    spec1 = SparsitySpec(density=0.3, block=(16, 16), backend="xla",
                         bn=128, interpret=False, reorder=scheme,
                         reorder_shards=4)
    params0, meta0 = init_sparse_linear(0, 64, 96, spec0, dtype=jnp.float32)
    params1, meta1 = init_sparse_linear(0, 64, 96, spec1, dtype=jnp.float32)
    assert params1["vals"].shape == params0["vals"].shape
    specs1, meta_s = sparse_linear_specs(64, 96, spec1)
    for name in params1:
        assert params1[name].shape == specs1[name].shape, name
    assert meta1.reorder == meta_s.reorder == scheme
    y0 = apply_sparse_linear(params0, meta0, x, spec0)
    y1 = apply_sparse_linear(params1, meta1, x, spec1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)

    def loss(p, spec, meta):
        return jnp.sum(apply_sparse_linear(p, meta, x, spec) ** 2)

    g0 = jax.grad(lambda p: loss(p, spec0, meta0), allow_int=True)(params0)
    g1 = jax.grad(lambda p: loss(p, spec1, meta1), allow_int=True)(params1)
    # same trainable weight, different storage order: compare as dense
    def dense_grad(params, g, meta):
        arr = ops.SparseArrays(
            g["vals"], params["row_ids"], params["col_ids"],
            params["real_mask"], params["t_perm"], params["t_row_ids"],
            params["t_col_ids"])
        full = np.asarray(ops.materialize_dense(arr, meta))
        return full[np.asarray(params["inv_perm"])]
    np.testing.assert_allclose(dense_grad(params1, g1, meta1),
                               dense_grad(params0, g0, meta0),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ shard balance
def test_shard_balance_rows_balances_block_loads():
    csr = topology.power_law(1024, 8.0, seed=2)
    block = (16, 16)
    a = bcsr_lib.from_scipy(csr, block)
    n_shards = 8
    perm = permute.shard_balance_rows(csr, block=block, n_shards=n_shards)
    assert sorted(perm.tolist()) == list(range(1024))
    balanced = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm), block)
    assert balanced.nnzb == a.nnzb      # whole-block-row moves only

    def shard_std(mat):
        loads = [c.sum() for c in
                 np.array_split(mat.blocks_per_row(), n_shards)]
        return np.std(loads)
    assert shard_std(balanced) <= shard_std(a)


def test_spmm_shard_count_defaults():
    from repro.launch.sharding import spmm_shard_count
    assert spmm_shard_count() >= 1
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    assert spmm_shard_count(mesh) == 1


# -------------------------------------------------------------- fingerprint
def test_autotune_fingerprint_includes_reorder():
    a = bcsr_lib.random_bcsr_exact(9, (128, 128), (16, 16), 24,
                                   dtype=np.float32)
    _, meta_i = ops.prepare_sparse(a, dtype=jnp.float32)
    _, meta_s = ops.prepare_sparse(a, dtype=jnp.float32,
                                   reorder="shard_balance",
                                   reorder_granularity="block_row")
    # block-row shard balancing preserves every bucketed stat — only the
    # reorder field separates the cache keys
    assert meta_i.nnzb == meta_s.nnzb
    k_i = autotune.fingerprint(meta_i, 64).key()
    k_s = autotune.fingerprint(meta_s, 64).key()
    assert k_i != k_s
    assert "ro=shard_balance" in k_s
    assert (autotune.fingerprint_bcsr(a, 64, reorder="identity").key()
            == k_i)
