"""The SDDMM + block-sparse attention subsystem (PR 5).

Covers: the public ``ops.sddmm`` (forward/VJP parity vs the dense masked
reference across backends, reorder transparency), the v7 ``op=``
fingerprint contract (SpMM and SDDMM picks never alias — pinned exactly),
the mask builders, ``block_sparse_attention`` forward/backward vs the
dense-masked oracle across backends and mask specs, the ``dist_spmm`` row
sharding of the score structure (in-process AND shard_map when >= 4
devices are available — the CI ``test-multidevice`` job forces 8), and
the end-to-end wiring (transformer flag, ServeEngine decode, dryrun
report).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcsr as bcsr_lib
from repro.kernels import autotune, ops
from repro.models import attention as A


@pytest.fixture(autouse=True)
def _fresh_tuner():
    autotune.set_autotuner(autotune.Autotuner())
    yield
    autotune.set_autotuner(None)


def _mask_cfg(mask=None, backend="xla", **kw):
    return A.AttnSparsitySpec(mask=mask or A.banded(24), block=(8, 8),
                              backend=backend, interpret=True, **kw)


# ================================================================= ops.sddmm
def _mk(shape=(96, 128), block=(16, 16), density=0.3, seed=0):
    return bcsr_lib.random_bcsr(seed, shape, block,
                                density).ensure_nonempty_rows()


def _sddmm_dense_oracle(arrays, meta, x, y):
    h, w = meta.block
    M, K = meta.shape
    xp = x
    if meta.reorder != "identity" and arrays.row_perm is not None:
        xp = jnp.take(x, arrays.row_perm, axis=0)
    full = jnp.pad(xp, ((0, meta.n_block_rows * h - M), (0, 0))) @ \
        jnp.pad(y, ((0, meta.n_block_cols * w - K), (0, 0))).T
    blocks = full.reshape(meta.n_block_rows, h, meta.n_block_cols, w
                          ).transpose(0, 2, 1, 3)
    samp = blocks[np.asarray(arrays.row_ids), np.asarray(arrays.col_ids)]
    return samp * np.asarray(arrays.real_mask)[:, None, None]


@pytest.mark.parametrize("backend", ["auto", "xla", "pallas", "row_loop",
                                     "dense"])
def test_ops_sddmm_forward(backend):
    a = _mk()
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((96, 40)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((128, 40)).astype(np.float32))
    got = ops.sddmm(arrays, meta, x, y, backend=backend, bn=64,
                    interpret=True)
    want = _sddmm_dense_oracle(arrays, meta, x, y)
    assert got.shape == (meta.nnzb,) + tuple(meta.block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_sddmm_grads_match_dense(backend):
    a = _mk(shape=(64, 96), density=0.4)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 24)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((96, 24)).astype(np.float32))

    def loss(x, y):
        out = ops.sddmm(arrays, meta, x, y, backend=backend, bn=64,
                        interpret=True)
        return jnp.sum(out * out)

    def loss_dense(x, y):
        return jnp.sum(_sddmm_dense_oracle(arrays, meta, x, y) ** 2)

    gx, gy = jax.grad(loss, (0, 1))(x, y)
    gx_d, gy_d = jax.grad(loss_dense, (0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(gy_d),
                               rtol=1e-3, atol=1e-3)


def test_ops_sddmm_reorder_transparent():
    """A jaccard-reordered structure samples (P X) Y^T — callers keep
    passing original-order X, grads match the dense oracle."""
    a = _mk(density=0.25, seed=3)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32,
                                      reorder="jaccard")
    assert meta.reorder == "jaccard"
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((96, 24)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((128, 24)).astype(np.float32))
    got = ops.sddmm(arrays, meta, x, y, backend="pallas", bn=64,
                    interpret=True)
    want = _sddmm_dense_oracle(arrays, meta, x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    gx, gy = jax.grad(lambda x, y: jnp.sum(ops.sddmm(
        arrays, meta, x, y, backend="pallas", bn=64, interpret=True) ** 2),
        (0, 1))(x, y)
    gx_d, gy_d = jax.grad(lambda x, y: jnp.sum(
        _sddmm_dense_oracle(arrays, meta, x, y) ** 2), (0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(gy_d),
                               rtol=1e-3, atol=1e-3)


def test_spmm_sddmm_mutual_duals_second_order():
    """spmm's VJP runs sddmm and vice versa — second-order AD bounces
    between the two custom VJPs.  Pinned on the xla backend (the pure-jnp
    kernels differentiate to any order; interpret-mode Pallas kernels with
    scalar-prefetch grids have no JVP rule, so the dual chain's LEAVES cap
    the order there, not the chain itself)."""
    a = _mk(shape=(32, 32), block=(8, 8), density=0.5)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))

    def f(b):
        return jnp.sum(ops.spmm(arrays, meta, b, backend="xla") ** 3)

    hvp = jax.grad(lambda b: jnp.vdot(jax.grad(f)(b), b))(b)
    # oracle: same HVP through the dense equivalent
    dense = jnp.asarray(a.to_dense())

    def fd(b):
        return jnp.sum((dense @ b) ** 3)

    hvp_d = jax.grad(lambda b: jnp.vdot(jax.grad(fd)(b), b))(b)
    np.testing.assert_allclose(np.asarray(hvp), np.asarray(hvp_d),
                               rtol=1e-3, atol=1e-3)


# ======================================================= v7 fingerprint pins
def test_v6_key_format_pinned():
    """The exact v7 key layout — a cross-process cache contract."""
    fp = autotune.Fingerprint(
        n_block_rows=4, n_block_cols=5, block=(16, 16), nnzb=10,
        pad_bucket=1, skew_bucket=2, n_bucket=64, reorder="jaccard",
        n_shards=2, max_bpr=3, op="sddmm")
    assert fp.key() == ("v7|op=sddmm|nbr=4|nbc=5|b=16x16|nnzb=10|pad=1"
                        "|skew=2|n=64|ro=jaccard|ns=2|mb=3|nk=1")
    assert dataclasses.replace(fp, op="spmm").key() == (
        "v7|op=spmm|nbr=4|nbc=5|b=16x16|nnzb=10|pad=1"
        "|skew=2|n=64|ro=jaccard|ns=2|mb=3|nk=1")
    assert dataclasses.replace(fp, n_chunks=4).key().endswith("|nk=4")


def test_spmm_and_sddmm_keys_never_alias():
    a = _mk()
    meta = ops.prepare_sparse_meta(a)
    fp_spmm = autotune.fingerprint(meta, 64)
    fp_sddmm = autotune.fingerprint(meta, 64, op="sddmm")
    assert fp_spmm.key() != fp_sddmm.key()
    assert fp_spmm.key().startswith("v7|op=spmm|")
    assert fp_sddmm.key().startswith("v7|op=sddmm|")
    # a cached pick for one family is invisible to the other
    tuner = autotune.get_autotuner()
    tuner.put(fp_spmm, autotune.KernelChoice("xla", 512), persist=False)
    assert tuner.get(fp_sddmm) is None


def test_variant_families_disjoint():
    spmm_names = set(autotune.variant_names("spmm"))
    sddmm_names = set(autotune.variant_names("sddmm"))
    assert spmm_names == {"nnz_stream", "row_loop", "xla", "dense"}
    assert sddmm_names == {"sddmm_stream", "sddmm_row_loop", "sddmm_xla",
                           "sddmm_dense"}
    attn_names = set(autotune.variant_names("attn"))
    assert attn_names == {"attn_fused", "attn_composed"}
    assert not (spmm_names & sddmm_names) and not (attn_names &
                                                   (spmm_names | sddmm_names))
    assert set(autotune.variant_names(None)) == \
        spmm_names | sddmm_names | attn_names


def test_auto_pick_stays_in_family():
    a = _mk()
    meta = ops.prepare_sparse_meta(a)
    for n in (8, 64, 512):
        pick = autotune.get_autotuner().pick(meta, n, op="sddmm")
        assert pick.variant in autotune.variant_names("sddmm")
        pick_s = autotune.get_autotuner().pick(meta, n)
        assert pick_s.variant in autotune.variant_names("spmm")


def test_tune_sddmm_measured_and_persisted(tmp_path):
    a = _mk(shape=(64, 64), density=0.4)
    cache = str(tmp_path / "tuned.json")
    tuner = autotune.Autotuner(cache_path=cache)
    choice, timings = tuner.tune(a, 16, op="sddmm", iters=1)
    assert choice.variant in autotune.variant_names("sddmm")
    assert choice.source == "measured"
    assert timings
    # winner lands under the v7 op=sddmm key and reloads from disk
    fp = autotune.fingerprint_bcsr(a.ensure_nonempty_rows(), 16, op="sddmm")
    fresh = autotune.Autotuner(cache_path=cache)
    assert fresh.get(fp) == choice


# ============================================================== mask builders
def test_mask_builders_structure():
    L, blk = 128, (16, 16)
    m_causal = A.attention_mask_meta(A.blockwise_causal(), L, blk)
    nbr = m_causal.n_block_rows
    assert m_causal.nnzb == nbr * (nbr + 1) // 2      # dense causal blocks
    m_band = A.attention_mask_meta(A.banded(32), L, blk)
    assert m_band.nnzb < m_causal.nnzb
    assert m_band.max_bpr == 3                        # ceil((32+16)/16)
    m_lg = A.attention_mask_meta(A.local_global(32, 16), L, blk)
    assert m_band.nnzb < m_lg.nnzb < m_causal.nnzb
    with pytest.raises(ValueError):
        A.banded(0)


def test_mask_meta_matches_arrays_and_merges():
    spec = A.banded(24)
    arrays, meta = A.attention_mask_arrays(spec, 64, (8, 8))
    assert meta == A.attention_mask_meta(spec, 64, (8, 8))
    assert arrays.vals.shape[0] == meta.nnzb
    merged = A.merged_attention_meta([spec, spec], 64, (8, 8))
    assert merged == meta


# ===================================================== block-sparse attention
def _qkv(B=2, L=64, H=2, d=8, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.standard_normal((B, L, H, d)), jnp.float32)
    return mk(), mk(), mk()


def _dense_masked_attention(q, k, v, mask, scale=None, cap=None):
    B, L, H, d = q.shape
    scale = d ** -0.5 if scale is None else scale
    pos = jnp.arange(L)
    ok = A.mask_allowed(mask, pos, pos)
    s = jnp.einsum("blhd,bshd->bhls", q, k) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    s = jnp.where(ok[None, None], s, A.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhls,bshd->blhd", p, v)


@pytest.mark.parametrize("backend", ["auto", "xla", "pallas"])
@pytest.mark.parametrize("mask", [A.banded(24), A.local_global(16, 8),
                                  A.blockwise_causal()],
                         ids=["banded", "local_global", "causal"])
def test_attention_forward_matches_dense_masked(backend, mask):
    q, k, v = _qkv()
    spec = _mask_cfg(mask, backend=backend)
    out = A.block_sparse_attention(q, k, v, spec)
    want = _dense_masked_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["auto", "xla", "pallas"])
@pytest.mark.parametrize("mask", [A.banded(24), A.local_global(16, 8)],
                         ids=["banded", "local_global"])
def test_attention_grads_match_dense_masked(backend, mask):
    q, k, v = _qkv()
    spec = _mask_cfg(mask, backend=backend)
    g = jax.grad(lambda q, k, v: jnp.sum(
        A.block_sparse_attention(q, k, v, spec) ** 2), (0, 1, 2))(q, k, v)
    g_d = jax.grad(lambda q, k, v: jnp.sum(
        _dense_masked_attention(q, k, v, mask) ** 2), (0, 1, 2))(q, k, v)
    for got, want, name in zip(g, g_d, "qkv"):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_attention_softcap_and_scale():
    q, k, v = _qkv(seed=5)
    mask = A.banded(16)
    out = A.block_sparse_attention(q, k, v, _mask_cfg(mask), scale=0.25,
                                   cap=5.0)
    want = _dense_masked_attention(q, k, v, mask, scale=0.25, cap=5.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_block_softmax_rows_sum_to_one():
    arrays, meta = A.attention_mask_arrays(A.banded(24), 64, (8, 8))
    rng = np.random.default_rng(6)
    scores = jnp.asarray(rng.standard_normal(
        (meta.nnzb,) + tuple(meta.block)), jnp.float32)
    elem = (arrays.vals > 0.5) & arrays.real_mask[:, None, None]
    probs = A.block_softmax(scores, elem, arrays.row_ids,
                            meta.n_block_rows)
    assert bool(jnp.all(probs >= 0))
    assert np.all(np.asarray(probs)[~np.asarray(elem)] == 0)
    row_sums = jax.ops.segment_sum(probs.sum(axis=2), arrays.row_ids,
                                   num_segments=meta.n_block_rows)
    np.testing.assert_allclose(np.asarray(row_sums), 1.0, rtol=1e-5)


# ==================================================== sharded score structure
def test_attention_sharded_scores_local_fallback():
    q, k, v = _qkv()
    mask = A.banded(24)
    want = A.block_sparse_attention(q, k, v, _mask_cfg(mask))
    out = A.block_sparse_attention(q, k, v, _mask_cfg(mask, shards=4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # grads flow through the per-shard VJPs + outer gather
    g = jax.grad(lambda q: jnp.sum(A.block_sparse_attention(
        q, k, v, _mask_cfg(mask, shards=4)) ** 2))(q)
    g_ref = jax.grad(lambda q: jnp.sum(A.block_sparse_attention(
        q, k, v, _mask_cfg(mask)) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >= 4 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_attention_sharded_scores_shard_map():
    from repro.launch import dist_spmm
    q, k, v = _qkv()
    spec = _mask_cfg(A.banded(24), shards=4)
    want = A.block_sparse_attention(q, k, v, spec)    # local fallback
    mesh = dist_spmm.make_spmm_mesh(4)
    with dist_spmm.use_spmm_mesh(mesh):
        out = jax.jit(lambda q, k, v: A.block_sparse_attention(
            q, k, v, spec))(q, k, v)
        g = jax.grad(lambda q: jnp.sum(A.block_sparse_attention(
            q, k, v, spec) ** 2))(q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    g_ref = jax.grad(lambda q: jnp.sum(A.block_sparse_attention(
        q, k, v, spec) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


# ============================================================== model wiring
def _smoke_cfg(**attn_kw):
    from repro.configs.archs import ARCHS, smoke_config
    cfg = smoke_config(ARCHS["smat-attn-1.3b"])
    cfg = dataclasses.replace(cfg, dtype="float32")
    if attn_kw:
        cfg = dataclasses.replace(cfg, attn_sparsity=dataclasses.replace(
            cfg.attn_sparsity, **attn_kw))
    return cfg


def test_transformer_causal_sparse_equals_dense():
    from repro.models import transformer as T
    cfg = _smoke_cfg(mask=A.blockwise_causal())
    cfg_dense = dataclasses.replace(cfg, attn_sparsity=None)
    params = T.init_params(cfg, seed=0)
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 64)), jnp.int32)}
    l_sparse, _, _ = T.forward(cfg, params, batch)
    l_dense, _, _ = T.forward(cfg_dense, params, batch)
    np.testing.assert_allclose(np.asarray(l_sparse), np.asarray(l_dense),
                               rtol=1e-4, atol=1e-4)


def test_transformer_banded_equals_sliding_window():
    from repro.models import transformer as T
    cfg = _smoke_cfg(mask=A.banded(32))
    cfg_swa = dataclasses.replace(cfg, attn_sparsity=None,
                                  sliding_window=32)
    params = T.init_params(cfg, seed=0)
    batch = {"tokens": jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 64)), jnp.int32)}
    l_sparse, _, _ = T.forward(cfg, params, batch)
    l_swa, _, _ = T.forward(cfg_swa, params, batch)
    np.testing.assert_allclose(np.asarray(l_sparse), np.asarray(l_swa),
                               rtol=1e-4, atol=1e-4)


def test_transformer_train_grads_finite():
    from repro.models import transformer as T
    cfg = _smoke_cfg()
    params = T.init_params(cfg, seed=0)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 64)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    loss, _ = T.train_loss(cfg, params, batch, remat="full")
    g = jax.grad(lambda p: T.train_loss(cfg, p, batch, remat="full")[0],
                 allow_int=True)(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(g):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                     jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf)))


def test_serve_decode_consistent_with_dense():
    """ServeEngine decode traces through the sparse-mask bias: with the
    blockwise-causal mask (== plain causal) the served tokens must match a
    dense-attention engine exactly."""
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine
    cfg = _smoke_cfg(mask=A.blockwise_causal())
    cfg_dense = dataclasses.replace(cfg, attn_sparsity=None)
    params = T.init_params(cfg, seed=0)
    prompts = [np.asarray([5, 6, 7, 11]), np.asarray([9, 2])]

    def run(c):
        eng = ServeEngine(c, params, n_slots=2, cache_len=64)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        done = eng.run()
        return {r: done[r].out_tokens for r in done}

    assert run(cfg) == run(cfg_dense)


def test_dryrun_attention_report():
    from repro.launch import dryrun
    cfg = _smoke_cfg()
    rep = dryrun.sparse_attention_report(cfg, seq_len=128)
    assert rep["nnzb"] > 0 and rep["max_bpr"] > 0
    assert rep["mask"]["kind"] == "banded"
    assert 0 < rep["block_density_vs_causal"] <= 1.0
    assert rep["sddmm_pick"].split("/")[0] in ops.BACKENDS
    assert rep["spmm_pick"].split("/")[0] in ops.BACKENDS
    # dense archs without the flag report nothing
    assert dryrun.sparse_attention_report(
        dataclasses.replace(cfg, attn_sparsity=None)) == {}


def test_long_context_applicability():
    """A bounded sparse mask qualifies for the 500k decode cell; the
    blockwise-causal anchor does not."""
    from repro.configs.base import SHAPES, cell_applicable
    cfg = _smoke_cfg(mask=A.banded(32))
    ok, _ = cell_applicable(cfg, SHAPES["long_500k"])
    assert ok
    cfg_c = _smoke_cfg(mask=A.blockwise_causal())
    ok, _ = cell_applicable(cfg_c, SHAPES["long_500k"])
    assert not ok
