"""Serving engine regression tests: batched decode with diverged slot
positions must not corrupt other slots' KV cache (the per-group decode
writes pad-token KV for every batch row unless masked per slot)."""
import numpy as np
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine, _merge_cache


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="serve-test", family="dense", layout="attn_mlp",
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=97, dtype="float32")


def _decode_all(cfg, params, jobs, n_slots, max_new=3):
    """jobs: [(rid, prompt_list)] -> {rid: out_tokens} via one engine."""
    eng = ServeEngine(cfg, params, n_slots=n_slots, cache_len=32)
    for rid, prompt in jobs:
        eng.submit(Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                           max_new_tokens=max_new))
    done = eng.run()
    assert sorted(done) == sorted(r for r, _ in jobs)
    return {rid: req.out_tokens for rid, req in done.items()}


def test_concurrent_divergent_positions_match_sequential():
    """Two requests with different prompt lengths decoded concurrently
    (diverged positions -> per-group decode calls) must produce exactly the
    tokens each yields when decoded alone."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, seed=0)
    jobs = [(0, [1, 2, 3, 4, 5, 6, 7]), (1, [9, 8])]

    solo = {}
    for rid, prompt in jobs:
        solo.update(_decode_all(cfg, params, [(rid, prompt)], n_slots=1))
    batched = _decode_all(cfg, params, jobs, n_slots=2)

    for rid, _ in jobs:
        assert batched[rid] == solo[rid], (
            f"request {rid}: concurrent {batched[rid]} != solo {solo[rid]} "
            "— cross-slot KV-cache corruption")


def test_engine_matches_direct_decode_oracle():
    """Engine greedy decoding must equal a straight decode_step loop: all
    prompt tokens at pos 0..L-1, first output sampled from the LAST prompt
    token's logits.  Catches the duplicated-tail bug (prefilling prompt[-1]
    and then feeding it again writes its KV twice and conditions the whole
    continuation on a prompt with a doubled last token)."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, seed=0)
    # this prompt demonstrably diverges under the duplicated-tail bug
    # (buggy greedy collapses to repeating the last prompt token)
    prompt = [58, 93, 70, 61, 52]
    max_new = 4

    cache = T.init_cache(cfg, 1, 32)
    logits = None
    pos = 0
    for t in prompt:
        logits, cache = T.decode_step(
            cfg, params, cache, jnp.asarray([t], jnp.int32),
            jnp.asarray(pos, jnp.int32))
        pos += 1
    oracle = []
    for _ in range(max_new):
        tok = int(np.asarray(logits)[0].argmax(-1))
        oracle.append(tok)
        logits, cache = T.decode_step(
            cfg, params, cache, jnp.asarray([tok], jnp.int32),
            jnp.asarray(pos, jnp.int32))
        pos += 1

    got = _decode_all(cfg, params, [(0, prompt)], n_slots=1,
                      max_new=max_new)[0]
    assert got == oracle, (got, oracle)


def test_merge_cache_masks_per_slot():
    """Only masked slots' rows may change; every cache-leaf layout
    (attn k/v, mla ckv/krope, ssd conv/state) resolves its batch axis."""
    B = 4
    old = {
        "k": jnp.zeros((2, B, 8, 2, 4)), "v": jnp.zeros((2, B, 8, 2, 4)),
        "ckv": jnp.zeros((B, 8, 6)), "krope": jnp.zeros((B, 8, 2)),
        "conv": jnp.zeros((B, 3, 5)), "state": jnp.zeros((B, 2, 3, 4)),
    }
    new = {k: jnp.ones_like(v) for k, v in old.items()}
    mask = jnp.asarray([True, False, True, False])
    merged = _merge_cache(old, new, mask)
    for name, leaf in merged.items():
        ax = {"k": -4, "v": -4, "ckv": -3, "krope": -3,
              "conv": -3, "state": -4}[name]
        moved = np.moveaxis(np.asarray(leaf), ax, 0)
        assert (moved[np.asarray(mask)] == 1).all(), name
        assert (moved[~np.asarray(mask)] == 0).all(), name
