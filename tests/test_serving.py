"""PR 8 serving pins: the continuous-batching engine must be
token-for-token identical to fixed-slot decoding, the paged block-sparse
KV decode must be BITWISE equal to the dense-bias decode in f32, and
every scheduler/placement decision must be deterministic in the request
trace alone (same trace -> same admits, tokens, page tables — locally
and under the 8-device forced-host mesh used by CI's test-multidevice).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged_kv import PagePlacementSpec

jnp_f32 = jnp.float32


def _tiny_cfg(**over) -> ModelConfig:
    kw = dict(name="serving-test", family="dense", layout="attn_mlp",
              n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
              d_ff=64, vocab_size=97, dtype="float32")
    kw.update(over)
    return ModelConfig(**kw)


def _sparse_cfg(mask=None, **attn_over) -> ModelConfig:
    spec = A.AttnSparsitySpec(mask=mask or A.banded(32), block=(16, 16),
                              backend="xla", interpret=True, **attn_over)
    return _tiny_cfg(attn_sparsity=spec)


def _stream(cfg, params, requests, **engine_kw):
    eng = ServeEngine(cfg, params, **engine_kw)
    out = {}
    for rid, tok in eng.generate([dataclasses.replace(r) for r in requests]):
        out.setdefault(rid, []).append(tok)
    return eng, out


def _requests(n, rng, vocab, lens=(7, 2, 5, 3, 6), max_new=4):
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=lens[i % len(lens)],
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


# ------------------------------------------------- fixed-slot equivalence
def test_continuous_batching_matches_fixed_slot_reference():
    """With greedy sampling, the continuous engine (2 slots, 5 queued
    requests -> admissions/evictions mid-run) must emit for every request
    EXACTLY the tokens a fixed-slot decode_step loop produces for that
    request alone — slot rows are causally isolated, so continuous
    batching is a pure scheduling change."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, seed=0)
    reqs = _requests(5, np.random.default_rng(0), cfg.vocab_size)

    def fixed_slot_oracle(prompt, max_new):
        cache = T.init_cache(cfg, 1, 32)
        logits, pos = None, 0
        for t in prompt:
            logits, cache = T.decode_step(
                cfg, params, cache, jnp.asarray([t], jnp.int32),
                jnp.asarray(pos, jnp.int32))
            pos += 1
        out = []
        for _ in range(max_new):
            tok = int(np.asarray(logits, np.float32)[0].argmax(-1))
            out.append(tok)
            logits, cache = T.decode_step(
                cfg, params, cache, jnp.asarray([tok], jnp.int32),
                jnp.asarray(pos, jnp.int32))
            pos += 1
        return out

    _, got = _stream(cfg, params, reqs, n_slots=2, cache_len=32)
    assert sorted(got) == [r.rid for r in reqs]
    for r in reqs:
        want = fixed_slot_oracle(r.prompt, r.max_new_tokens)
        assert got[r.rid] == want, (
            f"rid {r.rid}: continuous {got[r.rid]} != fixed-slot {want}")


def test_generate_stream_matches_run_shim_and_warns():
    """The deprecated submit()/run() surface must produce token-for-token
    the same results as generate(), and both shims must warn."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, seed=0)
    reqs = _requests(4, np.random.default_rng(1), cfg.vocab_size)

    _, streamed = _stream(cfg, params, reqs, n_slots=2, cache_len=32)

    eng = ServeEngine(cfg, params, n_slots=2, cache_len=32)
    with pytest.warns(DeprecationWarning):
        for r in reqs:
            eng.submit(dataclasses.replace(r))
    with pytest.warns(DeprecationWarning):
        done = eng.run()
    assert {rid: req.out_tokens for rid, req in done.items()} == streamed


def test_enqueue_rejects_cache_overflow():
    cfg = _tiny_cfg()
    params = T.init_params(cfg, seed=0)
    eng = ServeEngine(cfg, params, n_slots=1, cache_len=8)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.enqueue(Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                            max_new_tokens=3))


# ------------------------------------------------------- paged KV decode
@pytest.mark.parametrize("mask", [A.banded(32), A.local_global(32, 16),
                                  A.blockwise_causal()],
                         ids=["banded", "local_global", "causal"])
def test_paged_decode_bitwise_equals_full_table(mask):
    """The paged gather + sequential per-page softmax fold must be
    BITWISE equal to running the same fold over the FULL page table
    (= the dense-bias decode): skipped pages contribute exact zeros, and
    inserting exact zeros into a sequential add chain is a no-op."""
    cfg = _sparse_cfg(mask=mask, paged_decode="force")
    Sc, (h, w) = 64, cfg.attn_sparsity.block
    n_pages = Sc // w
    B, KV, dh = 3, cfg.n_kv_heads, cfg.head_dim
    H = cfg.n_heads
    pages, live, _ = A.decode_page_table(mask, Sc, (h, w))
    full_pages = np.arange(n_pages, dtype=np.int32)[None]
    full_live = np.ones((1, n_pages), bool)

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp_f32)
    kc = jnp.asarray(rng.standard_normal((B, Sc, KV, dh)), jnp_f32)
    vc = jnp.asarray(rng.standard_normal((B, Sc, KV, dh)), jnp_f32)
    scale = dh ** -0.5
    for pos in (0, 7, 17, 40, 63):
        got = L._paged_decode(cfg, q, kc, vc, jnp.asarray(pos, jnp.int32),
                              None, None, scale, pages=pages, live=live)
        ref = L._paged_decode(cfg, q, kc, vc, jnp.asarray(pos, jnp.int32),
                              None, None, scale, pages=full_pages,
                              live=full_live)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), \
            f"paged decode diverged from dense-bias reference at pos={pos}"


@pytest.mark.parametrize("mask", [A.banded(32), A.local_global(32, 16)],
                         ids=["banded", "local_global"])
def test_engine_paged_force_matches_off(mask):
    """End-to-end: an engine decoding through the page table must emit
    the SAME greedy tokens as one with the paged path disabled (f32
    throughout -> the bitwise unit pin makes argmax identical)."""
    params = T.init_params(_sparse_cfg(mask=mask), seed=0)
    reqs = _requests(3, np.random.default_rng(4), 97, lens=(5, 3, 4))
    streams = {}
    for mode in ("force", "off"):
        cfg = _sparse_cfg(mask=mask, paged_decode=mode)
        eng, streams[mode] = _stream(cfg, params, reqs,
                                     n_slots=2, cache_len=64)
        if mode == "force":
            assert eng.paged_kv is not None
            assert all(g["paged"] for g in eng.paged_kv.report()["groups"])
    assert streams["force"] == streams["off"]


def test_engine_auto_paged_gates_on_page_saving():
    """"auto" engages paging only when the mask saves pages: banded(32)
    at cache_len 64 touches 3 of 4 pages -> paged; blockwise_causal
    touches all pages -> dense-bias decode."""
    assert L._decode_pages(_sparse_cfg(mask=A.banded(32)), None,
                           64) is not None
    assert L._decode_pages(_sparse_cfg(mask=A.blockwise_causal()), None,
                           64) is None


# --------------------------------------------------------- prefix cache
def test_prefix_cache_reuse_is_exact_and_counted():
    """Shared-prefix requests decoded with the prefix cache must emit the
    same tokens as with it disabled (copied KV rows are bitwise equal to
    recomputed ones), and the scheduler must record the hits."""
    cfg = _tiny_cfg()
    params = T.init_params(cfg, seed=0)
    base = np.asarray([11, 23, 5, 42, 7, 19], np.int32)
    reqs = [Request(rid=0, prompt=base, max_new_tokens=3),
            Request(rid=1, prompt=np.concatenate([base[:4], [88]])
                    .astype(np.int32), max_new_tokens=3),
            Request(rid=2, prompt=base.copy(), max_new_tokens=3)]

    eng_on, with_cache = _stream(cfg, params, reqs, n_slots=1, cache_len=32)
    eng_off, without = _stream(cfg, params, reqs, n_slots=1, cache_len=32,
                               prefix_cache=False)
    assert with_cache == without
    assert eng_on.scheduler.prefix_hits >= 2
    assert eng_on.scheduler.prefix_tokens_reused >= 8
    assert eng_off.scheduler.prefix_hits == 0
    # fewer decode dispatches with reuse: the engine skipped the reused
    # prefill positions entirely
    assert eng_on.scheduler.step_idx < eng_off.scheduler.step_idx


# -------------------------------------------------------- determinism
def test_serving_trace_determinism():
    """Two runs over the same seeded trace must agree on every admit/evict
    decision, every sampled token (greedy AND temperature: the engine key
    is seeded), and the full paged-KV report."""
    reqs = _requests(5, np.random.default_rng(5), 97)
    reqs[2].temperature = 0.7
    runs = []
    for _ in range(2):
        cfg = _sparse_cfg()
        params = T.init_params(cfg, seed=0)
        eng, toks = _stream(cfg, params, reqs, n_slots=2, cache_len=64)
        runs.append({"tokens": toks, "trace": eng.scheduler.trace,
                     "report": eng.paged_kv.report(),
                     "tables": jax.tree_util.tree_map(
                         lambda x: np.asarray(x).tolist(),
                         eng.paged_kv.table_leaves())})
    assert runs[0] == runs[1]


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >= 4 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_serving_trace_matches_under_mesh():
    """mesh=None vs a real 4-way spmm mesh (8 forced host devices in CI):
    identical token streams, scheduler traces, and page tables — the
    sharded FFN path is the same math and scheduling is host-side."""
    from repro.launch import dist_spmm
    from repro.core.sparse_linear import SparsitySpec
    ffn = SparsitySpec(density=0.5, block=(16, 16), backend="xla",
                       shards=4, interpret=True)
    reqs = _requests(4, np.random.default_rng(6), 97, lens=(5, 3, 6, 2))
    runs = {}
    for name, mesh in (("local", None),
                       ("mesh", dist_spmm.make_spmm_mesh(4))):
        cfg = _sparse_cfg()
        cfg = dataclasses.replace(cfg, d_ff=64, ffn_sparsity=ffn)
        params = T.init_params(cfg, seed=0)
        eng, toks = _stream(cfg, params, reqs, n_slots=2, cache_len=64,
                            spmm_mesh=mesh)
        runs[name] = {"tokens": toks, "trace": eng.scheduler.trace,
                      "tables": jax.tree_util.tree_map(
                          lambda x: np.asarray(x).tolist(),
                          eng.paged_kv.table_leaves())}
    assert runs["local"] == runs["mesh"]


# ----------------------------------------------- placement + invariants
def test_placement_budget_and_cost_model():
    cfg = _sparse_cfg()
    from repro.serve.paged_kv import PagedKVCache
    kv = PagedKVCache(cfg, 64, 2,
                      placement=PagePlacementSpec(resident_pages=2))
    rep = kv.report()
    (row,) = rep["groups"]
    assert row["paged"] and row["n_pages"] == 4
    assert row["resident_pages"] == 2
    assert rep["resident_bytes_total"] + rep["offload_bytes_total"] == \
        row["page_bytes"] * row["n_pages"] * row["n_layers"]
    # offloading must cost more than all-device in the model
    all_dev = PagedKVCache(cfg, 64, 2).group_report("attn", None,
                                                    cfg.n_layers)
    assert row["est_step_read_us"] > all_dev["est_step_read_us"]


def test_verify_page_table_invariants():
    from repro.analysis.verify_launch import verify_page_table
    for mask, sl in ((A.banded(32), 128), (A.local_global(32, 16), 128),
                     (A.blockwise_causal(), 64)):
        assert verify_page_table(mask, sl, (16, 16)) == []
        assert verify_page_table(mask, sl, (16, 16), resident_pages=2) == []


def test_verify_page_table_detects_budget_overflow(monkeypatch):
    from repro.analysis import verify_launch
    from repro.serve import paged_kv

    def too_many(mask, sl, block, pspec):
        return np.ones(int(paged_kv.page_demand(mask, sl, block).size), bool)

    monkeypatch.setattr(paged_kv, "page_placement", too_many)
    msgs = verify_launch.verify_page_table(A.banded(32), 128, (16, 16),
                                           resident_pages=1)
    assert any("resident-budget overflow" in m for m in msgs)
