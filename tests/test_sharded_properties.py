"""Property-based differential tests for the sharded, overlap-chunked
SpMM (``launch.dist_spmm``) against the single-device ``ops.spmm``.

The generator draws random block structures — density, row skew, ragged
(non-multiple-of-block) tails, empty block-rows, rectangular dims — and
for every shard count S in {1, 2, 4, 8} x chunk depth in {1, 2, 4} x
backend asserts the differential contracts:

  * forward: ``spmm_sharded`` is BIT-identical (uint32 view) to the
    unsharded ``ops.spmm`` under the SAME backend — sharding assigns each
    output block-row to exactly one shard and the chunked pipeline
    concatenates disjoint column panels, so no summation order changes;
  * VJP: dvals is bit-identical to the unsharded reference on the real
    support (the value grads flow through the same per-entry contraction;
    the chunked path differentiates via the unchunked exec), and dB
    matches to fp32 tolerance (cross-shard scatter-add order differs).

Runs under the deterministic ``hypothesis`` stub (``repro.testing``) when
the real package is absent, so the examples are reproducible in CI.  The
explicit regression corpus at the bottom pins the structures that
historically carried the edge cases (ragged tails, empty shards, skew,
pre-reorder composition).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from repro.core import bcsr as bcsr_lib
from repro.core import topology
from repro.kernels import ops
from repro.launch import dist_spmm

SHARD_COUNTS = (1, 2, 4, 8)
CHUNK_COUNTS = (1, 2, 4)
BLOCK = (16, 16)


# ------------------------------------------------------------- generators
def _random_structure(kind: str, nbr: int, nbc: int, tail_r: int,
                      tail_c: int, density: float, seed: int):
    """A BCSR matrix with the requested pathology.

    ``kind``:
      * ``uniform``    — iid Bernoulli support at ``density``;
      * ``skewed``     — per-row densities follow a power law (a few rows
                         carry most of the support; extreme single-row skew);
      * ``empty_rows`` — uniform support with ~1/3 of the BLOCK-rows
                         zeroed out entirely (empty shards downstream).
    """
    m = nbr * BLOCK[0] - tail_r
    k = nbc * BLOCK[1] - tail_c
    rng = np.random.default_rng(seed)
    if kind == "skewed":
        w = (1.0 / (1.0 + np.arange(m)) ** 0.8)
        p_row = np.minimum(density * m * w / w.sum() * 3.0, 0.9)
    else:
        p_row = np.full(m, density)
    if kind == "empty_rows":
        dead = rng.permutation(nbr)[:max(nbr // 3, 1)]
        for br in dead:
            p_row[br * BLOCK[0]:(br + 1) * BLOCK[0]] = 0.0
    mask = rng.random((m, k)) < p_row[:, None]
    dense = np.where(mask, rng.standard_normal((m, k)), 0.0)
    return bcsr_lib.from_scipy(sp.csr_matrix(dense.astype(np.float32)),
                               BLOCK)


def _b_for(a, n=24, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((a.shape[1], n)).astype(np.float32))


def _assert_bitwise(out, ref, msg):
    got = np.asarray(out)
    want = np.asarray(ref)
    assert got.shape == want.shape, msg
    assert np.array_equal(got.view(np.uint32), want.view(np.uint32)), \
        f"{msg}: not bit-identical (max abs diff " \
        f"{np.abs(got - want).max()})"


# -------------------------------------------------------- forward property
@settings(max_examples=5, deadline=None)
@given(kind=st.sampled_from(["uniform", "skewed", "empty_rows"]),
       nbr=st.integers(2, 7), nbc=st.integers(2, 7),
       tail_r=st.sampled_from([0, 0, 5, 11]),
       tail_c=st.sampled_from([0, 0, 3]),
       density=st.floats(0.08, 0.5),
       seed=st.integers(0, 10_000))
def test_forward_bitwise_property(kind, nbr, nbc, tail_r, tail_c,
                                  density, seed):
    """Every (S, n_chunks, backend) produces the same bits as the
    unsharded same-backend reference."""
    a = _random_structure(kind, nbr, nbc, tail_r, tail_c, density, seed)
    if a.nnzb == 0:
        return  # degenerate draw: nothing to multiply
    b = _b_for(a)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    tag = f"{kind} nbr={nbr} nbc={nbc} tails=({tail_r},{tail_c}) " \
          f"d={density:.2f} seed={seed}"
    for backend in ("xla", "pallas"):
        ref = ops.spmm(arrays, meta, b, backend=backend, interpret=True)
        # pallas-interpret is slow: spot-check (S, chunks) there, sweep
        # the full grid on xla (the corpus covers pallas chunk depths)
        shard_counts = SHARD_COUNTS if backend == "xla" else (1, 4)
        for n_shards in shard_counts:
            sharr, smeta = dist_spmm.prepare_sharded(a, n_shards,
                                                     dtype=jnp.float32)
            chunks = CHUNK_COUNTS if backend == "xla" else (1, 4)
            for k in chunks:
                out = dist_spmm.spmm_sharded(sharr, smeta, b,
                                             backend=backend, n_chunks=k,
                                             interpret=True)
                _assert_bitwise(out, ref,
                                f"{tag} {backend} S={n_shards} nk={k}")


# ------------------------------------------------------------ VJP property
@settings(max_examples=4, deadline=None)
@given(kind=st.sampled_from(["uniform", "skewed", "empty_rows"]),
       nbr=st.integers(2, 6), nbc=st.integers(2, 6),
       tail_r=st.sampled_from([0, 7]),
       density=st.floats(0.1, 0.4),
       seed=st.integers(0, 10_000))
def test_vjp_property(kind, nbr, nbc, tail_r, density, seed):
    """dvals bit-identical to the unsharded reference on the real support;
    dB within fp32 tolerance — at every shard count and chunk depth."""
    a = _random_structure(kind, nbr, nbc, tail_r, 0, density, seed)
    if a.nnzb == 0:
        return
    b = _b_for(a, n=20)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    tag = f"{kind} nbr={nbr} nbc={nbc} tail={tail_r} seed={seed}"

    def loss_ref(v, bb):
        arr = ops.SparseArrays(v, *arrays[1:])
        return jnp.sum(ops.spmm(arr, meta, bb, backend="xla") ** 2)

    rv, rb = jax.grad(loss_ref, argnums=(0, 1))(arrays.vals, b)
    for n_shards in SHARD_COUNTS:
        sharr, smeta = dist_spmm.prepare_sharded(a, n_shards,
                                                 dtype=jnp.float32)
        for k in CHUNK_COUNTS:
            def loss_sh(v, bb, _k=k, _sh=sharr, _sm=smeta):
                out = dist_spmm.spmm_sharded(_sh._replace(vals=v), _sm,
                                             bb, backend="xla",
                                             n_chunks=_k)
                return jnp.sum(out ** 2)

            gv, gb = jax.grad(loss_sh, argnums=(0, 1))(sharr.vals, b)
            _assert_bitwise(gv, rv, f"{tag} S={n_shards} nk={k} dvals")
            np.testing.assert_allclose(
                np.asarray(gb), np.asarray(rb), rtol=1e-4, atol=1e-3,
                err_msg=f"{tag} S={n_shards} nk={k} dB")


# -------------------------------------------------------- regression corpus
def _corpus():
    """Explicit structures that carried historical edge cases."""
    return [
        ("ragged_partial",
         bcsr_lib.random_bcsr(0, (23 * 16 + 5, 160), BLOCK, 0.3)),
        ("power_law_skew",
         bcsr_lib.from_scipy(topology.power_law(500, 5.0, seed=2), BLOCK)),
        ("rect_wide",
         bcsr_lib.random_bcsr(3, (96, 400), BLOCK, 0.2)),
        ("empty_block_rows",
         _random_structure("empty_rows", 6, 5, 0, 0, 0.3, 9)),
        ("tiny_fewer_rows_than_shards",
         bcsr_lib.random_bcsr(1, (30, 64), BLOCK, 0.5)),
    ]


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("n_chunks", CHUNK_COUNTS)
def test_corpus_forward_bitwise(n_shards, n_chunks):
    for name, a in _corpus():
        b = _b_for(a)
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        ref = ops.spmm(arrays, meta, b, backend="xla")
        sharr, smeta = dist_spmm.prepare_sharded(a, n_shards,
                                                 dtype=jnp.float32)
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla",
                                     n_chunks=n_chunks)
        _assert_bitwise(out, ref, f"{name} S={n_shards} nk={n_chunks}")


@pytest.mark.parametrize("n_chunks", CHUNK_COUNTS)
def test_corpus_forward_bitwise_pallas(n_chunks):
    """The kernel backend agrees with itself under sharding + chunking."""
    for name, a in _corpus()[:2]:
        b = _b_for(a, n=16)
        arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
        ref = ops.spmm(arrays, meta, b, backend="pallas", interpret=True)
        sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="pallas",
                                     n_chunks=n_chunks, interpret=True)
        _assert_bitwise(out, ref, f"{name} pallas nk={n_chunks}")


def test_corpus_chunked_jit_matches_eager():
    """jit tracing the chunked dispatch changes nothing (the schedule is
    static python — same XLA program either way)."""
    _, a = _corpus()[0]
    b = _b_for(a)
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32)
    eager = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla",
                                   n_chunks=4)
    jitted = jax.jit(lambda bb: dist_spmm.spmm_sharded(
        sharr, smeta, bb, backend="xla", n_chunks=4))(b)
    _assert_bitwise(jitted, eager, "jit vs eager nk=4")


def test_corpus_reorder_composes_with_chunking():
    """Pre-reorder + sharding + chunking still returns the ORIGINAL row
    order (allclose — the permutation changes accumulation order)."""
    a = bcsr_lib.from_scipy(
        topology.blocked_random(n=512, nnz_target=9000, cluster=16, seed=1),
        BLOCK)
    b = _b_for(a)
    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32)
    ref = ops.spmm(arrays, meta, b, backend="xla")
    sharr, smeta = dist_spmm.prepare_sharded(a, 4, dtype=jnp.float32,
                                             reorder="jaccard")
    for k in CHUNK_COUNTS:
        out = dist_spmm.spmm_sharded(sharr, smeta, b, backend="xla",
                                     n_chunks=k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4,
                                   err_msg=f"jaccard nk={k}")
