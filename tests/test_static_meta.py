"""Static structure-metadata pipeline tests (specs-vs-init contract,
model-path heterogeneous per-shard dispatch, reorder-aware row_loop
schedules, v6 fingerprints).

The contract under test: a sparse layer's TRUE structure meta is a pure
static function of ``(seed, dims, spec)`` — ``sparse_linear_meta`` (and
``sparse_linear_specs(..., seed=...)``) must reproduce exactly what
``init_sparse_linear`` returns, and the model path (``models.layers.mlp``)
must dispatch on those metas rather than dims-only stand-ins, so
``SparsitySpec(shards=S)`` gets the same per-shard autotune picks as the
raw ``launch.dist_spmm`` API.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import bcsr as bcsr_lib
from repro.core import topology
from repro.core.sparse_linear import (SparsitySpec, init_sparse_linear,
                                      merge_sparse_metas, shard_shapes,
                                      sparse_linear_meta,
                                      sparse_linear_specs, _pattern_for)
from repro.kernels import autotune, ops
from repro.launch import dist_spmm
from repro.models import layers as L
from repro.models import transformer as T

D, F = 96, 160


def _spec(shards=0, reorder="identity", backend="xla"):
    return SparsitySpec(density=0.3, block=(16, 16), backend=backend,
                        reorder=reorder, shards=shards, interpret=True)


# ------------------------------------------------------ specs-vs-init contract
@pytest.mark.parametrize("shards", [0, 1, 4])
@pytest.mark.parametrize("reorder", ["identity", "jaccard"])
def test_specs_meta_matches_init_meta(shards, reorder):
    """The same (seed, dims, spec) must yield the SAME meta through all
    three derivations: init (params + meta), the memoized static path,
    and the seeded specs path — across seeds, shard counts, reorder."""
    spec = _spec(shards=shards, reorder=reorder)
    for seed in (3, 11, 42):
        _, m_init = init_sparse_linear(seed, D, F, spec, dtype=jnp.float32)
        _, m_specs = sparse_linear_specs(D, F, spec, dtype=jnp.float32,
                                         seed=seed)
        assert m_specs == m_init
        assert sparse_linear_meta(seed, D, F, spec) == m_init
        if shards > 0:
            assert all(m.max_bpr > 0 for m in m_init.shard_metas)
        else:
            assert m_init.max_bpr > 0


def test_seedless_specs_stay_dims_only():
    """Back-compat: without a seed the specs meta carries zero stats (the
    allocation-free dry-run mode) and the param specs are unchanged."""
    spec = _spec(shards=4)
    p_plain, m_plain = sparse_linear_specs(D, F, spec, dtype=jnp.float32)
    p_seeded, m_seeded = sparse_linear_specs(D, F, spec, dtype=jnp.float32,
                                             seed=7)
    assert all(m.max_bpr == 0 for m in m_plain.shard_metas)
    assert jax.tree.map(lambda s: (s.shape, s.dtype), p_plain) == \
        jax.tree.map(lambda s: (s.shape, s.dtype), p_seeded)
    assert any(m.max_bpr > 0 for m in m_seeded.shard_metas)


# -------------------------------------------------------------- meta merging
def test_merge_sparse_metas_takes_stats_max():
    spec = _spec()
    metas = [sparse_linear_meta(s, D, F, spec) for s in (1, 2, 3)]
    merged = merge_sparse_metas(metas)
    assert merged.max_bpr == max(m.max_bpr for m in metas)
    assert merged.bpr_cv_pct == max(m.bpr_cv_pct for m in metas)
    assert merged.nnzb == metas[0].nnzb        # static fields preserved


def test_merge_sparse_metas_shard_wise():
    spec = _spec(shards=4)
    metas = [sparse_linear_meta(s, D, F, spec) for s in (1, 2, 3)]
    merged = merge_sparse_metas(metas)
    for s in range(4):
        assert merged.shard_metas[s].max_bpr == \
            max(m.shard_metas[s].max_bpr for m in metas)


def test_merge_sparse_metas_rejects_mismatched_structure():
    spec = _spec()
    m0 = sparse_linear_meta(1, D, F, spec)
    m1 = sparse_linear_meta(1, D, F + 32, spec)
    with pytest.raises(ValueError, match="static structure"):
        merge_sparse_metas([m0, m1])


# ------------------------------------------------- model path == direct API
def test_model_path_shard_metas_match_direct_dist_spmm():
    """SparsitySpec(shards=4) through mlp(): the static metas the model
    path dispatches on are EXACTLY the ShardedMetas the raw dist_spmm API
    builds for the same patterns — so per-shard picks are identical."""
    spec = _spec(shards=4, backend="auto")
    meta_in, meta_out = L.mlp_sparse_metas(spec, D, F, (0,))

    def direct(seed, in_dim, out_dim):
        a = _pattern_for(seed, in_dim, out_dim, spec)
        rps, nnzb_ps, _ = shard_shapes(spec, out_dim, in_dim)
        _, m = dist_spmm.prepare_sharded(
            a, spec.shards, col_shards=spec.shard_cols, dtype=jnp.float32,
            reorder=spec.reorder, rows_per_shard=rps,
            nnzb_per_shard=nnzb_ps)
        return m

    seed = L.mlp_seed(0)
    m_gate = direct(seed, D, F)
    m_up = direct(seed + 1, D, F)
    m_down = direct(seed + 2, F, D)
    assert meta_in == merge_sparse_metas([m_gate, m_up])
    assert meta_out == m_down
    for n in (8, 64, 512):
        picks_model = [ops.resolve_backend("auto", spec.bn, m, n)
                       for m in meta_out.shard_metas]
        picks_direct = [ops.resolve_backend("auto", spec.bn, m, n)
                        for m in m_down.shard_metas]
        assert picks_model == picks_direct


def test_model_path_shard_fingerprints_differ():
    """Regression vs the dims-only collapse: shards with different local
    structures must reach the autotuner as DIFFERENT v6 fingerprints
    through the model path (they used to share one zero-stats key)."""
    spec = _spec(shards=4, backend="auto")
    meta_in, meta_out = L.mlp_sparse_metas(spec, D, F, (0,))
    for meta in (meta_in, meta_out):
        keys = {autotune.fingerprint(m, 64).key() for m in meta.shard_metas}
        assert len(keys) >= 2, keys


def test_model_path_heterogeneous_picks_execute():
    """End-to-end: a tuner seeded with DIFFERENT per-shard picks drives
    the model path through the multi-branch dispatch, and the output
    matches the xla-only reference bit-for-tolerance."""
    cfg = dataclasses.replace(get_config("smat-ffn-1.3b:smoke"),
                              dtype="float32", d_model=D, d_ff=F)
    spec_auto = _spec(shards=4, backend="auto")
    spec_xla = dataclasses.replace(spec_auto, backend="xla")
    cfg_auto = dataclasses.replace(cfg, ffn_sparsity=spec_auto)
    cfg_xla = dataclasses.replace(cfg, ffn_sparsity=spec_xla)

    params = L.init_mlp(cfg_auto, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, D), jnp.float32)
    n_tokens = 2 * 4

    meta_in, meta_out = L.mlp_sparse_metas(spec_auto, D, F, (0,))
    tuner = autotune.Autotuner()
    variants = [("nnz_stream", 128), ("xla", 512)]
    for meta in (meta_in, meta_out):
        fps = []
        for m in meta.shard_metas:
            fp = autotune.fingerprint(m, n_tokens)
            if fp.key() not in [f.key() for f in fps]:
                fps.append(fp)
        assert len(fps) >= 2          # structures genuinely diverge
        for i, fp in enumerate(fps):
            v, bn = variants[i % len(variants)]
            tuner.put(fp, autotune.KernelChoice(v, bn, source="measured"),
                      persist=False)

    old = autotune.get_autotuner()
    autotune.set_autotuner(tuner)
    try:
        for meta in (meta_in, meta_out):
            choices = dist_spmm._resolve_shard_choices(
                meta, n_tokens, "auto", spec_auto.bn)
            # picks did NOT collapse to one streaming choice
            assert len(set(choices)) >= 2, choices
        y_auto = L.mlp(cfg_auto, params, x)
    finally:
        autotune.set_autotuner(old)
    y_ref = L.mlp(cfg_xla, params, x)
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_stacked_model_forward_auto_matches_xla():
    """The merged stack meta must be valid for EVERY scanned layer: a full
    2-layer sparse-FFN forward under backend='auto' (real stats, possibly
    row_loop) matches the xla-backend forward on the same params."""
    cfg0 = dataclasses.replace(get_config("smat-ffn-1.3b:smoke"),
                               dtype="float32")
    spec_auto = dataclasses.replace(cfg0.ffn_sparsity, backend="auto")
    cfg_auto = dataclasses.replace(cfg0, ffn_sparsity=spec_auto)
    cfg_xla = dataclasses.replace(
        cfg0, ffn_sparsity=dataclasses.replace(spec_auto, backend="xla"))
    params = T.init_params(cfg_auto, seed=0)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg0.vocab_size, (1, 8)),
        jnp.int32)}
    la, _, _ = T.forward(cfg_auto, params, batch)
    lx, _, _ = T.forward(cfg_xla, params, batch)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lx),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------ reorder-aware row_loop
def test_reorder_strictly_shrinks_row_loop_schedule():
    """Acceptance: on a clustered structure, the jaccard permutation gives
    a STRICTLY shorter row_loop static schedule than identity order, and
    the shrunk schedule still computes the right answer."""
    csr = topology.blocked_random(n=512, nnz_target=9000, cluster=16, seed=1)
    a = bcsr_lib.from_scipy(csr, (16, 16))
    m_id = ops.prepare_sparse_meta(a)
    m_ro = ops.prepare_sparse_meta(a, reorder="jaccard")
    assert m_ro.max_bpr < m_id.max_bpr
    assert m_ro.row_loop_sched_len < m_id.row_loop_sched_len

    arrays, meta = ops.prepare_sparse(a, dtype=jnp.float32,
                                      reorder="jaccard")
    assert meta == m_ro       # prepare vs meta-only: bit-identical
    b = jnp.asarray(np.random.default_rng(0).standard_normal(
        (meta.shape[1], 32)).astype(np.float32))
    y_rl = ops.spmm(arrays, meta, b, backend="row_loop", interpret=True)
    arr_id, meta_id = ops.prepare_sparse(a, dtype=jnp.float32)
    y_ref = ops.spmm(arr_id, meta_id, b, backend="xla")
    np.testing.assert_allclose(np.asarray(y_rl), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_fingerprint_carries_schedule_bound():
    """Two metas identical except for the row_loop schedule bound must not
    alias in the cache (the mb= field, added in v4), so a shrunk reordered
    structure never inherits the unshrunk twin's row_loop decision."""
    a = bcsr_lib.random_bcsr_exact(0, (256, 256), (16, 16), nnzb=64)
    meta = ops.prepare_sparse_meta(a)
    twin = dataclasses.replace(meta, max_bpr=meta.max_bpr + 1)
    k0, k1 = autotune.fingerprint(meta, 64).key(), \
        autotune.fingerprint(twin, 64).key()
    assert k0 != k1
    assert k0.startswith("v7|") and f"mb={meta.max_bpr}" in k0
