"""Substrate tests: data pipeline, optimizer, checkpoint manager (atomic,
async, elastic), train loop (restart after injected failure, straggler
watchdog plumbing), serving engine."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data.pipeline import PrefetchIterator, make_batch
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.optim import adamw, compress
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import SimulatedFailure, train, train_with_restarts

CFG = get_config("h2o-danube-1.8b:smoke")
SHAPE = ShapeCell("t", "train", 64, 4)


def _mesh():
    return mesh_lib.make_mesh((1, 1), ("data", "model"))


# ------------------------------------------------------------------- data
def test_data_deterministic_and_restart_safe():
    b1 = make_batch(CFG, SHAPE, step=7)
    b2 = make_batch(CFG, SHAPE, step=7)
    b3 = make_batch(CFG, SHAPE, step=8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_disjoint():
    a = make_batch(CFG, SHAPE, step=3, host_id=0, n_hosts=2)
    b = make_batch(CFG, SHAPE, step=3, host_id=1, n_hosts=2)
    assert a["tokens"].shape[0] == SHAPE.global_batch // 2
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetch_iterator():
    it = PrefetchIterator(CFG, SHAPE, start_step=5)
    s0, b0 = next(it)
    s1, b1 = next(it)
    it.close()
    assert (s0, s1) == (5, 6)
    np.testing.assert_array_equal(b0["tokens"],
                                  make_batch(CFG, SHAPE, 5)["tokens"])


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_loss_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100)
    params = {"w": jnp.asarray([2.0, -3.0]), "idx": jnp.asarray([1, 2])}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss, allow_int=True)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 1e-2
    np.testing.assert_array_equal(params["idx"], [1, 2])  # ints untouched


def test_grad_clipping():
    cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw.update(cfg, g, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


# -------------------------------------------------------------- compression
def test_int8_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(128),
                    jnp.float32)
    q, s = compress.quantize_int8(x)
    err = np.abs(np.asarray(compress.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.51 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed sum converges to the
    true sum (the residual carries rounding error forward)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)
    residual = jnp.zeros_like(g_true)
    acc_c, acc_t = jnp.zeros_like(g_true), jnp.zeros_like(g_true)
    for _ in range(50):
        g32 = g_true + residual
        q, s = compress.quantize_int8(g32)
        g_hat = compress.dequantize_int8(q, s)
        residual = g32 - g_hat
        acc_c += g_hat
        acc_t += g_true
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.02, rel


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": jnp.zeros((2, 3))}}
    mgr.save(10, state)
    mgr.save(20, state)
    mgr.save(30, state)
    assert mgr.all_steps() == [20, 30]          # keep=2 GC'd step 10
    like = jax.eval_shape(lambda: state)
    restored, step = mgr.restore(like)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one mesh, restore under a different device layout."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    mesh = _mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(jax.eval_shape(lambda: state), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16.0).reshape(4, 4))


# ---------------------------------------------------------------- train loop
def test_train_loss_decreases(tmp_path):
    res = train(CFG, SHAPE, _mesh(), total_steps=12,
                opt_cfg=adamw.AdamWConfig(lr=2e-3, total_steps=12,
                                          warmup_steps=2),
                ckpt_dir=str(tmp_path), ckpt_every=6)
    assert len(res.losses) == 12
    assert res.losses[-1] < res.losses[0]
    assert all(np.isfinite(res.losses))


def test_train_restart_after_injected_failure(tmp_path):
    res = train_with_restarts(
        CFG, SHAPE, lambda i: _mesh(), total_steps=10,
        ckpt_dir=str(tmp_path), ckpt_every=4, fail_at_step=6,
        max_restarts=2,
        opt_cfg=adamw.AdamWConfig(lr=1e-3, total_steps=10))
    assert res.restarts_used == 1
    # restart resumed from step 4 checkpoint -> ran steps 4..9 again
    assert res.final_step == 10


def test_train_failure_without_ckpt_raises():
    with pytest.raises(SimulatedFailure):
        train(CFG, SHAPE, _mesh(), total_steps=5, fail_at_step=2)


# -------------------------------------------------------------------- serve
def test_serve_engine_batched_requests():
    cfg = get_config("h2o-danube-1.8b:smoke")
    params = T.init_params(cfg, seed=0)
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64)
    rng = np.random.default_rng(2)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, size=4,
                                               dtype=np.int32),
                           max_new_tokens=4))
    done = eng.run()
    assert sorted(done) == [0, 1, 2, 3]
    for r in done.values():
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serve_greedy_matches_forward():
    """Engine greedy decode must equal argmax of the teacher-forced
    forward logits."""
    import dataclasses
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b:smoke"),
                              dtype="float32")
    params = T.init_params(cfg, seed=1)
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    eng = ServeEngine(cfg, params, n_slots=1, cache_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    done = eng.run()
    first = done[0].out_tokens[0]

    logits, _, _ = T.forward(cfg, params,
                             {"tokens": jnp.asarray(prompt[None])})
    want = int(np.asarray(logits, np.float32)[0, -1].argmax())
    assert first == want
