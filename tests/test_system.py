"""End-to-end behaviour tests: the paper's pipeline as a system —
CSR -> reorder -> BCSR -> kernels inside a model -> train -> checkpoint ->
serve — wired together exactly as the launchers do."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.core import bcsr as bcsr_lib
from repro.core import reorder, topology
from repro.kernels import ops
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.optim import adamw
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import train


def test_paper_pipeline_end_to_end():
    """The full SMaT pipeline on one matrix: reorder reduces blocks, kernels
    agree with dense, gradients flow through the sparse op."""
    csr = topology.blocked_random(n=512, nnz_target=8_000, cluster=32,
                                  seed=0)
    perm = reorder.jaccard_rows(csr, block_w=16, tau=0.7)
    a0 = bcsr_lib.from_scipy(csr, (16, 16))
    a1 = bcsr_lib.from_scipy(reorder.apply_perm(csr, perm), (16, 16))
    assert a1.nnzb < a0.nnzb                     # preprocessing worked

    arrays, meta = ops.prepare_sparse(a1.ensure_nonempty_rows(),
                                      dtype=jnp.float32)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(
        (meta.n_block_cols * 16, 24)).astype(np.float32))
    y_k = ops.spmm(arrays, meta, b, backend="pallas", interpret=True)
    y_d = ops.spmm(arrays, meta, b, backend="dense")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_d),
                               rtol=1e-3, atol=1e-3)

    g = jax.grad(lambda v: jnp.sum(
        ops.spmm(arrays._replace(vals=v), meta, b, backend="xla") ** 2))(
            arrays.vals)
    assert float(jnp.abs(g).sum()) > 0


def test_sparse_lm_train_then_serve(tmp_path):
    """Train the paper-technique LM a few steps, checkpoint, reload into a
    serving engine, decode — the whole deployment loop."""
    cfg = dataclasses.replace(get_config("smat-ffn-1.3b:smoke"),
                              dtype="float32")
    shape = ShapeCell("sys", "train", 32, 2)
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    res = train(cfg, shape, mesh, total_steps=6,
                opt_cfg=adamw.AdamWConfig(lr=1e-3, total_steps=6,
                                          warmup_steps=1),
                ckpt_dir=str(tmp_path), ckpt_every=3)
    assert all(np.isfinite(res.losses))

    # reload the final checkpoint and serve from it
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    like = {"params": T.param_specs(cfg),
            "opt": jax.eval_shape(adamw.init, T.param_specs(cfg))}
    state, step = mgr.restore(like)
    assert step == 6

    eng = ServeEngine(cfg, state["params"], n_slots=1, cache_len=16)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=3))
    done = eng.run()
    assert len(done[0].out_tokens) == 3


def test_doctest_module_list_is_live():
    """``tests/doctest_modules.txt`` is the single source of truth for
    which modules CI runs ``--doctest-modules`` over.  Guard it against
    import rot: every listed file must exist AND import cleanly (a renamed
    or deleted module would otherwise fail only in the workflow, not
    locally), and the PR-6 fused-attention kernel must stay on the list so
    its docstring example keeps executing as a test."""
    import importlib
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    listing = os.path.join(root, "tests", "doctest_modules.txt")
    paths = [ln.strip() for ln in open(listing) if ln.strip()]
    assert paths, "doctest_modules.txt is empty"
    assert "src/repro/kernels/bcsr_attn.py" in paths
    for rel in paths:
        assert os.path.exists(os.path.join(root, rel)), \
            f"doctest_modules.txt lists missing file {rel}"
        assert rel.startswith("src/") and rel.endswith(".py"), rel
        mod_name = rel[len("src/"):-len(".py")].replace("/", ".")
        importlib.import_module(mod_name)


def test_benchmark_modules_importable():
    """Every module benchmarks/run.py can dispatch to — the gated SUITE
    and the report-only FIGURES — must stay importable, with the expected
    entry points.  CI runs only the gated suite; this keeps the figure
    modules from silently bit-rotting (they used to be orphans)."""
    import importlib
    import os
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    run = importlib.import_module("benchmarks.run")
    for mod_name, baseline in run.SUITE:
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        assert callable(mod.run) and callable(mod.diff), mod_name
        assert os.path.exists(os.path.join(root, "benchmarks", baseline)), \
            f"{mod_name}: committed baseline {baseline} missing"
    for mod_name in run.FIGURES:
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        assert callable(mod.run), mod_name
    assert callable(
        importlib.import_module("benchmarks.compare_sweeps").main)
